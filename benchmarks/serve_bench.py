"""Serving throughput (smoke scale): batched KV-cache decode tok/s per
family — dense, MoE (clustered dispatch), SSM (O(1) state)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model

ARCHS = ["olmo-1b", "dbrx-132b", "mamba2-1.3b"]


def run(batch: int = 8, gen: int = 32) -> List[Dict]:
    rows = []
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(batch, gen + 1)
        decode = jax.jit(m.decode_step, donate_argnums=(1,))
        tok = jnp.zeros((batch, 1), jnp.int32)
        logits, cache = decode(params, cache, tok, jnp.int32(0))  # warmup
        t0 = time.time()
        for i in range(gen):
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            logits, cache = decode(params, cache, tok, jnp.int32(i + 1))
        logits.block_until_ready()
        dt = time.time() - t0
        rows.append({"arch": arch, "family": cfg.family,
                     "tok_s": batch * gen / dt,
                     "ms_per_step": dt / gen * 1e3})
    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        print(f"serve_{r['arch']},{r['ms_per_step'] * 1e3:.0f},"
              f"family={r['family']};tok_s={r['tok_s']:.0f}")


if __name__ == "__main__":
    main()
