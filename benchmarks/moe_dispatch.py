"""Clustered vs one-hot MoE dispatch: wall time + FLOPs (smoke scale).

The framework-level incarnation of the paper's comparison: bucketed
(sorted) dispatch vs the dense one-hot baseline. The dry-run supplies the
production-scale HLO numbers (EXPERIMENTS.md §Perf); this bench gives a
runnable, CPU-scale wall-time contrast.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.configs.registry import get_smoke_config
from repro.models import moe as moe_mod
from repro.models.registry import build_model


def run(t: int = 4096, e: int = 16, k: int = 4, d: int = 256,
        repeats: int = 20) -> List[Dict]:
    cfg = get_smoke_config("dbrx-132b").with_(
        d_model=d, dtype="float32",
        moe=MoEConfig(n_experts=e, top_k=k, capacity_factor=1.25))
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda a: a[0], m.init(key)["blocks"]["moe"])
    x = jax.random.normal(key, (t, cfg.d_model), jnp.float32)
    rows = []
    for name, fn, g in [
            ("clustered", moe_mod.moe_clustered, 4),
            ("onehot", moe_mod.moe_onehot, max(1, t // 1024))]:
        jf = jax.jit(lambda p, x: fn(cfg, p, x, g))
        jf(p, x)[0].block_until_ready()
        t0 = time.time()
        for _ in range(repeats):
            y, _ = jf(p, x)
        y.block_until_ready()
        dt = (time.time() - t0) / repeats
        # analytic dispatch flops
        c = moe_mod._capacity(cfg, t // g)
        if name == "onehot":
            disp_flops = 2 * t * e * c * (d + 2)     # dispatch+combine
        else:
            disp_flops = 0                            # sort/gather only
        rows.append({"policy": name, "wall_s": dt,
                     "dispatch_flops": disp_flops})
    return rows


def main():
    print("bench,us_per_call,derived")
    rows = run()
    base = {r["policy"]: r for r in rows}
    sp = base["onehot"]["wall_s"] / base["clustered"]["wall_s"]
    for r in rows:
        print(f"moe_dispatch_{r['policy']},{r['wall_s'] * 1e6:.0f},"
              f"dispatch_flops={r['dispatch_flops']:.2e}")
    print(f"moe_dispatch_speedup,0,clustered_vs_onehot={sp:.2f}x")


if __name__ == "__main__":
    main()
