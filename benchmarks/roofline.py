"""Roofline report: aggregates results/dryrun/*.json into the §Roofline
table (single-pod cells) and writes results/roofline.md."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

DRYRUN_DIR = Path("results/dryrun")


def load_cells(mesh: str = "pod", tag: str = "") -> List[Dict]:
    out = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        parts = r["cell"].split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if parts[2] != mesh or cell_tag != tag:
            continue
        out.append(r)
    return out


def fraction_of_roofline(r: Dict) -> float:
    """useful work time / achievable step time ~= MODEL_FLOPS/peak over
    max(term)."""
    terms = r["roofline"]
    bound = max(terms.values())
    useful = r["model_flops_per_device"] / 197e12
    return useful / bound if bound else 0.0


def table(rows: List[Dict]) -> str:
    hdr = ("| cell | compute_s | memory_s | collective_s | dominant | "
           "useful/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: fraction_of_roofline(r)):
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} × {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{fraction_of_roofline(r):.4f} |")
    return hdr + "\n".join(lines)


def optimized_table(base_rows: List[Dict]) -> str:
    """Baseline vs best tagged (optimized) variant per cell."""
    best: Dict[str, Dict] = {}
    for f in sorted(DRYRUN_DIR.glob("*__pod__*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        key = f"{r['arch']}__{r['shape']}"
        cur = best.get(key)
        if cur is None or (max(r["roofline"].values())
                           < max(cur["roofline"].values())):
            best[key] = r
    lines = ["| cell | dominant term: baseline → optimized | variant | "
             "roofline frac: baseline → optimized |",
             "|---|---|---|---|"]
    n = 0
    for b in base_rows:
        key = f"{b['arch']}__{b['shape']}"
        o = best.get(key)
        if o is None:
            continue
        bb, oo = max(b["roofline"].values()), max(o["roofline"].values())
        if oo >= bb * 0.99:
            continue
        tag = o["cell"].split("__")[-1]
        lines.append(
            f"| {key} | {bb:.2f} s → {oo:.2f} s ({bb/oo:.2f}×) | {tag} | "
            f"{fraction_of_roofline(b):.4f} → {fraction_of_roofline(o):.4f} |")
        n += 1
    if n == 0:
        return ""
    return ("\n\n## §Perf: baseline vs optimized cells\n\n"
            + "\n".join(lines))


def main():
    rows = load_cells("pod")
    if not rows:
        print("bench,us_per_call,derived")
        print("roofline,0,no_dryrun_results_yet")
        return
    md = "# Roofline (single-pod 16x16, per-device terms)\n\n" + table(rows)
    md += optimized_table(rows)
    mrows = load_cells("multipod")
    if mrows:
        md += ("\n\n## Multi-pod (2x16x16) compile proof — per-device "
               "terms\n\n" + table(mrows))
    Path("results").mkdir(exist_ok=True)
    Path("results/roofline.md").write_text(md + "\n")
    print("bench,us_per_call,derived")
    for r in rows:
        print(f"roofline_{r['arch']}__{r['shape']},0,"
              f"dom={r['dominant']};frac={fraction_of_roofline(r):.4f}")


if __name__ == "__main__":
    main()
