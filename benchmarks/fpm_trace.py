"""Tracing overhead + traced-run artifacts.

Two questions, one bench:

1. **Overhead** — the tracer's record path is one ``perf_counter``
   read, one tuple build, one ring-slot store, and the disabled path is
   a ``tracer is None`` identity test at every site. The contrast runs
   the same mushroom mine traced and untraced, interleaved best-of-N
   (single-shot wall-clocks drift ±30% on a busy box; round-robin
   spreads the drift evenly), and ``--smoke`` asserts the traced best
   stays within 5% of the untraced best (plus a small absolute slack so
   a sub-second run can't fail on scheduler jitter alone).

2. **Artifacts** — the traced batch run and a traced streaming
   ingest→refresh→serve round each write a Chrome trace-event JSON
   (``mine.trace.json`` / ``stream.trace.json``, loadable at
   https://ui.perfetto.dev) whose well-formedness (per-lane span
   nesting, one lane per worker with task spans) is asserted, so CI
   uploads a trace a human can actually open.

Emits ``BENCH_trace.json`` so the overhead trajectory is recorded.
Run ``--smoke`` for the CI-sized variant (~1 min).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.fpm import mine
from repro.core.streaming import PatternServer, StreamingMiner
from repro.core.tidlist import pack_database
from repro.data.transactions import load
from repro.obs import (Tracer, check_nesting, time_in_state,
                       write_chrome_trace)


def overhead(*, scale: int, support: float, n_workers: int,
             max_k: int, rounds: int, trace_dir: str) -> Dict:
    db, prof = load("mushroom", seed=0, scale=scale)
    bm, counts = pack_database(db, prof.n_dense_items,
                               return_counts=True)
    ms = max(1, int(support * len(db)))
    kw = dict(policy="clustered", n_workers=n_workers, max_k=max_k,
              granularity="bucket", item_counts=counts)
    # warm-up: backend selection + any jit compile happen once, off
    # the clock for both arms
    ref, _ = mine(bm, ms, **kw)
    best = {"untraced": float("inf"), "traced": float("inf")}
    last_tracer = None
    for _ in range(max(2, rounds)):
        res, m = mine(bm, ms, **kw)
        assert res == ref
        best["untraced"] = min(best["untraced"], m.wall_s)
        tr = Tracer()
        res, m = mine(bm, ms, **kw, trace=tr)
        assert res == ref, "tracing changed the mining result"
        if m.wall_s < best["traced"]:
            best["traced"] = m.wall_s
            last_tracer = tr
    path = os.path.join(trace_dir, "mine.trace.json")
    write_chrome_trace(last_tracer, path)
    _assert_trace_shape(last_tracer, n_workers)
    return {
        "bench": "trace_overhead", "dataset": "synth:mushroom",
        "scale": scale, "support": support, "n_workers": n_workers,
        "max_k": max_k, "rounds": rounds,
        "untraced_s": best["untraced"], "traced_s": best["traced"],
        "overhead": best["traced"] / max(best["untraced"], 1e-9) - 1.0,
        "events": len(last_tracer.events()),
        "dropped": last_tracer.dropped(),
        "trace_path": os.path.abspath(path),
    }


def _assert_trace_shape(tr: Tracer, n_workers: int) -> None:
    """The artifact must be worth opening: every worker has its own
    lane with task spans, sweeps/flushes were traced, and per-lane
    nesting is well formed."""
    bad = check_nesting(tr.events())
    assert not bad, f"malformed span nesting: {bad[:3]}"
    task_lanes = {e.lane for e in tr.events()
                  if e.ph == "X" and e.cat == "task"}
    workers = {n for n in tr.lane_names() if n.startswith("worker-")}
    assert len(workers) == n_workers, tr.lane_names()
    assert task_lanes >= workers, (task_lanes, workers)
    cats = {e.cat for e in tr.events() if e.ph == "X"}
    assert {"flush", "sweep", "level"} <= cats, cats
    for row in time_in_state(tr).values():
        if row["lane"].startswith("worker-"):
            # spans tile the worker loop: total within 5% of extent
            assert row["total"] >= 0.95 * row["extent"] - 0.002, row


def streaming_round(*, scale: int, n_workers: int, max_k: int,
                    trace_dir: str) -> Dict:
    db, prof = load("mushroom", seed=0, scale=scale)
    ms = max(1, int(0.2 * len(db)))
    cut = max(1, int(0.9 * len(db)))
    tr = Tracer()
    sm = StreamingMiner(prof.n_dense_items, ms, initial_db=db[:cut],
                        n_workers=n_workers, max_k=max_k, tracer=tr)
    try:
        sm.refresh()
        sm.ingest(db[cut:])
        lag_pending = sm.refresh_lag
        rep = sm.refresh()
        srv = PatternServer(sm)
        top = srv.top_k((), 5)
        srv.support_many([x for x, _ in top])
        lat = srv.latency_percentiles()
    finally:
        sm.close()
    path = os.path.join(trace_dir, "stream.trace.json")
    write_chrome_trace(tr, path)
    names = {e.name for e in tr.events() if e.ph == "X"}
    assert {"ingest", "refresh", "publish"} <= names, names
    assert not check_nesting(tr.events())
    assert lag_pending > 0.0 and sm.refresh_lag == 0.0
    return {
        "bench": "trace_streaming", "dataset": "synth:mushroom",
        "scale": scale, "generation": rep.generation,
        "refresh_wall_s": rep.wall_s,
        "lag_before_refresh_s": lag_pending,
        "events": len(tr.events()),
        "latency": lat,
        "trace_path": os.path.abspath(path),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~1 min) + overhead assertion")
    ap.add_argument("--rounds", type=int, default=5,
                    help="interleaved best-of-N rounds per arm")
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--scale", type=int, default=0,
                    help="dataset scale (0 = 2 smoke / 8 full)")
    ap.add_argument("--trace-dir", default=".",
                    help="where the .trace.json artifacts land")
    ap.add_argument("--out", default="BENCH_trace.json")
    args = ap.parse_args(argv)

    scale = args.scale or (2 if args.smoke else 8)
    os.makedirs(args.trace_dir, exist_ok=True)
    rows: List[Dict] = [
        overhead(scale=scale, support=0.15, n_workers=args.n_workers,
                 max_k=args.max_k, rounds=args.rounds,
                 trace_dir=args.trace_dir),
        streaming_round(scale=max(1, scale // 2),
                        n_workers=args.n_workers, max_k=args.max_k,
                        trace_dir=args.trace_dir),
    ]
    with open(args.out, "w") as f:
        json.dump({"bench": "fpm_trace", "smoke": args.smoke,
                   "results": rows}, f, indent=2)
    ov = rows[0]
    print("bench,us_per_call,derived")
    print(f"trace_overhead,{ov['traced_s'] * 1e6:.0f},"
          f"untraced={ov['untraced_s']:.3f}s;"
          f"overhead={ov['overhead']:+.1%};"
          f"events={ov['events']};dropped={ov['dropped']}")
    st = rows[1]
    print(f"trace_streaming,{st['refresh_wall_s'] * 1e6:.0f},"
          f"gen={st['generation']};events={st['events']};"
          f"lag_before_refresh={st['lag_before_refresh_s'] * 1e3:.1f}ms")
    if args.smoke:
        # the gate the tentpole promises: tracing costs < 5% (+0.05s
        # absolute slack so sub-second runs can't fail on scheduler
        # jitter alone)
        assert ov["traced_s"] <= 1.05 * ov["untraced_s"] + 0.05, (
            f"tracing overhead above budget: traced={ov['traced_s']:.3f}s "
            f"vs untraced={ov['untraced_s']:.3f}s "
            f"({ov['overhead']:+.1%})")
        print(f"# smoke overhead check passed: {ov['overhead']:+.1%} "
              f"(budget 5%)")
    print(f"# wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
