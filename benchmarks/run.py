"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

  fpm_policies     Fig. 1  (normalized runtimes, Cilk vs Clustered)
  fpm_granularity  bucket-sweep vs per-candidate tasks (smoke sizes)
  fpm_locality     Table 1 (locality metrics)
  fpm_scaling      worker scaling
  fpm_distributed  clustered vs round-robin placement on an 8-dev mesh
  fpm_streaming    ingest / incremental-refresh / serving latencies
  kernels_bench    kernel micro-benches + analytic TPU bounds
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (fpm_distributed, fpm_granularity, fpm_locality,
                        fpm_policies, fpm_scaling, fpm_streaming,
                        kernels_bench)

ALL = [
    ("fpm_policies", fpm_policies.main),
    ("fpm_granularity", lambda: fpm_granularity.main(["--smoke"])),
    ("fpm_locality", fpm_locality.main),
    ("fpm_scaling", fpm_scaling.main),
    ("fpm_distributed", fpm_distributed.main),
    ("fpm_streaming", lambda: fpm_streaming.main(["--smoke"])),
    ("kernels_bench", kernels_bench.main),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = []
    for name, fn in ALL:
        if only and name != only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()
    if failed:
        print(f"# failures: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
