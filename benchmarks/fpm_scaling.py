"""Worker-count scaling of the FPM engine (paper ran 8 threads/16 cores;
single-core container => measures scheduling overhead + work effects)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.fpm import mine, mine_serial
from repro.core.tidlist import pack_database
from repro.data.transactions import load
import time


def run(dataset: str = "mushroom", workers=(1, 2, 4, 8),
        max_k: int = 4) -> List[Dict]:
    db, prof = load(dataset, seed=0)
    n_items = (prof.n_dense_items if prof.kind == "dense"
               else prof.n_items)
    bm = pack_database(db, n_items)
    ms = max(1, int(prof.support * len(db)))
    t0 = time.time()
    mine_serial(bm, ms, max_k=max_k)
    serial_s = time.time() - t0
    rows = []
    for n in workers:
        # candidate granularity: efficiency is measured against the
        # per-candidate serial join, so the engine must do the same
        # per-task work (the bucket engine's A/B lives in
        # fpm_granularity.py)
        _, met = mine(bm, ms, policy="clustered", n_workers=n,
                      max_k=max_k, granularity="candidate")
        rows.append({"workers": n, "wall_s": met.wall_s,
                     "serial_s": serial_s,
                     "efficiency": serial_s / (met.wall_s * 1)})
    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        print(f"scaling_w{r['workers']},{r['wall_s'] * 1e6:.0f},"
              f"serial={r['serial_s']:.2f}s;eff={r['efficiency']:.2f}")


if __name__ == "__main__":
    main()
