"""Table 1 reproduction: locality metrics, Cilk-style vs Clustered.

PAPI IPC / dTLB counters -> this environment's observables:
  prefix-cache hit rate   (higher = better reuse; paper: fewer TLB misses)
  tasks per steal         (paper: bucket steals amortize contention)
  steals                  (paper: repeated stealing hurts Cilk)
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.fpm import mine
from repro.core.tidlist import pack_database
from repro.data.transactions import PROFILES, load

DATASETS = ["chess", "connect", "mushroom", "pumsb", "accidents",
            "t10i4", "t40i10", "kosarak"]

# single-core container: heavy profiles run at a raised support so the
# full table completes in minutes (documented in EXPERIMENTS.md §Paper)
SUPPORT_OVERRIDE = {"pumsb": 0.88, "t40i10": 0.04}


def run(datasets: List[str] = DATASETS, n_workers: int = 8,
        max_k: int = 4) -> List[Dict]:
    rows = []
    for name in datasets:
        db, prof = load(name, seed=0)
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        bm = pack_database(db, n_items)
        frac = SUPPORT_OVERRIDE.get(name, prof.support)
        ms = max(1, int(frac * len(db)))
        row = {"dataset": f"synth:{name}", "support": prof.support}
        for policy in ("cilk", "clustered"):
            # candidate granularity: the prefix-cache hit-rate gap IS
            # the Table-1 metric (bucket tasks touch each prefix once,
            # so the cache rate is ~0 for every policy)
            _, met = mine(bm, ms, policy=policy, n_workers=n_workers,
                          max_k=max_k, granularity="candidate")
            s = met.scheduler
            row[f"{policy}_cache_hit"] = met.cache_hit_rate
            row[f"{policy}_steals"] = int(s["steals"])
            row[f"{policy}_tasks_per_steal"] = s["tasks_per_steal"]
        rows.append(row)
    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        print(f"table1_{r['dataset']},0,"
              f"hit_cilk={r['cilk_cache_hit']:.3f};"
              f"hit_clu={r['clustered_cache_hit']:.3f};"
              f"tps_cilk={r['cilk_tasks_per_steal']:.2f};"
              f"tps_clu={r['clustered_tasks_per_steal']:.2f}")


if __name__ == "__main__":
    main()
