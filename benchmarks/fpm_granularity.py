"""A/B/C: task granularity — one task per candidate vs one task per
prefix-bucket (level-synchronous vectorized sweep) vs barrier-free
depth-first equivalence-class recursion with parent→child bitmap
handoff. Same policies, same supports; the contrast is wall-clock,
measured locality traffic (rows-touched / bytes-swept), prefix-cache
misses (the handoff makes the LRU cache vestigial: depth-first shows
cache_misses == 0), and the depth-first engine's retained-bitmap peak.

This is the shared-memory engine's version of the clustered-vs-round-
robin placement contrast in benchmarks/fpm_distributed.py: the bucket
engine turns the clustered policy's incidental cache locality into
structure, and the depth-first engine removes the remaining inter-level
barriers plus every prefix recomputation.

Emits ``BENCH_granularity.json`` so the perf trajectory is recorded.
Run ``--smoke`` for the CI-sized variant (~2 min).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.fpm import mine
from repro.core.tidlist import pack_database
from repro.data.transactions import load

#                 scale  support
SETUP = {
    "mushroom": (8, 0.15),
    "chess":    (64, 0.68),
    "retail":   (2, 0.012),
}
SMOKE_SETUP = {
    "mushroom": (2, 0.15),
    "chess":    (4, 0.72),
    "retail":   (1, 0.012),
}


def run(datasets: List[str], *, n_workers: int = 4, max_k: int = 5,
        policies=("clustered", "cilk"), backend: str = "auto",
        smoke: bool = False, repeats: int = 1) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    repeats = max(1, repeats)
    rows = []
    for name in datasets:
        scale, frac = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        bm = pack_database(db, n_items)
        ms = max(1, int(frac * len(db)))
        for policy in policies:
            rec: Dict = {"dataset": f"synth:{name}", "policy": policy,
                         "support": frac, "n_workers": n_workers,
                         "max_k": max_k, "backend": backend}
            counts = {}
            for gran in ("candidate", "bucket", "depth-first"):
                key = gran.replace("-", "_")
                best, met = float("inf"), None
                for _ in range(repeats):
                    res, m = mine(bm, ms, policy=policy,
                                  n_workers=n_workers, max_k=max_k,
                                  granularity=gran, backend=backend)
                    if m.wall_s < best:
                        # counters travel with the run that set the
                        # best wall-clock, never mixed across repeats
                        best, met = m.wall_s, m
                counts[gran] = res
                rec[f"{key}_s"] = best
                rec[f"{key}_rows_touched"] = met.rows_touched
                rec[f"{key}_bytes_swept"] = met.bytes_swept
                rec[f"{key}_tasks"] = int(met.scheduler["tasks_run"])
                rec[f"{key}_cache_misses"] = met.cache_misses
                rec["frequent"] = met.frequent
                if gran == "depth-first":
                    rec["depth_first_peak_retained_bitmaps"] = \
                        met.peak_retained_bitmaps
                    rec["depth_first_peak_bytes_retained"] = \
                        met.peak_bytes_retained
            assert (counts["candidate"] == counts["bucket"]
                    == counts["depth-first"]), \
                f"granularity mismatch on {name}/{policy}"
            rec["speedup"] = rec["candidate_s"] / max(rec["bucket_s"],
                                                      1e-9)
            rec["df_speedup"] = rec["bucket_s"] / max(
                rec["depth_first_s"], 1e-9)
            rows.append(rec)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized datasets (~2 min)")
    ap.add_argument("--datasets", nargs="*",
                    default=["mushroom", "chess", "retail"])
    ap.add_argument("--policies", nargs="*", default=["clustered", "cilk"])
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--repeats", type=int, default=1,
                    help="best-of-N wall-clock per granularity")
    ap.add_argument("--out", default="BENCH_granularity.json")
    args = ap.parse_args(argv)

    rows = run(args.datasets, n_workers=args.n_workers, max_k=args.max_k,
               policies=tuple(args.policies), backend=args.backend,
               smoke=args.smoke, repeats=args.repeats)
    payload = {
        "bench": "fpm_granularity",
        "smoke": args.smoke,
        "backend": args.backend,
        "results": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("bench,us_per_call,derived")
    for r in rows:
        print(f"granularity_{r['dataset']}_{r['policy']},"
              f"{r['bucket_s'] * 1e6:.0f},"
              f"speedup={r['speedup']:.2f}x;"
              f"df_speedup={r['df_speedup']:.2f}x;"
              f"df_cache_misses={r['depth_first_cache_misses']};"
              f"rows={r['bucket_rows_touched']}vs"
              f"{r['candidate_rows_touched']}")
    print(f"# wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
