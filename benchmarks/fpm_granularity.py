"""A/B/C: task granularity — one task per candidate vs one task per
prefix-bucket (level-synchronous vectorized sweep) vs barrier-free
depth-first equivalence-class recursion with parent→child bitmap
handoff. Same policies, same supports; the contrast is wall-clock,
measured locality traffic (rows-touched / bytes-swept), prefix-cache
misses (the handoff makes the LRU cache vestigial: depth-first shows
cache_misses == 0), and the depth-first engine's retained-bitmap peak.

This is the shared-memory engine's version of the clustered-vs-round-
robin placement contrast in benchmarks/fpm_distributed.py: the bucket
engine turns the clustered policy's incidental cache locality into
structure, and the depth-first engine removes the remaining inter-level
barriers plus every prefix recomputation.

Also measures the arena/dispatcher plumbing: per-run batch occupancy
(sweep requests per flush — asserted > 1 under --smoke so the
dispatcher cannot silently degrade to one-bucket launches) and a
repeated-sweep H2D contrast (device-resident arena: ~one initial
upload; host-only arena: the old per-sweep transfer bill). The
``mesh_granularity`` rows run the same engine over ``--mesh`` device
shards and record per-device dispatcher occupancy plus the
cross-device gauges (``d2d_bytes``, ``migrations``); --smoke asserts
depth-first keeps ``cache_misses == 0`` on the mesh.

The hybrid-representation rows contrast the depth-first engine under
``representation`` bitmap / sparse / auto on every dataset (plus each
dataset's measured ones-per-word density and the auto runs'
dense/sparse sweep split): sparse retail subtrees are where the
gather-intersect path wins, mushroom/chess stay all-bitmap, and
--smoke asserts auto never loses to the best single representation by
more than 10% (plus retail ``df_speedup > 1.0`` and mushroom staying
all-bitmap with no regression).

Emits ``BENCH_granularity.json`` so the perf trajectory is recorded.
Run ``--smoke`` for the CI-sized variant (~2 min).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.core.fpm import mesh_over_devices, mine
from repro.core.join_backend import SweepDispatcher, get_backend
from repro.core.tidlist import BitmapArena, pack_database
from repro.data.transactions import load

#                 scale  support
SETUP = {
    "mushroom": (8, 0.15),
    "chess":    (64, 0.68),
    "retail":   (2, 0.012),
}
SMOKE_SETUP = {
    "mushroom": (2, 0.15),
    "chess":    (4, 0.72),
    "retail":   (1, 0.012),
}


def run(datasets: List[str], *, n_workers: int = 4, max_k: int = 5,
        policies=("clustered", "cilk"), backend: str = "auto",
        arena: str = "auto", max_batch: int = 32,
        flush_us: float = 200.0, smoke: bool = False,
        repeats: int = 1) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    repeats = max(1, repeats)
    rows = []
    for name in datasets:
        scale, frac = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        bm, item_counts = pack_database(db, n_items,
                                        return_counts=True)
        ms = max(1, int(frac * len(db)))
        density = (float(item_counts.sum())
                   / max(bm.shape[0] * bm.shape[1], 1))
        for policy in policies:
            rec: Dict = {"dataset": f"synth:{name}", "policy": policy,
                         "support": frac, "n_workers": n_workers,
                         "max_k": max_k, "backend": backend,
                         "arena": arena, "max_batch": max_batch,
                         "flush_us": flush_us,
                         "density_ones_per_word": density}
            counts = {}
            for gran in ("candidate", "bucket", "depth-first"):
                # the depth-first rows carry the representation
                # contrast: auto (the primary row) vs forced bitmap
                # vs forced sparse — that's where diffset handoffs
                # change the engine's traffic
                reps = (("auto", "bitmap", "sparse")
                        if gran == "depth-first" else ("auto",))
                # interleaved min-of-N for every row under a timing
                # assertion (bucket + all depth-first reps):
                # single-shot wall-clocks drift ±30% on a busy box,
                # and back-to-back repeats of ONE config share any
                # slow phase — round-robin over the representations
                # spreads drift evenly so the auto-vs-forced contrast
                # is unbiased. Candidate is the slow reference row,
                # never asserted against — one shot is enough.
                rounds = (repeats if gran == "candidate"
                          else max(repeats, 2))
                timing = {rep: (float("inf"), None, None)
                          for rep in reps}
                for _ in range(rounds):
                    for rep in reps:
                        res, m = mine(bm, ms, policy=policy,
                                      n_workers=n_workers, max_k=max_k,
                                      granularity=gran, backend=backend,
                                      arena=arena, max_batch=max_batch,
                                      flush_us=flush_us,
                                      representation=rep,
                                      item_counts=item_counts)
                        if m.wall_s < timing[rep][0]:
                            # counters travel with the run that set the
                            # best wall-clock, never mixed across
                            # repeats
                            timing[rep] = (m.wall_s, m, res)
                for rep in reps:      # "auto" first: it seeds counts
                    key = gran.replace("-", "_") + (
                        "" if rep == "auto" else f"_{rep}")
                    best, met, res = timing[rep]
                    if rep != "auto":
                        assert res == counts["depth-first"], \
                            f"representation mismatch on {name}/{rep}"
                        rec[f"{key}_s"] = best
                        rec[f"{key}_sparse_sweeps"] = met.sparse_sweeps
                        continue
                    counts[gran] = res
                    rec[f"{key}_s"] = best
                    rec[f"{key}_rows_touched"] = met.rows_touched
                    rec[f"{key}_bytes_swept"] = met.bytes_swept
                    rec[f"{key}_tasks"] = int(
                        met.scheduler["tasks_run"])
                    rec[f"{key}_cache_misses"] = met.cache_misses
                    rec[f"{key}_flushes"] = met.flushes
                    rec[f"{key}_batch_occupancy"] = met.batch_occupancy
                    rec[f"{key}_h2d_bytes"] = met.h2d_bytes
                    rec[f"{key}_sparse_sweeps"] = met.sparse_sweeps
                    rec[f"{key}_dense_sweeps"] = met.dense_sweeps
                    rec[f"{key}_sparse_bytes_swept"] = \
                        met.sparse_bytes_swept
                    rec["frequent"] = met.frequent
                    if gran == "depth-first":
                        rec["depth_first_peak_retained_bitmaps"] = \
                            met.peak_retained_bitmaps
                        rec["depth_first_peak_bytes_retained"] = \
                            met.peak_bytes_retained
                        rec["depth_first_rep_picks"] = met.rep_picks
                        rec["depth_first_sparse_rows"] = met.sparse_rows
            assert (counts["candidate"] == counts["bucket"]
                    == counts["depth-first"]), \
                f"granularity mismatch on {name}/{policy}"
            rec["speedup"] = rec["candidate_s"] / max(rec["bucket_s"],
                                                      1e-9)
            rec["df_speedup"] = rec["bucket_s"] / max(
                rec["depth_first_s"], 1e-9)
            # auto vs the best single forced representation
            rec["rep_speedup"] = rec["depth_first_bitmap_s"] / max(
                rec["depth_first_s"], 1e-9)
            rows.append(rec)
    return rows


def mesh_granularity(n_shards: int = 2, *, n_workers: int = 4,
                     max_k: int = 4, smoke: bool = False) -> List[Dict]:
    """The unified engine on a mesh: every granularity distributed over
    ``n_shards`` device shards (real jax devices when the host exposes
    enough — e.g. under --xla_force_host_platform_device_count —
    logical shards otherwise). Emits per-device dispatcher occupancy
    and the cross-device traffic gauges (``d2d_bytes``,
    ``migrations``) so the trajectory records the mesh path, and shows
    depth-first keeping its structural ``cache_misses == 0`` on the
    mesh."""
    mesh = mesh_over_devices(n_shards) or n_shards
    mesh_kind = "logical" if isinstance(mesh, int) else "jax"
    db, prof = load("mushroom", seed=0, scale=1 if smoke else 4)
    bm = pack_database(db, prof.n_dense_items)
    ms = max(1, int(0.18 * len(db)))
    out = []
    for gran in ("bucket", "candidate", "depth-first"):
        # on a real jax mesh, run the batched sweeps through the
        # interpreted kernel so the per-shard DEVICE mirrors (and their
        # d2d fetch path) are actually exercised — numpy would reduce
        # the row to logical-shard bookkeeping. Candidate stays on
        # numpy: per-candidate interpreted launches cost minutes and
        # the dispatcher routing under test is identical.
        backend = ("pallas-interpret"
                   if mesh_kind == "jax" and gran != "candidate"
                   else "numpy")
        res, met = mine(bm, ms, policy="clustered", n_workers=n_workers,
                        max_k=max_k, granularity=gran, mesh=mesh,
                        backend=backend)
        out.append({
            "bench": "mesh_granularity", "granularity": gran,
            "mesh_kind": mesh_kind, "backend": backend,
            "n_devices": met.n_devices,
            "wall_s": met.wall_s, "frequent": met.frequent,
            "rows_touched": met.rows_touched,
            "cache_misses": met.cache_misses,
            "d2d_bytes": met.d2d_bytes,
            "migrations": met.migrations,
            "batch_occupancy": met.batch_occupancy,
            "per_device": met.per_device,
        })
    return out


def repeat_sweep_h2d(repeats: int = 5, n_txn: int = 400,
                     n_buckets: int = 24, n_exts: int = 16) -> List[Dict]:
    """Repeated-sweep H2D contrast, the tentpole's whole point.

    The same ``n_buckets`` sweeps are submitted ``repeats`` times
    through one pallas-interpret dispatcher. With a device-resident
    arena ("jax") the bitmaps cross host→device exactly once — the
    initial arena upload — no matter how often they are swept; with a
    host-only arena ("numpy", the old path's behaviour) every batch
    re-uploads its gathered payload. Both rows land in the JSON so the
    trajectory records the drop."""
    db, prof = load("mushroom", seed=0)
    bm = pack_database(db[:n_txn], prof.n_dense_items)
    n_items = bm.shape[0]
    out = []
    for backing in ("jax", "numpy"):
        arena = BitmapArena.from_bitmaps(bm, backing=backing)
        disp = SweepDispatcher(arena, get_backend("pallas-interpret"),
                               n_clients=n_buckets)
        sweep_rows = 0
        try:
            for _ in range(repeats):
                futs = [disp.submit(p, tuple(range(p + 1,
                                                   p + 1 + n_exts)))
                        for p in range(n_buckets)]
                for f in futs:
                    f.result()
                sweep_rows += n_buckets * (1 + n_exts)
        finally:
            disp.stop()
        naive = sweep_rows * bm.shape[1] * 4    # old path: re-upload all
        out.append({"bench": "repeat_sweep_h2d", "arena": backing,
                    "repeats": repeats, "n_buckets": n_buckets,
                    "n_exts": n_exts, "n_items": n_items,
                    "arena_bytes": arena.nbytes_base,
                    "h2d_bytes": arena.h2d_bytes,
                    "naive_h2d_bytes": naive,
                    "batch_occupancy": disp.batch_occupancy})
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized datasets (~2 min)")
    ap.add_argument("--datasets", nargs="*",
                    default=["mushroom", "chess", "retail"])
    ap.add_argument("--policies", nargs="*", default=["clustered", "cilk"])
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--arena", default="auto",
                    choices=["auto", "numpy", "jax"])
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--flush-us", type=float, default=200.0)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--repeats", type=int, default=1,
                    help="best-of-N wall-clock per granularity")
    ap.add_argument("--mesh", type=int, default=2,
                    help="device shards for the mesh_granularity rows "
                         "(real jax devices when available, logical "
                         "shards otherwise)")
    ap.add_argument("--out", default="BENCH_granularity.json")
    args = ap.parse_args(argv)

    rows = run(args.datasets, n_workers=args.n_workers, max_k=args.max_k,
               policies=tuple(args.policies), backend=args.backend,
               arena=args.arena, max_batch=args.max_batch,
               flush_us=args.flush_us, smoke=args.smoke,
               repeats=args.repeats)
    h2d_rows = repeat_sweep_h2d()
    # --mesh 0/1 follows the launcher/quickstart convention: no mesh
    # rows, shared-memory results only
    mesh_rows = (mesh_granularity(args.mesh, n_workers=args.n_workers,
                                  smoke=args.smoke)
                 if args.mesh > 1 else [])
    payload = {
        "bench": "fpm_granularity",
        "smoke": args.smoke,
        "backend": args.backend,
        "arena": args.arena,
        "results": rows,
        "repeat_sweep_h2d": h2d_rows,
        "mesh_granularity": mesh_rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("bench,us_per_call,derived")
    for r in rows:
        print(f"granularity_{r['dataset']}_{r['policy']},"
              f"{r['bucket_s'] * 1e6:.0f},"
              f"speedup={r['speedup']:.2f}x;"
              f"df_speedup={r['df_speedup']:.2f}x;"
              f"df_cache_misses={r['depth_first_cache_misses']};"
              f"batch_occ={r['bucket_batch_occupancy']:.2f};"
              f"rows={r['bucket_rows_touched']}vs"
              f"{r['candidate_rows_touched']};"
              f"density={r['density_ones_per_word']:.2f};"
              f"df_rep=auto:{r['depth_first_s']:.2f}s/"
              f"bm:{r['depth_first_bitmap_s']:.2f}s/"
              f"sp:{r['depth_first_sparse_s']:.2f}s;"
              f"df_sparse_sweeps={r['depth_first_sparse_sweeps']}")
    for h in h2d_rows:
        print(f"repeat_sweep_h2d_arena={h['arena']},,"
              f"h2d={h['h2d_bytes']}B;naive={h['naive_h2d_bytes']}B;"
              f"arena={h['arena_bytes']}B;"
              f"occ={h['batch_occupancy']:.2f}")
    for m in mesh_rows:
        occ = "/".join(f"{d['batch_occupancy']:.2f}"
                       for d in m["per_device"])
        print(f"mesh_{m['granularity']}_{m['n_devices']}dev"
              f"({m['mesh_kind']}),{m['wall_s'] * 1e6:.0f},"
              f"d2d={m['d2d_bytes']}B;migrations={m['migrations']};"
              f"dev_occ={occ};cache_misses={m['cache_misses']}")
    if args.smoke:
        # the dispatcher must actually coalesce: mean occupancy of the
        # batched granularities stays above one request per launch
        occs = [r[f"{g}_batch_occupancy"] for r in rows
                for g in ("bucket", "depth_first")]
        mean_occ = sum(occs) / len(occs)
        assert mean_occ > 1.0, (
            f"dispatcher degraded to one-bucket batches: mean "
            f"batch_occupancy {mean_occ:.2f} (per-run: {occs})")
        print(f"# smoke occupancy check passed: mean={mean_occ:.2f}")
        # device-resident arena: repeated sweeps cost ~one initial
        # upload (indices excluded from the gauge), not one per sweep
        dev = next(h for h in h2d_rows if h["arena"] == "jax")
        assert dev["h2d_bytes"] <= 1.05 * dev["arena_bytes"], dev
        assert dev["h2d_bytes"] < 0.1 * dev["naive_h2d_bytes"], dev
        print("# smoke h2d check passed: "
              f"{dev['h2d_bytes']}B ~= one arena upload "
              f"({dev['arena_bytes']}B) vs naive {dev['naive_h2d_bytes']}B")
        # hybrid representation: auto must track the best single
        # representation (≤10% + scheduling jitter slack) everywhere,
        # beat bucket on sparse retail, and keep dense mushroom
        # all-bitmap with no regression against forced-bitmap
        slack = 0.15
        for r in rows:
            best_single = min(r["depth_first_bitmap_s"],
                              r["depth_first_sparse_s"])
            assert r["depth_first_s"] <= 1.10 * best_single + slack, (
                f"auto representation lost >10% to the best single "
                f"representation on {r['dataset']}/{r['policy']}: "
                f"auto={r['depth_first_s']:.3f}s vs "
                f"best={best_single:.3f}s")
        retail = [r for r in rows if r["dataset"] == "synth:retail"]
        if retail:
            best_df = max(r["df_speedup"] for r in retail)
            assert best_df > 1.0, (
                f"retail depth-first (hybrid) no longer beats bucket: "
                f"df_speedup={best_df:.2f}")
            assert all(r["depth_first_sparse_sweeps"] > 0
                       for r in retail), "retail never went sparse"
            print(f"# smoke retail check passed: df_speedup="
                  f"{best_df:.2f} (sparse sweeps="
                  f"{retail[0]['depth_first_sparse_sweeps']})")
        shroom = [r for r in rows if r["dataset"] == "synth:mushroom"]
        for r in shroom:
            assert r["depth_first_sparse_sweeps"] == 0, (
                f"mushroom went sparse under auto: "
                f"{r['depth_first_sparse_sweeps']} sparse sweeps")
            assert r["depth_first_s"] <= (1.05 * r["depth_first_bitmap_s"]
                                          + slack), (
                f"mushroom auto regressed vs forced bitmap: "
                f"{r['depth_first_s']:.3f}s vs "
                f"{r['depth_first_bitmap_s']:.3f}s")
        if shroom:
            print("# smoke mushroom check passed: all-bitmap, "
                  f"auto={shroom[0]['depth_first_s']:.2f}s vs "
                  f"bitmap={shroom[0]['depth_first_bitmap_s']:.2f}s")
        if mesh_rows:
            # the mesh path keeps depth-first's structural invariant:
            # the handoff replaces the prefix cache even across shards
            df = next(m for m in mesh_rows
                      if m["granularity"] == "depth-first")
            assert df["cache_misses"] == 0, df
            assert len(df["per_device"]) == df["n_devices"] >= 2, df
            print(f"# smoke mesh check passed: depth-first on "
                  f"{df['n_devices']} shards, cache_misses=0, "
                  f"d2d={df['d2d_bytes']}B")
    print(f"# wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
