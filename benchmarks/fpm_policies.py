"""Fig. 1 reproduction: normalized runtimes, Cilk-style vs Clustered.

The paper runs 8 threads on 16 cores; this container has 1 core, so the
wall-time contrast here comes from the *work reduction* the clustered
policy's locality buys (prefix-intersection reuse), not thread scaling —
the same mechanism the paper measures via dTLB misses/IPC. Runtimes are
averaged over repeats and normalized Cilk=1.0, like Fig. 1.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.fpm import mine
from repro.core.tidlist import pack_database
from repro.data.transactions import PROFILES, load

DATASETS = ["chess", "connect", "mushroom", "pumsb", "accidents",
            "t10i4", "t40i10", "kosarak"]

# The paper's datasets have 10^5..10^6 transactions, putting the per-task
# TID-join well above scheduling overhead. The profiles are scaled into
# that regime here (supports tuned so each dataset mines in ~5-60 s on
# this single-core container); EXPERIMENTS.md §Paper documents this.
BENCH_SETUP = {
    #            scale  support
    "chess":      (128, 0.68),
    "connect":    (128, 0.85),
    "mushroom":   (128, 0.15),
    "pumsb":      (64,  0.90),
    "accidents":  (64,  0.35),
    "t10i4":      (32,  0.005),
    "t40i10":     (16,  0.04),
    "kosarak":    (32,  0.006),
}


def run(datasets: List[str] = DATASETS, n_workers: int = 4,
        repeats: int = 1, max_k: int = 5,
        granularity: str = "candidate") -> List[Dict]:
    """``granularity="candidate"`` reproduces the paper's per-itemset
    tasks (Fig. 1's setting — the cache hit-rate gap is the story);
    ``"bucket"`` runs the same policy contrast on the vectorized
    bucket-sweep engine (see benchmarks/fpm_granularity.py for the
    granularity A/B itself)."""
    rows = []
    for name in datasets:
        scale, frac = BENCH_SETUP[name]
        db, prof = load(name, seed=0, scale=scale)
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        bm = pack_database(db, n_items)
        ms = max(1, int(frac * len(db)))
        times = {}
        metrics = {}
        for policy in ("cilk", "clustered"):
            best = []
            for r in range(repeats):
                res, met = mine(bm, ms, policy=policy,
                                n_workers=n_workers, max_k=max_k,
                                granularity=granularity)
                best.append(met.wall_s)
                metrics[policy] = met
            times[policy] = sum(best) / len(best)
        rows.append({
            "dataset": f"synth:{name}",
            "support": frac,
            "granularity": granularity,
            "cilk_s": times["cilk"],
            "clustered_s": times["clustered"],
            "normalized_clustered": times["clustered"] / times["cilk"],
            "speedup": times["cilk"] / times["clustered"],
            "itemsets": metrics["clustered"].frequent,
            "rows_touched": metrics["clustered"].rows_touched,
        })
    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        print(f"fig1_{r['dataset']},{r['clustered_s'] * 1e6:.0f},"
              f"norm={r['normalized_clustered']:.3f};"
              f"speedup={r['speedup']:.2f}x")


if __name__ == "__main__":
    main()
