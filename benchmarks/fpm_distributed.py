"""Distributed FPM: clustered vs round-robin placement through the
`mine_distributed` compat shim (both now run the unified mesh engine —
sharded arena, per-device dispatchers, device-affine workers).

Spawns an 8-device subprocess (the bench process itself must keep seeing
1 device). Reports rows-touched (HBM-locality proxy), cross-device
d2d bytes, and wall time.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap


CODE = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro.data.transactions import load
from repro.core.tidlist import pack_database
from repro.core.distributed_fpm import mine_distributed
db, p = load('mushroom', seed=0)
db = db[:2000]
bm = pack_database(db, p.n_dense_items)
ms = int(p.support * len(db))
mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
out = {}
for pol in ['clustered', 'round_robin']:
    t0 = time.time()
    res, stats = mine_distributed(bm, ms, mesh, policy=pol, max_k=5)
    out[pol] = {'wall_s': time.time() - t0, 'found': len(res), **stats}
print(json.dumps(out))
"""


def run():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",   # skip TPU probing in the child
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(CODE)],
                       capture_output=True, text=True, timeout=560,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main():
    print("bench,us_per_call,derived")
    out = run()
    for pol, v in out.items():
        print(f"dist_fpm_{pol},{v['wall_s'] * 1e6:.0f},"
              f"rows_touched={v['rows_touched']};found={v['found']};"
              f"d2d={v['d2d_bytes']}B;migrations={v['migrations']}")
    ratio = (out["round_robin"]["rows_touched"]
             / max(out["clustered"]["rows_touched"], 1))
    print(f"dist_fpm_locality,0,rows_ratio_rr_over_clustered={ratio:.2f}")


if __name__ == "__main__":
    main()
