"""Kernel micro-benchmarks (jnp reference path on CPU; Pallas numbers are
structural — interpret mode is not a perf proxy, so we benchmark the
jnp oracle and report the kernel's analytic VMEM/roofline terms)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmap_join.ref import bitmap_join_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_gram.ref import masked_gram_ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def timeit(fn, *args, repeats=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # bitmap_join: E=4096 extensions x W=4096 words (0.5M transactions)
    prefix = jnp.asarray(rng.integers(0, 2 ** 32, 4096, dtype=np.uint32))
    exts = jnp.asarray(rng.integers(0, 2 ** 32, (4096, 4096),
                                    dtype=np.uint32))
    f = jax.jit(bitmap_join_ref)
    dt = timeit(f, prefix, exts)
    bytes_moved = exts.nbytes + prefix.nbytes
    rows.append({"name": "bitmap_join_4096x4096", "wall_s": dt,
                 "tpu_mem_bound_s": bytes_moved / HBM_BW})

    # masked_gram: 512 items x 8192 transactions
    a = jnp.asarray((rng.random((512, 8192)) < 0.4), jnp.bfloat16)
    mask = jnp.asarray((rng.random(8192) < 0.5), jnp.bfloat16)
    f = jax.jit(masked_gram_ref)
    dt = timeit(f, a, mask)
    flops = 2 * 512 * 512 * 8192
    rows.append({"name": "masked_gram_512x8192", "wall_s": dt,
                 "tpu_compute_bound_s": flops / PEAK_FLOPS})

    # flash attention: BH=8, S=2048, D=128
    q = jnp.asarray(rng.standard_normal((8, 2048, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((8, 2048, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((8, 2048, 128)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    dt = timeit(f, q, k, v, repeats=3)
    flops = 4 * 8 * 2048 * 2048 * 128
    rows.append({"name": "flash_attention_8x2048x128", "wall_s": dt,
                 "tpu_compute_bound_s": flops / PEAK_FLOPS})
    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        extra = {k: v for k, v in r.items() if k not in ("name", "wall_s")}
        ds = ";".join(f"{k}={v:.3e}" for k, v in extra.items())
        print(f"{r['name']},{r['wall_s'] * 1e6:.0f},{ds}")


if __name__ == "__main__":
    main()
