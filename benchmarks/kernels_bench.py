"""Kernel micro-benchmarks (jnp reference path on CPU; Pallas numbers are
structural — interpret mode is not a perf proxy, so we benchmark the
jnp oracle and report the kernel's analytic VMEM/roofline terms)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitmap_join.ref import bitmap_join_ref

HBM_BW = 819e9


def timeit(fn, *args, repeats=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # bitmap_join: E=4096 extensions x W=4096 words (0.5M transactions)
    prefix = jnp.asarray(rng.integers(0, 2 ** 32, 4096, dtype=np.uint32))
    exts = jnp.asarray(rng.integers(0, 2 ** 32, (4096, 4096),
                                    dtype=np.uint32))
    f = jax.jit(bitmap_join_ref)
    dt = timeit(f, prefix, exts)
    bytes_moved = exts.nbytes + prefix.nbytes
    rows.append({"name": "bitmap_join_4096x4096", "wall_s": dt,
                 "tpu_mem_bound_s": bytes_moved / HBM_BW})

    return rows


def main():
    print("bench,us_per_call,derived")
    for r in run():
        extra = {k: v for k, v in r.items() if k not in ("name", "wall_s")}
        ds = ";".join(f"{k}={v:.3e}" for k, v in extra.items())
        print(f"{r['name']},{r['wall_s'] * 1e6:.0f},{ds}")


if __name__ == "__main__":
    main()
