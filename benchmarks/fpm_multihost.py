"""Multi-host mining benchmark: transaction-axis partitioning over the
loopback cluster, cross-host steal-as-migration, and the mesh
data-parallel rows (one distributed benchmark entry point).

Rows:

  scaling      ``mine()`` vs ``mine_cluster(hosts=N)`` on the same
               packed database. The headline is AGGREGATE SWEEP
               CAPACITY, bytes processed per second of the busiest
               host's sweep+eval time — the number that scales with
               hosts even when the bench machine itself has one core
               (the loopback hosts interleave on it, so wall-clock
               cannot show the scaling but busy-time attribution can):

                   capacity(1) = bytes_swept / sweep_s
                   capacity(N) = sum_h (bytes_h + eval_bytes_h)
                                       / (sweep_s_h + eval_s_h)

               Busy-time attribution jitters with thread interleaving
               on a shared-core runner, so every configuration runs
               best-of-``REPS`` and the asserted ratio is the best
               rep. ``net_bytes`` bills every descriptor flush + count
               reply that crossed (loopback: would have crossed) the
               interconnect; the single-host row must bill ZERO.
  steal        ``owner_fn`` pins every bucket on host 0, so hosts 1+
               are idle unless cross-host steal-as-migration fires;
               the row records ``cross_steals`` and the migrated
               prefix-slice bytes in ``steal_net``.
  mesh8        the legacy distributed rows, ported off the
               ``mine_distributed`` compat shim onto ``mine(mesh=...)``
               directly: an 8-virtual-device subprocess compares
               clustered vs round-robin placement by rows-touched
               (HBM-locality proxy), d2d bytes and migrations.

``--smoke`` (CI) shrinks the datasets and asserts the acceptance
invariants: cluster results bit-match single-host ``mine()``, 2-host
aggregate capacity >= 1.5x one host, ``net_bytes`` > 0 only when a
reduction or steal actually occurred (and == 0 single-host), and the
forced-steal row migrates at least one bucket.

Emits ``BENCH_multihost.json``.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import textwrap
import time
from typing import Dict, List

import numpy as np

from repro.core.cluster import mine_cluster
from repro.core.fpm import mine
from repro.core.tidlist import pack_database
from repro.data.transactions import load

#            scale  support  max_k
SETUP = {"mushroom": (16, 0.20, 5)}
SMOKE_SETUP = {"mushroom": (16, 0.22, 4)}
# best-of-N: busy-time attribution on a shared-core box jitters with
# thread interleaving, so each configuration runs N times and the row
# keeps the best ratio alongside every rep's capacities
REPS = 5
# steal-as-migration is a race the idle host must win before the
# victim drains its queue; retry the forced-steal row until it lands
STEAL_TRIES = 5


def _sweep_s(met) -> float:
    return sum(float(r.get("sweep_s", 0.0)) for r in met.per_device)


def _cluster_capacity(met) -> float:
    """Aggregate capacity: each host's slice-scan throughput (local
    sweeps + the peer evaluations attributed to its slice), summed."""
    return sum((h["bytes_swept"] + h["eval_bytes"])
               / max(h["sweep_s"] + h["eval_s"], 1e-9)
               for h in met.per_host)


def run_scaling(datasets: List[str], *, hosts: List[int],
                smoke: bool = False) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    rows: List[Dict] = []
    for name in datasets:
        scale, frac, max_k = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        bm = pack_database(db, n_items)
        ms = max(1, int(frac * len(db)))
        mine(bm, ms, granularity="bucket", n_workers=1,
             max_k=max_k)    # warm the backend outside the timings
        base = {"dataset": f"synth:{name}", "n_tx": len(db),
                "n_items": n_items, "n_words": int(bm.shape[1]),
                "min_support": ms, "max_k": max_k, "reps": REPS,
                "mode": "scaling"}

        caps1: List[float] = []
        wall1 = 0.0
        ref = None
        met1 = None
        for _ in range(REPS):
            t0 = time.time()
            ref, met1 = mine(bm, ms, granularity="bucket",
                             n_workers=1, max_k=max_k)
            wall1 = time.time() - t0
            caps1.append(met1.bytes_swept / max(_sweep_s(met1), 1e-9))
        rows.append({**base, "hosts": 1, "wall_s": wall1,
                     "frequent": len(ref),
                     "bytes_swept": met1.bytes_swept,
                     "capacity_Bps": max(caps1),
                     "capacity_Bps_reps": caps1,
                     "net_bytes": met1.net_bytes,
                     "steal_net": met1.steal_net})
        print(f"{name:10s} hosts=1 wall={wall1:6.2f}s "
              f"capacity={max(caps1) / 1e6:8.1f} MB/s "
              f"net={met1.net_bytes}B")
        if smoke:
            assert met1.net_bytes == 0 and met1.steal_net == 0, (
                "a single-host mine must bill zero interconnect bytes")

        for n in hosts:
            ratios: List[float] = []
            capsn: List[float] = []
            wall = 0.0
            met = None
            for r in range(REPS):
                t0 = time.time()
                res, met = mine_cluster(bm, ms, hosts=n,
                                        granularity="bucket",
                                        n_workers=1, max_k=max_k)
                wall = time.time() - t0
                capsn.append(_cluster_capacity(met))
                ratios.append(capsn[-1] / caps1[r])
                assert res == ref, (
                    f"{name} hosts={n}: cluster mine must bit-match "
                    "the single-host result")
            ratio = max(ratios)
            rows.append({**base, "hosts": n, "wall_s": wall,
                         "frequent": len(ref),
                         "bytes_swept": met.bytes_swept,
                         "capacity_Bps": max(capsn),
                         "capacity_Bps_reps": capsn,
                         "capacity_ratio_vs_1": ratio,
                         "capacity_ratio_reps": ratios,
                         "net_bytes": met.net_bytes,
                         "steal_net": met.steal_net,
                         "cross_steals": met.cross_steals,
                         "per_host": met.per_host})
            print(f"{name:10s} hosts={n} wall={wall:6.2f}s "
                  f"capacity={max(capsn) / 1e6:8.1f} MB/s "
                  f"(x{ratio:.2f} vs 1 host) net={met.net_bytes}B "
                  f"steal_net={met.steal_net}B "
                  f"steals={met.cross_steals}")
            if smoke:
                assert met.net_bytes > 0, (
                    "a multi-host mine reduces every flush — net_bytes "
                    "cannot be zero")
                if n == 2:
                    assert ratio >= 1.5, (
                        "2-host aggregate sweep capacity must reach "
                        f">= 1.5x one host, got {ratio:.2f}x")
    return rows


def run_steal(*, n_workers: int = 4, smoke: bool = False) -> Dict:
    """Every bucket pinned on host 0: host 1 has no owned work, so any
    progress it shows is steal-as-migration (whole buckets, billed at
    the victim's prefix-row slice width)."""
    rng = np.random.default_rng(0)
    n_tx = 16000 if smoke else 40000
    bm = pack_database(
        [sorted(rng.choice(24, size=int(rng.integers(3, 9)),
                           replace=False).tolist())
         for _ in range(n_tx)], 24)
    ms = int(0.05 * n_tx)
    ref, _ = mine(bm, ms, granularity="bucket", n_workers=n_workers,
                  max_k=4)
    # the idle host only migrates work if it wakes before the victim
    # drains its queue — a race on a shared-core box, so retry
    for attempt in range(STEAL_TRIES):
        t0 = time.time()
        res, met = mine_cluster(bm, ms, hosts=2, granularity="bucket",
                                n_workers=n_workers, max_k=4,
                                owner_fn=lambda key: 0)
        assert res == ref, "forced-steal run must bit-match"
        if met.cross_steals > 0:
            break
    row = {"mode": "steal", "n_tx": n_tx, "n_words": int(bm.shape[1]),
           "min_support": ms, "wall_s": time.time() - t0,
           "frequent": len(res), "cross_steals": met.cross_steals,
           "steal_net": met.steal_net, "net_bytes": met.net_bytes,
           "attempts": attempt + 1}
    print(f"steal      hosts=2 (all buckets pinned on host 0) "
          f"cross_steals={met.cross_steals} "
          f"steal_net={met.steal_net}B attempts={attempt + 1}")
    if smoke:
        assert met.cross_steals > 0 and met.steal_net > 0, (
            "with every bucket pinned remotely the idle host must "
            "migrate work")
    return row


MESH_CODE = """
import json, time
import jax, numpy as np
from jax.sharding import Mesh
from repro.data.transactions import load
from repro.core.tidlist import pack_database
from repro.core.fpm import mine
db, p = load('mushroom', seed=0)
db = db[:{cap}]
bm = pack_database(db, p.n_dense_items)
ms = int(p.support * len(db))
mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
# the legacy shim's placements, spelled directly on the unified engine:
# clustered = bucket tasks + prefix cache; round_robin = scattered
# FIFO placement at candidate granularity, no cache
placements = {{'clustered': ('clustered', 'bucket', 32),
              'round_robin': ('fifo', 'candidate', 0)}}
out = {{}}
for name, (pol, gran, cache) in placements.items():
    t0 = time.time()
    res, met = mine(bm, ms, mesh=mesh, policy=pol, granularity=gran,
                    cache_size=cache, max_k={max_k})
    out[name] = {{'wall_s': time.time() - t0, 'found': len(res),
                 'rows_touched': met.rows_touched,
                 'd2d_bytes': met.d2d_bytes,
                 'migrations': met.migrations}}
print(json.dumps(out))
"""


def run_mesh(*, smoke: bool = False) -> List[Dict]:
    """The legacy 8-virtual-device rows on ``mine(mesh=...)``: the
    bench process must keep seeing one device, so the mesh run lives
    in a subprocess."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",   # skip TPU probing in the child
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    code = MESH_CODE.format(cap=1200 if smoke else 2000,
                            max_k=4 if smoke else 5)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560,
                       env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    out = json.loads(r.stdout.strip().splitlines()[-1])
    rows = [{"mode": "mesh8", "policy": pol, **v}
            for pol, v in out.items()]
    ratio = (out["round_robin"]["rows_touched"]
             / max(out["clustered"]["rows_touched"], 1))
    rows.append({"mode": "mesh8_locality",
                 "rows_ratio_rr_over_clustered": ratio})
    for pol, v in out.items():
        print(f"mesh8      {pol:11s} wall={v['wall_s']:6.2f}s "
              f"rows={v['rows_touched']} d2d={v['d2d_bytes']}B "
              f"migrations={v['migrations']}")
    print(f"mesh8      locality rows_ratio_rr_over_clustered="
          f"{ratio:.2f}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["mushroom"],
                    choices=list(SETUP))
    ap.add_argument("--hosts", type=int, nargs="+", default=[2, 3])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized datasets + acceptance assertions")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the 8-virtual-device subprocess rows")
    ap.add_argument("--out", default="BENCH_multihost.json")
    args = ap.parse_args(argv)
    rows = run_scaling(args.datasets, hosts=args.hosts,
                       smoke=args.smoke)
    rows.append(run_steal(n_workers=args.workers, smoke=args.smoke))
    if not args.no_mesh:
        rows += run_mesh(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump({"bench": "fpm_multihost", "smoke": args.smoke,
                   "rows": rows}, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
