"""Streaming subsystem benchmark: ingest throughput, incremental
refresh vs full re-mine, and query latency while a refresh is mining.

Scenario per dataset: mine an initial database (generation 1), ingest a
small batch (the "small-delta" production shape: a trickle of new
transactions against a large corpus), then

  ingest      wall-clock + transactions/s + the device upload the
              segment append billed (with eager backing this is
              EXACTLY the new segment's payload bytes — the
              ``ingest_h2d`` row records both so the invariant is
              visible in the JSON);
  refresh     incremental re-mine wall / rows_touched / bytes_swept
              plus the delta-plan split (reused / delta-swept /
              fully-swept candidates), against a from-scratch
              ``fpm.mine`` of the concatenated database at the same
              granularity — ``refresh_speedup`` and ``rows_ratio``
              are the headline columns;
  serving     p50/p95 query latency against the PatternServer while
              the refresh is actively mining (queries answer from the
              previous published generation and never block) and at
              idle, plus the count of mid-refresh queries served.

``--smoke`` (CI) shrinks the datasets and asserts the acceptance
invariants: incremental refresh touches fewer rows AND finishes
faster (``refresh_speedup > 1.0``) than the full re-mine on the
small-delta scenario, ingest h2d equals the new segment's bytes, and
segment compaction keeps the arena's segment count bounded across
repeated ingest/refresh cycles.

Emits ``BENCH_streaming.json``.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.fpm import mine
from repro.core.streaming import PatternServer, StreamingMiner
from repro.core.tidlist import pack_database
from repro.data.transactions import load

#            scale  support  batch_tx  slice (0 = whole db)
SETUP = {
    "retail":   (4, 0.012, 400, 0),
    "mushroom": (8, 0.15, 600, 0),
}
SMOKE_SETUP = {
    "retail":   (1, 0.012, 50, 6000),
    "mushroom": (1, 0.16, 60, 4000),
}
# The fewer-rows acceptance invariant holds on the SPARSE long-tail
# profile (the "small-delta scenario": a small batch touches few of
# the 1200 items, so most equivalence classes stay clean). The dense
# profiles are the recorded adversarial contrast: a few dozen dense
# transactions contain nearly every item, everything is dirty, and
# incremental ≈ full — the JSON shows it rather than hiding it.
ASSERT_ROWS = {"retail"}


def _percentiles(lat_us: List[float]) -> Dict[str, float]:
    if not lat_us:
        return {"p50_us": 0.0, "p95_us": 0.0}
    a = np.asarray(lat_us)
    return {"p50_us": float(np.percentile(a, 50)),
            "p95_us": float(np.percentile(a, 95))}


def _query_loop(server: PatternServer, probes, stop: threading.Event,
                lat_us: List[float], gens: set) -> None:
    i = 0
    while not stop.is_set():
        itemset = probes[i % len(probes)]
        t0 = time.perf_counter_ns()
        server.support(itemset)
        server.top_k(itemset[:1], 5)
        lat_us.append((time.perf_counter_ns() - t0) / 1e3 / 2)
        gens.add(server.snapshot.generation)
        i += 1
        # ~1 kHz query load: a pure-Python spin here would hog the GIL
        # and starve the numpy workers it is supposed to race
        stop.wait(0.001)


def run(datasets: List[str], *, n_workers: int = 4, max_k: int = 5,
        granularity: str = "bucket", policy: str = "clustered",
        smoke: bool = False) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    rows: List[Dict] = []
    for name in datasets:
        scale, frac, batch_tx, cap = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        if cap:
            db = db[:cap]
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        init, batch = db[:-batch_tx], db[-batch_tx:]
        ms = max(1, int(frac * len(db)))
        rec: Dict = {"dataset": f"synth:{name}", "n_initial": len(init),
                     "batch_tx": batch_tx, "min_support": ms,
                     "granularity": granularity, "policy": policy,
                     "n_workers": n_workers, "max_k": max_k}

        sm = StreamingMiner(n_items, ms, initial_db=init,
                            granularity=granularity, policy=policy,
                            n_workers=n_workers, max_k=max_k)
        r1 = sm.refresh()
        rec["gen1_wall_s"] = r1.wall_s
        rec["gen1_rows_touched"] = r1.rows_touched
        server = PatternServer(sm)
        probes = [x for x, _ in sm.snapshot.top_k((), 32)] or [(0,)]

        # idle serving baseline
        idle_lat: List[float] = []
        stop = threading.Event()
        t = threading.Thread(target=_query_loop,
                             args=(server, probes, stop, idle_lat,
                                   set()))
        t.start()
        time.sleep(0.25)
        stop.set()
        t.join()
        rec["query_idle"] = _percentiles(idle_lat)

        # ingest
        t0 = time.time()
        ing = sm.ingest(batch)
        rec["ingest_wall_s"] = time.time() - t0
        rec["ingest_tx_per_s"] = batch_tx / max(rec["ingest_wall_s"],
                                                1e-9)
        rec["ingest_payload_bytes"] = ing.payload_bytes

        # refresh with a live query load
        ref_lat: List[float] = []
        gens: set = set()
        stop = threading.Event()
        t = threading.Thread(target=_query_loop,
                             args=(server, probes, stop, ref_lat, gens))
        t.start()
        rep = sm.refresh()
        stop.set()
        t.join()
        rec["refresh_wall_s"] = rep.wall_s
        rec["refresh_rows_touched"] = rep.rows_touched
        rec["refresh_bytes_swept"] = rep.bytes_swept
        rec["dirty_items"] = rep.dirty_items
        rec["reused"] = rep.reused
        rec["swept_delta"] = rep.swept_delta
        rec["swept_full"] = rep.swept_full
        rec["born"] = rep.born
        rec["died"] = rep.died
        rec["query_during_refresh"] = _percentiles(ref_lat)
        rec["queries_during_refresh"] = len(ref_lat)
        rec["generations_seen_during_refresh"] = sorted(gens)
        rec["compacted_segments"] = rep.compacted_segments
        rec["compaction_bytes"] = rep.compaction_bytes
        # requests per dispatcher flush DURING the refresh: the delta
        # path must coalesce its tuple-prefix sweeps into wide bursts,
        # not trickle per-candidate launches at occupancy ~1
        rec["refresh_batch_occupancy"] = rep.metrics.batch_occupancy

        # from-scratch baseline on the concatenated database
        bm = pack_database(db, n_items)
        t0 = time.time()
        full_res, full_met = mine(bm, ms, granularity=granularity,
                                  policy=policy, n_workers=n_workers,
                                  max_k=max_k)
        rec["full_wall_s"] = time.time() - t0
        rec["full_rows_touched"] = full_met.rows_touched
        rec["full_bytes_swept"] = full_met.bytes_swept
        rec["full_batch_occupancy"] = full_met.batch_occupancy
        rec["refresh_speedup"] = rec["full_wall_s"] / max(
            rec["refresh_wall_s"], 1e-9)
        rec["rows_ratio"] = rec["refresh_rows_touched"] / max(
            rec["full_rows_touched"], 1)
        assert dict(sm.snapshot.supports) == full_res, name

        # eager-device ingest: h2d == the new segment's bytes (the
        # billing happens at add_segment, so the default sweep backend
        # keeps this variant cheap)
        sm2 = StreamingMiner(n_items, ms,
                             initial_db=init[:len(init) // 4],
                             arena="jax", n_workers=2, max_k=3)
        sm2.refresh()
        ing2 = sm2.ingest(batch)
        rec["ingest_h2d"] = {"h2d_bytes": ing2.h2d_bytes,
                             "segment_payload_bytes": ing2.payload_bytes,
                             "arena_total_bytes":
                                 sm2.arena.n_base * sm2.arena.n_words
                                 * 4}

        # sustained ingest/refresh cycles: segment compaction must keep
        # the arena's segment count bounded (without it every cycle
        # leaves one more narrow segment, and delta sweeps degrade into
        # per-segment launch trickles)
        n_cycles = 6
        chunk = max(1, batch_tx // 4)
        cyc_walls: List[float] = []
        cyc_compacted = 0
        cyc_bytes = 0
        for c in range(n_cycles):
            sm.ingest([db[(c * chunk + j) % len(db)]
                       for j in range(chunk)])
            r = sm.refresh()
            cyc_walls.append(r.wall_s)
            cyc_compacted += r.compacted_segments
            cyc_bytes += r.compaction_bytes
        rec["cycles"] = {"n": n_cycles, "batch_tx": chunk,
                         "refresh_wall_s": cyc_walls,
                         "compacted_segments": cyc_compacted,
                         "compaction_bytes": cyc_bytes,
                         "final_segments": sm.arena.n_segments}
        rows.append(rec)

        print(f"{name:10s} ingest {rec['ingest_tx_per_s']:9.0f} tx/s | "
              f"refresh {rec['refresh_wall_s']:6.3f}s "
              f"rows {rec['refresh_rows_touched']:8d} "
              f"(full {rec['full_rows_touched']:8d}, "
              f"ratio {rec['rows_ratio']:.3f}) | "
              f"reused {rec['reused']} delta {rec['swept_delta']} "
              f"full {rec['swept_full']} | "
              f"q_p50 {rec['query_during_refresh']['p50_us']:.0f}us "
              f"({rec['queries_during_refresh']} during refresh)")

        if smoke:
            if name in ASSERT_ROWS:
                assert rec["refresh_rows_touched"] < \
                    rec["full_rows_touched"], (
                        "incremental refresh must touch fewer rows "
                        "than a full re-mine on the small-delta "
                        "scenario")
                assert rec["refresh_bytes_swept"] < \
                    rec["full_bytes_swept"]
                assert rec["refresh_speedup"] > 1.0, (
                    "incremental refresh must beat the full re-mine "
                    "wall clock on the small-delta scenario, got "
                    f"{rec['refresh_speedup']:.3f}")
            assert rec["cycles"]["final_segments"] <= 3, (
                "segment compaction must bound the arena's segment "
                f"count, got {rec['cycles']['final_segments']}")
            assert rec["cycles"]["compacted_segments"] > 0
            h = rec["ingest_h2d"]
            assert h["h2d_bytes"] == h["segment_payload_bytes"], \
                "ingest must upload exactly the new segment"
            assert h["h2d_bytes"] < h["arena_total_bytes"]
            assert rec["queries_during_refresh"] > 0
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["retail",
                                                      "mushroom"],
                    choices=list(SETUP))
    ap.add_argument("--granularity", default="bucket",
                    choices=["bucket", "candidate", "depth-first"])
    ap.add_argument("--policy", default="clustered")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized datasets + acceptance assertions")
    ap.add_argument("--out", default="BENCH_streaming.json")
    args = ap.parse_args(argv)
    rows = run(args.datasets, n_workers=args.workers, max_k=args.max_k,
               granularity=args.granularity, policy=args.policy,
               smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump({"bench": "fpm_streaming", "smoke": args.smoke,
                   "rows": rows}, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
