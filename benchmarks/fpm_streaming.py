"""Streaming subsystem benchmark: ingest throughput, incremental
refresh vs full re-mine, and query latency while a refresh is mining.

Scenario per dataset: mine an initial database (generation 1), ingest a
small batch (the "small-delta" production shape: a trickle of new
transactions against a large corpus), then

  ingest      wall-clock + transactions/s + the device upload the
              segment append billed (with eager backing this is
              EXACTLY the new segment's payload bytes — the
              ``ingest_h2d`` row records both so the invariant is
              visible in the JSON);
  refresh     incremental re-mine wall / rows_touched / bytes_swept
              plus the delta-plan split (reused / delta-swept /
              fully-swept candidates), against a from-scratch
              ``fpm.mine`` of the concatenated database at the same
              granularity — ``refresh_speedup`` and ``rows_ratio``
              are the headline columns;
  serving     p50/p95 query latency against the PatternServer while
              the refresh is actively mining (queries answer from the
              previous published generation and never block) and at
              idle, plus the count of mid-refresh queries served.

``--storm`` adds the production-rate serving scenario on top: a
steady mix of known-hit lookups, batched UNKNOWN-itemset sweeps
(every probe is longer than ``max_k``, so it can never be answered
from the published store on first touch and must ride the sweep
dispatchers), and top-k ranking queries — first at idle, then
concurrently with ingest/refresh cycles. Each kind records
p50/p95/p99, and the dispatcher queue gauges are read around the
quiet and storm refresh windows so the JSON shows that query bursts
RAISE mean flush occupancy rather than trickling occupancy-1 flushes
between the candidate sweeps.

``--smoke`` (CI) shrinks the datasets and asserts the acceptance
invariants: incremental refresh touches fewer rows AND finishes
faster (``refresh_speedup > 1.0``) than the full re-mine on the
small-delta scenario, ingest h2d equals the new segment's bytes, and
segment compaction keeps the arena's segment count bounded across
repeated ingest/refresh cycles. With ``--storm`` it additionally
asserts that unknown-itemset answers equal brute force, that the
known-hit p99 under a concurrent refresh stays within 5x the idle
p99 (with a small absolute floor so micro-latency jitter on busy CI
runners cannot flake the gate), and that storm flush occupancy beats
the quiet baseline.

Emits ``BENCH_streaming.json``.
"""
from __future__ import annotations

import argparse
import itertools
import json
import threading
import time
from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.core.fpm import mine
from repro.core.streaming import PatternServer, StreamingMiner
from repro.core.tidlist import pack_database
from repro.data.transactions import load

#            scale  support  batch_tx  slice (0 = whole db)
SETUP = {
    "retail":   (4, 0.012, 400, 0),
    "mushroom": (8, 0.15, 600, 0),
}
SMOKE_SETUP = {
    "retail":   (1, 0.012, 50, 6000),
    "mushroom": (1, 0.16, 60, 4000),
}
# The fewer-rows acceptance invariant holds on the SPARSE long-tail
# profile (the "small-delta scenario": a small batch touches few of
# the 1200 items, so most equivalence classes stay clean). The dense
# profiles are the recorded adversarial contrast: a few dozen dense
# transactions contain nearly every item, everything is dirty, and
# incremental ≈ full — the JSON shows it rather than hiding it.
ASSERT_ROWS = {"retail"}


def _percentiles(lat_us: List[float]) -> Dict[str, float]:
    if not lat_us:
        return {"p50_us": 0.0, "p95_us": 0.0, "p99_us": 0.0, "n": 0}
    a = np.asarray(lat_us)
    return {"p50_us": float(np.percentile(a, 50)),
            "p95_us": float(np.percentile(a, 95)),
            "p99_us": float(np.percentile(a, 99)),
            "n": len(lat_us)}


def _query_loop(server: PatternServer, probes, stop: threading.Event,
                lat_us: List[float], gens: set) -> None:
    i = 0
    while not stop.is_set():
        itemset = probes[i % len(probes)]
        t0 = time.perf_counter_ns()
        server.support(itemset)
        server.top_k(itemset[:1], 5)
        lat_us.append((time.perf_counter_ns() - t0) / 1e3 / 2)
        gens.add(server.snapshot.generation)
        i += 1
        # ~1 kHz query load: a pure-Python spin here would hog the GIL
        # and starve the numpy workers it is supposed to race
        stop.wait(0.001)


def run(datasets: List[str], *, n_workers: int = 4, max_k: int = 5,
        granularity: str = "bucket", policy: str = "clustered",
        smoke: bool = False) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    rows: List[Dict] = []
    for name in datasets:
        scale, frac, batch_tx, cap = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        if cap:
            db = db[:cap]
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        init, batch = db[:-batch_tx], db[-batch_tx:]
        ms = max(1, int(frac * len(db)))
        rec: Dict = {"dataset": f"synth:{name}", "n_initial": len(init),
                     "batch_tx": batch_tx, "min_support": ms,
                     "granularity": granularity, "policy": policy,
                     "n_workers": n_workers, "max_k": max_k}

        sm = StreamingMiner(n_items, ms, initial_db=init,
                            granularity=granularity, policy=policy,
                            n_workers=n_workers, max_k=max_k)
        r1 = sm.refresh()
        rec["gen1_wall_s"] = r1.wall_s
        rec["gen1_rows_touched"] = r1.rows_touched
        server = PatternServer(sm)
        probes = [x for x, _ in sm.snapshot.top_k((), 32)] or [(0,)]

        # idle serving baseline
        idle_lat: List[float] = []
        stop = threading.Event()
        t = threading.Thread(target=_query_loop,
                             args=(server, probes, stop, idle_lat,
                                   set()))
        t.start()
        time.sleep(0.25)
        stop.set()
        t.join()
        rec["query_idle"] = _percentiles(idle_lat)

        # ingest
        t0 = time.time()
        ing = sm.ingest(batch)
        rec["ingest_wall_s"] = time.time() - t0
        rec["ingest_tx_per_s"] = batch_tx / max(rec["ingest_wall_s"],
                                                1e-9)
        rec["ingest_payload_bytes"] = ing.payload_bytes

        # refresh with a live query load
        ref_lat: List[float] = []
        gens: set = set()
        stop = threading.Event()
        t = threading.Thread(target=_query_loop,
                             args=(server, probes, stop, ref_lat, gens))
        t.start()
        rep = sm.refresh()
        stop.set()
        t.join()
        rec["refresh_wall_s"] = rep.wall_s
        rec["refresh_rows_touched"] = rep.rows_touched
        rec["refresh_bytes_swept"] = rep.bytes_swept
        rec["dirty_items"] = rep.dirty_items
        rec["reused"] = rep.reused
        rec["swept_delta"] = rep.swept_delta
        rec["swept_full"] = rep.swept_full
        rec["born"] = rep.born
        rec["died"] = rep.died
        rec["query_during_refresh"] = _percentiles(ref_lat)
        rec["queries_during_refresh"] = len(ref_lat)
        rec["generations_seen_during_refresh"] = sorted(gens)
        rec["compacted_segments"] = rep.compacted_segments
        rec["compaction_bytes"] = rep.compaction_bytes
        # requests per dispatcher flush DURING the refresh: the delta
        # path must coalesce its tuple-prefix sweeps into wide bursts,
        # not trickle per-candidate launches at occupancy ~1
        rec["refresh_batch_occupancy"] = rep.metrics.batch_occupancy

        # from-scratch baseline on the concatenated database
        bm = pack_database(db, n_items)
        t0 = time.time()
        full_res, full_met = mine(bm, ms, granularity=granularity,
                                  policy=policy, n_workers=n_workers,
                                  max_k=max_k)
        rec["full_wall_s"] = time.time() - t0
        rec["full_rows_touched"] = full_met.rows_touched
        rec["full_bytes_swept"] = full_met.bytes_swept
        rec["full_batch_occupancy"] = full_met.batch_occupancy
        rec["refresh_speedup"] = rec["full_wall_s"] / max(
            rec["refresh_wall_s"], 1e-9)
        rec["rows_ratio"] = rec["refresh_rows_touched"] / max(
            rec["full_rows_touched"], 1)
        assert dict(sm.snapshot.supports) == full_res, name

        # eager-device ingest: h2d == the new segment's bytes (the
        # billing happens at add_segment, so the default sweep backend
        # keeps this variant cheap)
        sm2 = StreamingMiner(n_items, ms,
                             initial_db=init[:len(init) // 4],
                             arena="jax", n_workers=2, max_k=3)
        sm2.refresh()
        ing2 = sm2.ingest(batch)
        rec["ingest_h2d"] = {"h2d_bytes": ing2.h2d_bytes,
                             "segment_payload_bytes": ing2.payload_bytes,
                             "arena_total_bytes":
                                 sm2.arena.n_base * sm2.arena.n_words
                                 * 4}

        # sustained ingest/refresh cycles: segment compaction must keep
        # the arena's segment count bounded (without it every cycle
        # leaves one more narrow segment, and delta sweeps degrade into
        # per-segment launch trickles)
        n_cycles = 6
        chunk = max(1, batch_tx // 4)
        cyc_walls: List[float] = []
        cyc_compacted = 0
        cyc_bytes = 0
        for c in range(n_cycles):
            sm.ingest([db[(c * chunk + j) % len(db)]
                       for j in range(chunk)])
            r = sm.refresh()
            cyc_walls.append(r.wall_s)
            cyc_compacted += r.compacted_segments
            cyc_bytes += r.compaction_bytes
        rec["cycles"] = {"n": n_cycles, "batch_tx": chunk,
                         "refresh_wall_s": cyc_walls,
                         "compacted_segments": cyc_compacted,
                         "compaction_bytes": cyc_bytes,
                         "final_segments": sm.arena.n_segments}
        rows.append(rec)

        print(f"{name:10s} ingest {rec['ingest_tx_per_s']:9.0f} tx/s | "
              f"refresh {rec['refresh_wall_s']:6.3f}s "
              f"rows {rec['refresh_rows_touched']:8d} "
              f"(full {rec['full_rows_touched']:8d}, "
              f"ratio {rec['rows_ratio']:.3f}) | "
              f"reused {rec['reused']} delta {rec['swept_delta']} "
              f"full {rec['swept_full']} | "
              f"q_p50 {rec['query_during_refresh']['p50_us']:.0f}us "
              f"({rec['queries_during_refresh']} during refresh)")

        if smoke:
            if name in ASSERT_ROWS:
                assert rec["refresh_rows_touched"] < \
                    rec["full_rows_touched"], (
                        "incremental refresh must touch fewer rows "
                        "than a full re-mine on the small-delta "
                        "scenario")
                assert rec["refresh_bytes_swept"] < \
                    rec["full_bytes_swept"]
                assert rec["refresh_speedup"] > 1.0, (
                    "incremental refresh must beat the full re-mine "
                    "wall clock on the small-delta scenario, got "
                    f"{rec['refresh_speedup']:.3f}")
            assert rec["cycles"]["final_segments"] <= 3, (
                "segment compaction must bound the arena's segment "
                f"count, got {rec['cycles']['final_segments']}")
            assert rec["cycles"]["compacted_segments"] > 0
            h = rec["ingest_h2d"]
            assert h["h2d_bytes"] == h["segment_payload_bytes"], \
                "ingest must upload exactly the new segment"
            assert h["h2d_bytes"] < h["arena_total_bytes"]
            assert rec["queries_during_refresh"] > 0
        sm.close()
        sm2.close()
    return rows


def _brute_support(db: List[List[int]], itemset: Tuple[int, ...]) -> int:
    want = set(itemset)
    return sum(1 for t in db if want <= set(t))


def _fresh_probes(n_items: int, min_len: int) -> Iterator[Tuple[int, ...]]:
    """An endless supply of NEVER-REPEATED itemsets, all longer than
    ``max_k`` — each can be answered from the published store at most
    once (after its own backfill), so the sweep load stays real."""
    return itertools.chain.from_iterable(
        itertools.combinations(range(n_items), k)
        for k in range(min_len, n_items + 1))


def _exactness_probes(db: List[List[int]], probes: Iterator,
                      min_len: int, n: int) -> List[Tuple[int, ...]]:
    """Unknown itemsets with teeth: mostly sub-itemsets of real
    transactions (support >= 1, so a broken sweep cannot hide behind
    all-zero answers), padded with lexicographic probes."""
    out: List[Tuple[int, ...]] = []
    seen = set()
    for t in db:
        if len(t) >= min_len:
            x = tuple(sorted(set(t)))[:min_len]
            if len(x) == min_len and x not in seen:
                seen.add(x)
                out.append(x)
        if len(out) >= n - 8:
            break
    for x in itertools.islice(probes, n - len(out)):
        out.append(x)
    return out


def _queue_gauges(runtime) -> Tuple[int, int]:
    st = [d.stats() for d in runtime.dispatchers]
    return (sum(s["queue_flushes"] for s in st),
            sum(s["queue_requests"] for s in st))


def _storm_threads(server: PatternServer, hot: List[Tuple[int, ...]],
                   probes: Iterator, sweep_batch: int):
    """Three query loops — known-hit, unknown-sweep (batched), top-k —
    each recording its own latency series. The sweep series is
    per-itemset amortized (batch wall / batch size), which is the
    number a serving SLO is written against."""
    stop = threading.Event()
    lats: Dict[str, List[float]] = {"hit": [], "sweep": [], "top_k": []}

    def hit_loop() -> None:
        i = 0
        while not stop.is_set():
            x = hot[i % len(hot)]
            t0 = time.perf_counter_ns()
            server.support(x)
            lats["hit"].append((time.perf_counter_ns() - t0) / 1e3)
            i += 1
            stop.wait(0.001)

    def sweep_loop() -> None:
        while not stop.is_set():
            xs = list(itertools.islice(probes, sweep_batch))
            if not xs:
                break
            t0 = time.perf_counter_ns()
            server.support_many(xs)
            lats["sweep"].append(
                (time.perf_counter_ns() - t0) / 1e3 / len(xs))
            stop.wait(0.002)

    def topk_loop() -> None:
        i = 0
        while not stop.is_set():
            x = hot[i % len(hot)]
            t0 = time.perf_counter_ns()
            server.top_k(x[:1], 5)
            lats["top_k"].append((time.perf_counter_ns() - t0) / 1e3)
            i += 1
            stop.wait(0.001)

    threads = [threading.Thread(target=f, daemon=True)
               for f in (hit_loop, sweep_loop, topk_loop)]
    return stop, lats, threads


def _serve_for(server: PatternServer, hot: List[Tuple[int, ...]],
               probes: Iterator, sweep_batch: int,
               seconds: float) -> Dict[str, List[float]]:
    """Idle serving: the same three query kinds, single-threaded and
    unopposed, for the baseline percentile row."""
    out: Dict[str, List[float]] = {"hit": [], "sweep": [], "top_k": []}
    i = 0
    end = time.monotonic() + seconds
    while time.monotonic() < end:
        x = hot[i % len(hot)]
        t0 = time.perf_counter_ns()
        server.support(x)
        out["hit"].append((time.perf_counter_ns() - t0) / 1e3)
        t0 = time.perf_counter_ns()
        server.top_k(x[:1], 5)
        out["top_k"].append((time.perf_counter_ns() - t0) / 1e3)
        xs = list(itertools.islice(probes, sweep_batch))
        t0 = time.perf_counter_ns()
        server.support_many(xs)
        out["sweep"].append(
            (time.perf_counter_ns() - t0) / 1e3 / max(len(xs), 1))
        i += 1
        time.sleep(0.001)
    return out


def run_storm(datasets: List[str], *, n_workers: int = 4, max_k: int = 5,
              granularity: str = "bucket", policy: str = "clustered",
              smoke: bool = False) -> List[Dict]:
    setup = SMOKE_SETUP if smoke else SETUP
    rows: List[Dict] = []
    n_cycles = 3
    sweep_batch = 16
    for name in datasets:
        scale, frac, batch_tx, cap = setup[name]
        db, prof = load(name, seed=0, scale=scale)
        if cap:
            db = db[:cap]
        n_items = (prof.n_dense_items if prof.kind == "dense"
                   else prof.n_items)
        hold = batch_tx * 2 * n_cycles
        init = db[:-hold]
        base = len(init)
        chunks = [db[base + c * batch_tx: base + (c + 1) * batch_tx]
                  for c in range(2 * n_cycles)]
        ms = max(1, int(frac * len(db)))
        rec: Dict = {"dataset": f"synth:{name}", "mode": "storm",
                     "n_initial": base, "batch_tx": batch_tx,
                     "min_support": ms, "granularity": granularity,
                     "policy": policy, "n_workers": n_workers,
                     "max_k": max_k, "sweep_batch": sweep_batch,
                     "n_cycles": n_cycles}

        sm = StreamingMiner(n_items, ms, initial_db=init,
                            granularity=granularity, policy=policy,
                            n_workers=n_workers, max_k=max_k)
        sm.refresh()
        server = PatternServer(sm)
        probes = _fresh_probes(n_items, max_k + 1)
        hot = [x for x, _ in sm.snapshot.top_k((), 32)] or [(0,)]
        server.top_k((), 5)  # build the ranking index outside timings

        # exactness: batched unknown-itemset sweeps vs brute force over
        # the transactions the published generation covers
        sample = _exactness_probes(init, probes, max_k + 1, 32)
        got = server.support_many(sample)
        want = [_brute_support(init, x) for x in sample]
        rec["exact_queries_checked"] = len(sample)
        rec["exact_nonzero_answers"] = sum(1 for s in got if s > 0)
        assert got == want, (
            "unknown-itemset sweep answers must equal brute force: "
            f"{[(x, g, w) for x, g, w in zip(sample, got, want) if g != w][:4]}")
        rec["exact_ok"] = True

        # idle percentiles per query kind
        rec["query_idle"] = {
            k: _percentiles(v)
            for k, v in _serve_for(server, hot, probes, sweep_batch,
                                   0.35).items()}

        rt = sm.runtime
        # quiet cycles: ingest/refresh with no query traffic -> the
        # baseline mean flush occupancy on the dispatcher queues
        qf0, qr0 = _queue_gauges(rt)
        quiet_walls: List[float] = []
        for c in range(n_cycles):
            sm.ingest(chunks[c])
            quiet_walls.append(sm.refresh().wall_s)
        qf1, qr1 = _queue_gauges(rt)
        rec["quiet_queue_flushes"] = qf1 - qf0
        rec["queue_occupancy_quiet"] = (
            (qr1 - qr0) / (qf1 - qf0) if qf1 > qf0 else 0.0)
        rec["refresh_wall_quiet_s"] = quiet_walls

        # storm cycles: the same ingest/refresh cadence with all three
        # query loops running against it the whole time
        stop, lats, threads = _storm_threads(server, hot, probes,
                                             sweep_batch)
        qf0, qr0 = _queue_gauges(rt)
        for t in threads:
            t.start()
        storm_walls: List[float] = []
        for c in range(n_cycles, 2 * n_cycles):
            sm.ingest(chunks[c])
            storm_walls.append(sm.refresh().wall_s)
        time.sleep(0.15)  # let a few more pure-query bursts land
        stop.set()
        for t in threads:
            t.join()
        qf1, qr1 = _queue_gauges(rt)
        rec["storm_queue_flushes"] = qf1 - qf0
        rec["queue_occupancy_storm"] = (
            (qr1 - qr0) / (qf1 - qf0) if qf1 > qf0 else 0.0)
        rec["refresh_wall_storm_s"] = storm_walls
        rec["query_storm"] = {k: _percentiles(v)
                              for k, v in lats.items()}
        rec["query_sweeps"] = sm.query_sweeps
        rec["query_sweep_bytes"] = sm.query_sweep_bytes
        rec["served"] = server.merged_stats()
        sm.close()
        rows.append(rec)

        qi, qs = rec["query_idle"], rec["query_storm"]
        print(f"{name:10s} storm | hit p99 {qi['hit']['p99_us']:7.0f}"
              f" -> {qs['hit']['p99_us']:7.0f}us | "
              f"sweep p99 {qi['sweep']['p99_us']:7.0f}"
              f" -> {qs['sweep']['p99_us']:7.0f}us | "
              f"top_k p99 {qi['top_k']['p99_us']:7.0f}"
              f" -> {qs['top_k']['p99_us']:7.0f}us | "
              f"occ {rec['queue_occupancy_quiet']:.2f}"
              f" -> {rec['queue_occupancy_storm']:.2f}")

        if smoke:
            assert rec["exact_ok"]
            assert rec["exact_nonzero_answers"] > 0, (
                "exactness sample must include itemsets with nonzero "
                "support, or the check has no teeth")
            idle_p99 = rec["query_idle"]["hit"]["p99_us"]
            storm_p99 = rec["query_storm"]["hit"]["p99_us"]
            # the p99 target: known-hit latency under a concurrent
            # refresh within 5x idle; the absolute floor absorbs
            # scheduler jitter on busy CI runners where idle p99 is a
            # handful of microseconds
            assert storm_p99 <= max(5 * idle_p99, 5000.0), (
                f"hit p99 under refresh {storm_p99:.0f}us breaches 5x "
                f"idle p99 {idle_p99:.0f}us")
            assert rec["query_storm"]["sweep"]["n"] > 0
            assert rec["query_storm"]["top_k"]["n"] > 0
            assert rec["queue_occupancy_storm"] > \
                rec["queue_occupancy_quiet"], (
                    "query bursts must RAISE mean flush occupancy, got "
                    f"{rec['queue_occupancy_storm']:.2f} storm vs "
                    f"{rec['queue_occupancy_quiet']:.2f} quiet")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["retail",
                                                      "mushroom"],
                    choices=list(SETUP))
    ap.add_argument("--granularity", default="bucket",
                    choices=["bucket", "candidate", "depth-first"])
    ap.add_argument("--policy", default="clustered")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized datasets + acceptance assertions")
    ap.add_argument("--storm", action="store_true",
                    help="add the production-rate serving rows "
                         "(per-kind p50/p95/p99, occupancy contrast)")
    ap.add_argument("--out", default="BENCH_streaming.json")
    args = ap.parse_args(argv)
    rows = run(args.datasets, n_workers=args.workers, max_k=args.max_k,
               granularity=args.granularity, policy=args.policy,
               smoke=args.smoke)
    if args.storm:
        rows += run_storm(args.datasets, n_workers=args.workers,
                          max_k=args.max_k,
                          granularity=args.granularity,
                          policy=args.policy, smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump({"bench": "fpm_streaming", "smoke": args.smoke,
                   "storm": args.storm, "rows": rows}, f, indent=2,
                  sort_keys=True)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
