"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps with checkpointing + fault injection + recovery.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; pass --tiny for a CI-speed run.)
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.data.lm_pipeline import make_batch_iter
from repro.models.registry import build_model
from repro.optim import adamw
from repro.runtime.fault import FaultInjector, run_with_recovery


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    if args.tiny:
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                          vocab_size=1024, tie_embeddings=True)
        args.steps = min(args.steps, 60)
    else:
        # ~100M params: 12L x 768d (GPT-2-small-ish, swiglu)
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=2048, vocab_size=32768,
                          tie_embeddings=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params; "
          f"{args.steps} steps @ {args.batch}x{args.seq}")

    ocfg = OptimizerConfig(lr=6e-4, warmup_steps=args.steps // 10,
                           total_steps=args.steps)
    opt_state = adamw.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        upd, opt_state, metrics = adamw.update(ocfg, grads, opt_state,
                                               params)
        return (adamw.apply_updates(params, upd), opt_state,
                dict(metrics, loss=loss))

    batch_iter = make_batch_iter(cfg.vocab_size, args.batch, args.seq)
    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"  step {step:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)

    # inject a fault at 40% of the run to demo checkpoint recovery
    inj = FaultInjector(fail_at=[int(args.steps * 0.4)])
    (params, opt_state), report = run_with_recovery(
        step_fn=train_step, init_state=(params, opt_state),
        batch_iter=batch_iter, n_steps=args.steps,
        ckpt_dir="results/example_ckpt", ckpt_every=25,
        fault_injector=inj, on_metrics=on_metrics)

    print(f"\nrecovered from {report.restarts} injected fault(s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
