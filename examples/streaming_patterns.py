"""Streaming quickstart: ingest batches, refresh incrementally, serve
queries between (and during) refreshes.

    PYTHONPATH=src python examples/streaming_patterns.py
"""
from repro.core.streaming import PatternServer, StreamingMiner
from repro.data.transactions import load


def main():
    db, prof = load("retail", seed=0)
    init, stream = db[:10000], db[10000:]

    # fraction-based threshold: it rises as the database grows, so the
    # frequent border moves both ways (births AND deaths)
    miner = StreamingMiner(prof.n_items, prof.support, initial_db=init,
                           n_workers=4, max_k=5)
    server = PatternServer(miner)

    rep = miner.refresh()
    print(f"gen {rep.generation}: {rep.frequent} frequent itemsets "
          f"over {rep.n_transactions} transactions "
          f"({rep.wall_s:.2f}s from scratch)")

    step = len(stream) // 4
    for i in range(4):
        batch = stream[i * step:(i + 1) * step]
        ing = miner.ingest(batch)
        print(f"  ingested {ing.n_transactions} tx as segment "
              f"{ing.segment} ({ing.payload_bytes} B packed)")
        # queries keep answering from the published generation —
        # ingest never blocks them, refresh never blocks them
        hot = server.top_k((), 3)
        print(f"  serving gen {server.snapshot.generation}, top-3 "
              f"{hot}")
        rep = miner.refresh()
        print(f"gen {rep.generation}: {rep.frequent} frequent | "
              f"border +{rep.born}/-{rep.died} | candidates: "
              f"{rep.reused} reused, {rep.swept_delta} delta-swept, "
              f"{rep.swept_full} fully swept | {rep.rows_touched} "
              f"rows in {rep.wall_s:.2f}s")

    itemset = server.top_k((), 1)[0][0]
    print(f"support{itemset} = {server.support(itemset)} "
          f"at generation {server.snapshot.generation}")


if __name__ == "__main__":
    main()
