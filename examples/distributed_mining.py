"""Multi-device FPM through the unified task engine: `fpm.mine(mesh=)`
runs every granularity on a device mesh — sharded bitmap arena (one
mirror per device), one sweep dispatcher per device, device-affine
workers whose cross-device bucket steals migrate the bucket's retained
bitmaps (spawns an 8-device subprocess).

Run:  PYTHONPATH=src python examples/distributed_mining.py
"""
import subprocess
import sys
import textwrap

CODE = """
import sys; sys.path.insert(0, "src")
import time
import jax, numpy as np
from jax.sharding import Mesh
from repro.data.transactions import load
from repro.core.tidlist import pack_database
from repro.core.fpm import mine, mine_serial
from repro.core.distributed_fpm import mine_distributed

db, p = load('mushroom', seed=0)
db = db[:2500]
bm = pack_database(db, p.n_dense_items)
ms = int(0.22 * len(db))
print(f"{len(db)} transactions over 8 devices, min_support={ms}")
ref = mine_serial(bm, ms, max_k=4)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))

# the unified engine: every granularity runs distributed
for gran in ['bucket', 'depth-first']:
    t0 = time.time()
    res, met = mine(bm, ms, mesh=mesh, granularity=gran,
                    policy='clustered', n_workers=8, max_k=4)
    assert res == ref
    occ = '/'.join(f"{d['batch_occupancy']:.1f}" for d in met.per_device)
    print(f"[{gran:11s}] wall={time.time()-t0:5.2f}s "
          f"rows_touched={met.rows_touched:7d} "
          f"d2d={met.d2d_bytes}B migrations={met.migrations} "
          f"dev_occupancy={occ} cache_misses={met.cache_misses}")

# the legacy two-policy API is a shim over the same engine
for pol in ['round_robin', 'clustered']:
    t0 = time.time()
    res, stats = mine_distributed(bm, ms, mesh, policy=pol, max_k=4)
    assert res == ref
    print(f"[{pol:11s}] wall={time.time()-t0:5.2f}s "
          f"rows_touched={stats['rows_touched']:7d} "
          f"candidates={stats['candidates']}")
print("clustered placement touches fewer bitmap rows (prefix joined "
      "once per bucket), and depth-first carries its zero-recompute "
      "handoff onto the mesh: cross-device traffic is explicit "
      "(d2d bytes = fetched rows + migrated bucket bitmaps).")
"""


def main():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu",   # skip TPU probing in the child
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(CODE)],
                       env=env, text=True)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
