"""Distributed FPM on a multi-device mesh: the paper's clustered
scheduling as owner-computes placement (spawns an 8-device subprocess).

Run:  PYTHONPATH=src python examples/distributed_mining.py
"""
import subprocess
import sys
import textwrap

CODE = """
import sys; sys.path.insert(0, "src")
import time
import jax, numpy as np
from jax.sharding import Mesh
from repro.data.transactions import load
from repro.core.tidlist import pack_database
from repro.core.fpm import mine_serial
from repro.core.distributed_fpm import mine_distributed

db, p = load('mushroom', seed=0)
db = db[:2500]
bm = pack_database(db, p.n_dense_items)
ms = int(0.22 * len(db))
print(f"{len(db)} transactions over 8 devices, min_support={ms}")
ref = mine_serial(bm, ms, max_k=4)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
for pol in ['round_robin', 'clustered']:
    t0 = time.time()
    res, stats = mine_distributed(bm, ms, mesh, policy=pol, max_k=4)
    assert res == ref
    print(f"[{pol:11s}] wall={time.time()-t0:5.2f}s "
          f"rows_touched={stats['rows_touched']:7d} "
          f"candidates={stats['candidates']}")
print("clustered placement touches fewer bitmap rows: the prefix join "
      "is computed once per bucket (owner-computes locality).")
"""


def main():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(CODE)],
                       env=env, text=True)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
