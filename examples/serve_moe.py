"""Serve a small MoE model with batched requests: prefill + decode with
a KV cache, clustered expert dispatch.

Run:  PYTHONPATH=src python examples/serve_moe.py
"""
import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.configs.registry import get_smoke_config
from repro.models.registry import build_model


def main():
    cfg = get_smoke_config("dbrx-132b").with_(
        d_model=128, n_heads=8, n_kv_heads=4, vocab_size=2048,
        n_layers=4, moe=MoEConfig(n_experts=8, top_k=2))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch, prompt_len, gen_len = 8, 24, 24
    max_len = prompt_len + gen_len
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    cache = model.init_cache(batch, max_len)

    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, cache, prompts[:, i:i + 1],
                               jnp.int32(i))
    print(f"prefill {batch}x{prompt_len}: {(time.time()-t0)*1e3:.0f} ms")

    outs = []
    t0 = time.time()
    for i in range(gen_len):
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
    dt = time.time() - t0
    print(f"decode  {batch}x{gen_len}: {dt*1e3:.0f} ms "
          f"({batch*gen_len/dt:.0f} tok/s)")
    gen = np.concatenate(outs, axis=1)
    print("request 0 generated ids:", gen[0].tolist())
    # consistency: greedy decode must be deterministic
    assert gen.shape == (batch, gen_len)


if __name__ == "__main__":
    main()
