"""Quickstart: the paper's system in 60 seconds.

1. Generate a transaction database (FIMI-profile synthetic).
2. Mine it with the Cilk-style policy, then the clustered policy, at
   candidate granularity (one scalar join per task — the paper's §2
   setting) and show the locality metrics that explain the difference
   (the Fig. 1 + Table 1 story).
3. Re-mine at bucket granularity: one task per (k-1)-prefix, the prefix
   intersection computed once, all extensions swept in one vectorized
   call through the join backend — the same locality, made structural.
4. Re-mine depth-first: barrier-free equivalence-class recursion where
   each task spawns its child classes and hands each child its already-
   intersected prefix bitmap — no barriers, no prefix recomputation,
   the LRU cache vestigial (zero misses).

Run:  PYTHONPATH=src python examples/quickstart.py
      (optionally: --backend pallas-interpret --arena jax
       --max-batch 16 --flush-us 500)
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.core.fpm import mesh_over_devices, mine, mine_serial
from repro.core.tidlist import pack_database
from repro.data.transactions import load


def main():
    ap = argparse.ArgumentParser(description="FPM quickstart")
    ap.add_argument("--backend", default="auto",
                    help="join backend: auto|numpy|pallas-interpret|"
                         "pallas-jit")
    ap.add_argument("--arena", default="auto",
                    choices=["auto", "numpy", "jax"],
                    help="bitmap arena backing (device residency)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="sweep dispatcher: max requests per launch")
    ap.add_argument("--flush-us", type=float, default=200.0,
                    help="sweep dispatcher: straggler wait before a "
                         "partial flush")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the run over N devices (logical shards "
                         "on a 1-device host); 0 = shared-memory")
    args = ap.parse_args()
    knobs = dict(backend=args.backend, arena=args.arena,
                 max_batch=args.max_batch, flush_us=args.flush_us,
                 mesh=mesh_over_devices(args.mesh))

    db, prof = load("chess", seed=0)
    bitmaps = pack_database(db, prof.n_dense_items)
    min_support = int(prof.support * len(db))
    print(f"synthetic 'chess' profile: {len(db)} transactions, "
          f"{prof.n_dense_items} items, min_support={min_support}")

    ref = mine_serial(bitmaps, min_support, max_k=4)
    print(f"serial Apriori: {len(ref)} frequent itemsets\n")

    for policy in ("cilk", "clustered"):
        res, met = mine(bitmaps, min_support, policy=policy,
                        n_workers=4, max_k=4, granularity="candidate",
                        **knobs)
        assert res == ref
        s = met.scheduler
        print(f"[{policy:9s}] wall={met.wall_s:6.2f}s  "
              f"prefix-cache hit rate={met.cache_hit_rate:6.1%}  "
              f"steals={int(s['steals']):5d}  "
              f"tasks/steal={s['tasks_per_steal']:.2f}")

    print("\nThe clustered policy runs tasks that share a (k-1)-prefix "
          "back-to-back\non one worker, so the prefix intersection is "
          "computed once and reused —\nthe paper's dTLB/IPC win, "
          "observable here as the cache-hit-rate gap.\n")

    for gran in ("candidate", "bucket", "depth-first"):
        res, met = mine(bitmaps, min_support, policy="clustered",
                        n_workers=4, max_k=4, granularity=gran, **knobs)
        assert res == ref
        print(f"[granularity={gran:11s}] wall={met.wall_s:6.2f}s  "
              f"tasks={int(met.scheduler['tasks_run']):6d}  "
              f"rows touched={met.rows_touched:8d}  "
              f"cache misses={met.cache_misses:6d}  "
              f"batch occupancy={met.batch_occupancy:5.2f}  "
              f"h2d={met.h2d_bytes:8d}B  "
              f"peak retained bitmaps={met.peak_retained_bitmaps}")

    print("\nBucket granularity makes the bucket the unit of task "
          "execution: the\nprefix intersection happens once per bucket "
          "and the extensions are swept\nthrough one handle-based "
          "request on the sweep dispatcher, which coalesces\nmany "
          "workers' buckets into one batched multi-prefix kernel "
          "launch (numpy\nufuncs here; the Pallas bitmap_join_many "
          "kernel on TPU) — fewer rows\ntouched, fewer tasks, same "
          "supports. Every bitmap lives in one\nrefcounted arena, so "
          "the device sees ~one initial upload (h2d above)\ninstead "
          "of per-sweep transfers.\n\n"
          "Depth-first granularity goes barrier-free: each class task "
          "spawns its\nchild equivalence classes onto its own worker "
          "and hands each child the\nalready-intersected prefix∧ext "
          "arena handle, so no prefix is ever\nrecomputed (cache "
          "misses: zero) and only one terminal wait remains. The\n"
          "price is the retained-bitmap peak printed above — bounded "
          "by depth-first\ndrain order, and measured.")


if __name__ == "__main__":
    main()
