"""Quickstart: the paper's system in 60 seconds.

1. Generate a transaction database (FIMI-profile synthetic).
2. Mine it with the Cilk-style policy, then the clustered policy.
3. Show the locality metrics that explain the difference (the paper's
   Fig. 1 + Table 1 story).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.fpm import mine, mine_serial
from repro.core.tidlist import pack_database
from repro.data.transactions import load


def main():
    db, prof = load("chess", seed=0)
    bitmaps = pack_database(db, prof.n_dense_items)
    min_support = int(prof.support * len(db))
    print(f"synthetic 'chess' profile: {len(db)} transactions, "
          f"{prof.n_dense_items} items, min_support={min_support}")

    ref = mine_serial(bitmaps, min_support, max_k=4)
    print(f"serial Apriori: {len(ref)} frequent itemsets\n")

    for policy in ("cilk", "clustered"):
        res, met = mine(bitmaps, min_support, policy=policy,
                        n_workers=4, max_k=4)
        assert res == ref
        s = met.scheduler
        print(f"[{policy:9s}] wall={met.wall_s:6.2f}s  "
              f"prefix-cache hit rate={met.cache_hit_rate:6.1%}  "
              f"steals={int(s['steals']):5d}  "
              f"tasks/steal={s['tasks_per_steal']:.2f}")

    print("\nThe clustered policy runs tasks that share a (k-1)-prefix "
          "back-to-back\non one worker, so the prefix intersection is "
          "computed once and reused —\nthe paper's dTLB/IPC win, "
          "observable here as the cache-hit-rate gap.")


if __name__ == "__main__":
    main()
