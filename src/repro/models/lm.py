"""Decoder-only LM assembly for dense / moe / ssm / hybrid / vlm families.

Layers are scanned (stacked params) so HLO size is O(1) in depth — required
for 94-layer dry-runs. The hybrid (Zamba2) family scans Mamba2 groups and
interleaves the *shared* attention block between groups (weights reused at
every site — the block's working set stays resident, a locality argument of
the same flavour as the paper's clustering).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamDef, apply_ffn, apply_norm,
                                 cross_entropy, dtype_of, ffn_defs,
                                 init_params, norm_defs, padded_vocab,
                                 shapes_tree, stack_defs)
from repro.parallel.ctx import shard_activation

PyTree = Any


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "collectives":
        # save the post-collective sublayer outputs (they are seq-sharded
        # and small) so backward remat does NOT re-run the forward's TP
        # all-reduces / all-gathers — EXPERIMENTS.md §Perf hillclimb B.
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out"))
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)


def maybe_scan(cfg, f, init, xs):
    """lax.scan when cfg.scan_layers, else an unrolled python loop with the
    same (carry, stacked_ys) contract (used by the dry-run cost lowering)."""
    if cfg.scan_layers:
        return jax.lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = f(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    stacked = (None if ys[0] is None
               else jax.tree.map(lambda *ls: jnp.stack(ls), *ys))
    return carry, stacked


class DecoderLM:
    """cfg.family in {dense, moe, ssm, hybrid, vlm}."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.vp = padded_vocab(cfg.vocab_size)
        self._defs = self._param_defs()

    # ------------------------------------------------------------- defs --
    def _block_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        if cfg.family in ("dense", "vlm"):
            return {"ln1": norm_defs(cfg, d), "attn": attn.attn_defs(cfg, d),
                    "ln2": norm_defs(cfg, d),
                    "ffn": ffn_defs(cfg, d, cfg.d_ff)}
        if cfg.family == "moe":
            return {"ln1": norm_defs(cfg, d), "attn": attn.attn_defs(cfg, d),
                    "ln2": norm_defs(cfg, d), "moe": moe_mod.moe_defs(cfg, d)}
        if cfg.family in ("ssm", "hybrid"):
            return {"ln": norm_defs(cfg, d), "ssm": ssm_mod.ssm_defs(cfg)}
        raise ValueError(cfg.family)

    def _shared_block_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": norm_defs(cfg, d), "attn": attn.attn_defs(cfg, d),
                "ln2": norm_defs(cfg, d), "ffn": ffn_defs(cfg, d, cfg.d_ff)}

    def _layer_split(self) -> Tuple[int, int, int]:
        """hybrid: (n_sites, attn_every, tail)."""
        cfg = self.cfg
        ae = cfg.hybrid.attn_every
        n_sites = cfg.n_layers // ae
        tail = cfg.n_layers - n_sites * ae
        return n_sites, ae, tail

    def _param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        defs: Dict[str, Any] = {
            "embed": ParamDef((self.vp, cfg.d_model), ("vocab", "embed"),
                              "normal"),
            "final_norm": norm_defs(cfg, d),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = ParamDef((cfg.d_model, self.vp),
                                       ("embed", "vocab"), "normal")
        if cfg.family == "hybrid":
            n_sites, ae, tail = self._layer_split()
            defs["blocks"] = stack_defs(self._block_defs(), n_sites * ae)
            if tail:
                defs["tail_blocks"] = stack_defs(self._block_defs(), tail)
            defs["shared"] = self._shared_block_defs()
        else:
            defs["blocks"] = stack_defs(self._block_defs(), cfg.n_layers)
        return defs

    def param_defs(self) -> Dict[str, Any]:
        return self._defs

    def init(self, key) -> PyTree:
        return init_params(self._defs, key)

    def param_shapes(self) -> PyTree:
        return shapes_tree(self._defs)

    # ------------------------------------------------------------ blocks --
    def _apply_block(self, p, x, positions, aux):
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            h = apply_norm(cfg, p["ln1"], x)
            # seq-sharded constraint on the post-norm activation: its
            # COTANGENT inherits the sharding, so the qkv-projection
            # backward emits reduce-scatter instead of full all-reduce
            # (EXPERIMENTS.md §Perf, hillclimb B iteration 2)
            h = shard_activation(h, ("act_batch", "act_seq", "act_embed"))
            q, k, v = attn.qkv(cfg, p["attn"], h, positions)
            q = shard_activation(q, ("act_batch", None, "act_heads", None))
            o = attn.attention(cfg, q, k, v, causal=True)
            dt = x.dtype
            y = jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"].astype(dt))
            y = shard_activation(y, ("act_batch", "act_seq", "act_embed"))
            x = x + jax.ad_checkpoint.checkpoint_name(y, "attn_out")
            h = apply_norm(cfg, p["ln2"], x)
            h = shard_activation(h, ("act_batch", "act_seq", "act_embed"))
            if cfg.family == "moe":
                y, a = moe_mod.apply_moe(cfg, p["moe"], h)
                aux = aux + a
            else:
                y = apply_ffn(cfg, p["ffn"], h)
            y = shard_activation(y, ("act_batch", "act_seq", "act_embed"))
            x = x + jax.ad_checkpoint.checkpoint_name(y, "mlp_out")
        else:  # ssm / hybrid backbone
            h = apply_norm(cfg, p["ln"], x)
            y = ssm_mod.apply_ssm_block(cfg, p["ssm"], h)
            y = shard_activation(y, ("act_batch", "act_seq", "act_embed"))
            x = x + jax.ad_checkpoint.checkpoint_name(y, "mlp_out")
        x = shard_activation(x, ("act_batch", "act_seq", "act_embed"))
        return x, aux

    def _apply_shared_block(self, p, x, positions, window: int = 0):
        cfg = self.cfg
        h = apply_norm(cfg, p["ln1"], x)
        q, k, v = attn.qkv(cfg, p["attn"], h, positions)
        o = attn.attention(cfg, q, k, v, causal=True, window=window)
        dt = x.dtype
        x = x + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"].astype(dt))
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
        return x

    def _scan_blocks(self, stacked, x, positions, aux):
        body = _remat(self.cfg, functools.partial(
            lambda carry, p: self._apply_block(p, carry[0], positions,
                                               carry[1])))
        if not self.cfg.scan_layers:
            # unrolled python loop: used by the dry-run's cost lowering —
            # XLA cost_analysis counts while-loop bodies ONCE, so the
            # scanned artifact under-reports FLOPs by ~n_layers.
            n = jax.tree.leaves(stacked)[0].shape[0]
            for i in range(n):
                x, aux = body((x, aux), jax.tree.map(lambda a: a[i], stacked))
            return x, aux

        def f(carry, p):
            x, aux = body(carry, p)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(f, (x, aux), stacked)
        return x, aux

    # ------------------------------------------------------------- apply --
    def apply(self, params, tokens) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens [B,S] -> (logits [B,S,Vp], aux_loss)."""
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        b, s = tokens.shape
        x = params["embed"].astype(dt)[tokens]
        x = shard_activation(x, ("act_batch", "act_seq", "act_embed"))
        positions = jnp.arange(s)[None, :]
        aux = jnp.zeros((), jnp.float32)

        if cfg.family == "hybrid":
            n_sites, ae, tail = self._layer_split()
            grouped = jax.tree.map(
                lambda a: a.reshape((n_sites, ae) + a.shape[1:]),
                params["blocks"])
            for i in range(n_sites):
                grp = jax.tree.map(lambda a: a[i], grouped)
                x, aux = self._scan_blocks(grp, x, positions, aux)
                x = self._apply_shared_block(params["shared"], x, positions)
            if tail:
                x, aux = self._scan_blocks(params["tail_blocks"], x,
                                           positions, aux)
        else:
            x, aux = self._scan_blocks(params["blocks"], x, positions, aux)

        x = apply_norm(cfg, params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head.astype(dt)
        logits = shard_activation(logits, ("act_batch", "act_seq", "vocab"))
        return logits, aux

    def loss(self, params, batch) -> jnp.ndarray:
        logits, aux = self.apply(params, batch["tokens"])
        return (cross_entropy(logits, batch["labels"], self.cfg.vocab_size)
                + 0.01 * aux)

    # ------------------------------------------------------------- cache --
    def window_for(self, max_len: int) -> int:
        """Sliding-window size for the shared attn block (hybrid only):
        long-context decode uses a ring-buffer window (DESIGN.md §4)."""
        cfg = self.cfg
        if cfg.family == "hybrid" and max_len > 65536:
            return cfg.hybrid.long_ctx_window
        return 0

    def cache_defs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            return {"kv": attn.cache_defs(cfg, batch, max_len, cfg.n_layers)}
        if cfg.family == "ssm":
            return {"ssm": ssm_mod.ssm_cache_defs(cfg, batch, cfg.n_layers)}
        if cfg.family == "hybrid":
            n_sites, ae, tail = self._layer_split()
            w = self.window_for(max_len)
            return {
                "ssm": ssm_mod.ssm_cache_defs(cfg, batch, cfg.n_layers),
                "kv": attn.cache_defs(cfg, batch, max_len, n_sites, window=w),
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, max_len: int) -> PyTree:
        return init_params(self.cache_defs(batch, max_len),
                           jax.random.PRNGKey(0))

    def cache_shapes(self, batch: int, max_len: int) -> PyTree:
        return shapes_tree(self.cache_defs(batch, max_len))

    # ----------------------------------------------------------- prefill --
    def prefill(self, params, tokens) -> Tuple[jnp.ndarray, PyTree]:
        """Run the full forward, returning last-position logits + KV cache.

        Only attention families materialize a KV cache at prefill; SSM and
        hybrid prefill via their own recurrence (cache = final states) —
        for the dry-run cells, prefill of attention families is the
        quadratic-cost artifact of interest.
        """
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        b, s = tokens.shape
        x = params["embed"].astype(dt)[tokens]
        positions = jnp.arange(s)[None, :]
        aux = jnp.zeros((), jnp.float32)

        caches_k = []
        caches_v = []
        if cfg.family in ("dense", "vlm", "moe"):
            def f(carry, p):
                x, aux = carry
                h = apply_norm(cfg, p["ln1"], x)
                q, k, v = attn.qkv(cfg, p["attn"], h, positions)
                o = attn.attention(cfg, q, k, v, causal=True)
                x = x + jnp.einsum("bshe,hed->bsd", o,
                                   p["attn"]["wo"].astype(x.dtype))
                h2 = apply_norm(cfg, p["ln2"], x)
                if cfg.family == "moe":
                    y, a = moe_mod.apply_moe(cfg, p["moe"], h2)
                    aux = aux + a
                else:
                    y = apply_ffn(cfg, p["ffn"], h2)
                return (x + y, aux), (k, v)

            (x, aux), (ks, vs) = maybe_scan(cfg, f, (x, aux), params["blocks"])
            cache = {"kv": {"k": ks, "v": vs}}
        else:
            # ssm/hybrid prefill: run apply path and return decode states.
            # (States are reconstructed exactly by the recurrence; for the
            # dry-run artifact we lower the forward itself.)
            logits, aux = self.apply(params, tokens)
            return logits[:, -1:], self.init_cache(b, s)

        x = apply_norm(cfg, params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x[:, -1:] @ head.astype(dt)
        return logits, cache

    # ------------------------------------------------------------ decode --
    def decode_step(self, params, cache, tokens, pos, *, window: int = 0
                    ) -> Tuple[jnp.ndarray, PyTree]:
        """One decode step. tokens [B,1]; pos: scalar int32 position.
        ``window`` is static (pass self.window_for(max_len) for hybrids)."""
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        x = params["embed"].astype(dt)[tokens]          # [B,1,D]
        positions = jnp.full(tokens.shape, pos)
        new_cache = dict(cache)

        if cfg.family in ("dense", "vlm", "moe"):
            int8 = cfg.kv_cache_dtype == "int8"

            def f(x, xs):
                if int8:
                    p, ck, cv, cks, cvs = xs
                else:
                    p, ck, cv = xs
                h = apply_norm(cfg, p["ln1"], x)
                q, k, v = attn.qkv(cfg, p["attn"], h, positions)
                if int8:
                    ck, cv, cks, cvs = attn.cache_update(
                        ck, cv, k, v, pos, scales=(cks, cvs))
                    o = attn.decode_attention(cfg, q, ck, cv, pos,
                                              scales=(cks, cvs))
                else:
                    ck, cv = attn.cache_update(ck, cv, k, v, pos)
                    o = attn.decode_attention(cfg, q, ck, cv, pos)
                x = x + jnp.einsum("bshe,hed->bsd", o,
                                   p["attn"]["wo"].astype(x.dtype))
                h2 = apply_norm(cfg, p["ln2"], x)
                if cfg.family == "moe":
                    y, _ = moe_mod.apply_moe(cfg, p["moe"], h2)
                else:
                    y = apply_ffn(cfg, p["ffn"], h2)
                return (x + y,
                        (ck, cv, cks, cvs) if int8 else (ck, cv))

            if int8:
                x, (ks, vs, kss, vss) = maybe_scan(
                    cfg, f, x, (params["blocks"], cache["kv"]["k"],
                                cache["kv"]["v"], cache["kv"]["k_scale"],
                                cache["kv"]["v_scale"]))
                new_cache["kv"] = {"k": ks, "v": vs, "k_scale": kss,
                                   "v_scale": vss}
            else:
                x, (ks, vs) = maybe_scan(
                    cfg, f, x, (params["blocks"], cache["kv"]["k"],
                                cache["kv"]["v"]))
                new_cache["kv"] = {"k": ks, "v": vs}

        elif cfg.family == "ssm":
            def f(x, xs):
                p, h, conv = xs
                hin = apply_norm(cfg, p["ln"], x)
                y, h, conv = ssm_mod.ssm_decode_step(cfg, p["ssm"], hin, h,
                                                     conv)
                return x + y, (h, conv)

            x, (hs, convs) = maybe_scan(
                cfg, f, x, (params["blocks"], cache["ssm"]["h"],
                       cache["ssm"]["conv"]))
            new_cache["ssm"] = {"h": hs, "conv": convs}

        else:  # hybrid
            n_sites, ae, tail = self._layer_split()
            w = window

            def mamba_f(x, xs):
                p, h, conv = xs
                hin = apply_norm(cfg, p["ln"], x)
                y, h, conv = ssm_mod.ssm_decode_step(cfg, p["ssm"], hin, h,
                                                     conv)
                return x + y, (h, conv)

            grouped = jax.tree.map(
                lambda a: a.reshape((n_sites, ae) + a.shape[1:]),
                params["blocks"])
            sc = cache["ssm"]
            g_h = sc["h"][:n_sites * ae].reshape(
                (n_sites, ae) + sc["h"].shape[1:])
            g_c = sc["conv"][:n_sites * ae].reshape(
                (n_sites, ae) + sc["conv"].shape[1:])
            hs_out, conv_out, kv_k, kv_v = [], [], [], []
            for i in range(n_sites):
                grp = jax.tree.map(lambda a: a[i], grouped)
                x, (hs, convs) = maybe_scan(
                    cfg, mamba_f, x, (grp, g_h[i], g_c[i]))
                hs_out.append(hs)
                conv_out.append(convs)
                # shared attention site i
                sp = params["shared"]
                h_in = apply_norm(cfg, sp["ln1"], x)
                q, k, v = attn.qkv(cfg, sp["attn"], h_in, positions)
                ck, cv = attn.cache_update(
                    cache["kv"]["k"][i], cache["kv"]["v"][i], k, v, pos,
                    window=w)
                o = attn.decode_attention(cfg, q, ck, cv, pos, window=w)
                x = x + jnp.einsum("bshe,hed->bsd", o,
                                   sp["attn"]["wo"].astype(x.dtype))
                x = x + apply_ffn(cfg, sp["ffn"],
                                  apply_norm(cfg, sp["ln2"], x))
                kv_k.append(ck)
                kv_v.append(cv)
            if tail:
                x, (hs, convs) = maybe_scan(
                    cfg, mamba_f, x,
                    (params["tail_blocks"], sc["h"][n_sites * ae:],
                     sc["conv"][n_sites * ae:]))
            new_h = jnp.concatenate(
                [jnp.stack(hs_out).reshape((-1,) + sc["h"].shape[1:])]
                + ([hs] if tail else []), axis=0)
            new_conv = jnp.concatenate(
                [jnp.stack(conv_out).reshape((-1,) + sc["conv"].shape[1:])]
                + ([convs] if tail else []), axis=0)
            new_cache = {"ssm": {"h": new_h, "conv": new_conv},
                         "kv": {"k": jnp.stack(kv_k), "v": jnp.stack(kv_v)}}

        x = apply_norm(cfg, params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head.astype(dt)
        return logits, new_cache
