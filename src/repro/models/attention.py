"""GQA attention: full / q-chunked (memory-efficient) / decode-with-cache.

Design notes (TPU adaptation):
- KV heads are expanded (repeated) to the query head count *after* the
  cache: the cache stores the compact n_kv_heads layout (HBM win), while
  the attention einsum runs over the expanded layout so that tensor
  parallelism can shard the query-head axis even when n_kv_heads is not
  divisible by the `model` mesh axis (KV replication under TP — the
  standard Megatron GQA treatment).
- Long prefills use a q-chunked lax.scan: one [Bq, S] logit block live at
  a time, softmax over the full row (exact, no online rescaling needed).
  On TPU the Pallas flash_attention kernel replaces this path
  (cfg.use_pallas); both match the same oracle in tests.
- Sliding-window masking supports the hybrid (Zamba2) long-context shared
  attention block.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, apply_rope

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_defs(cfg, d: int) -> Dict[str, ParamDef]:
    hd = cfg.resolved_head_dim()
    defs = {
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed", "heads", "head_dim"),
                       "normal"),
        "wk": ParamDef((d, cfg.n_kv_heads, hd),
                       ("embed", "kv_heads", "head_dim"), "normal"),
        "wv": ParamDef((d, cfg.n_kv_heads, hd),
                       ("embed", "kv_heads", "head_dim"), "normal"),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", "head_dim", "embed"),
                       "normal",
                       scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((cfg.n_heads, hd), ("heads", "head_dim"),
                              "zeros")
        defs["bk"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                              "zeros")
        defs["bv"] = ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                              "zeros")
    return defs


def qkv(cfg, p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
        rope: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B,S,D] -> q:[B,S,Hq,Dh], k/v:[B,S,Hkv,Dh] (RoPE applied)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,T,Hkv,D] -> [B,T,Hq,D] by repeating each kv head G times."""
    b, t, hkv, d = k.shape
    g = n_heads // hkv
    if g == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, hkv, g, d))
    return k.reshape(b, t, n_heads, d)


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: int, kv_len: Optional[jnp.ndarray]) -> jnp.ndarray:
    """[Q,K] additive bias. q_pos:[Q], k_pos:[K]."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, f32: bool = True):
    """q:[B,Q,H,D] k,v:[B,T,H,D] bias:[Q,T] -> [B,Q,H,D]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    acc = jnp.float32 if f32 else q.dtype
    logits = jnp.einsum("bqhd,bthd->bhqt", q, k,
                        preferred_element_type=acc) * scale
    logits = logits + bias[None, None].astype(acc)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", w, v)


def _context_parallel_tp(cfg, s: int, h: int):
    """Context-parallel attention applies when the head count cannot be
    sharded over `model` but the query-block axis can (DESIGN/EXPERIMENTS
    §Perf: qwen2.5 40H, whisper 6H). Returns (tp, block) or (0, 0).
    The block adapts downward so that s == n_local * tp * block."""
    from repro.parallel.ctx import current as _ctx
    ctx = _ctx()
    if ctx is None:
        return 0, 0
    mesh = ctx[0]
    tp = mesh.shape.get("model", 1)
    if tp <= 1 or h % tp == 0:
        return 0, 0                    # head sharding handles it
    bq = min(cfg.attn_block_q, max(s // tp, 1))
    while bq > 1 and s % (tp * bq):
        bq //= 2
    return (tp, bq) if (bq >= 8 and s % (tp * bq) == 0) else (0, 0)


def attention(cfg, q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              *, causal: bool = True, window: int = 0,
              q_offset: int = 0) -> jnp.ndarray:
    """Self/cross attention. q:[B,S,Hq,D], k/v:[B,T,Hkv,D] -> [B,S,Hq,D].

    Paths:
      - plain SDPA for short sequences (heads sharded over `model` when
        divisible — the expand_kv trick keeps GQA shardable);
      - q-chunked scan above cfg.attn_blockwise_threshold (compiled
        memory O(S·block) instead of O(S²));
      - context-parallel blockwise when heads are NOT divisible by the
        `model` axis: query blocks are sharded over `model` (grouped
        GQA form, KV kept compact), so the S² logit traffic divides by
        tp instead of replicating.
    """
    b, s, h, d = q.shape
    cp, cp_bq = _context_parallel_tp(cfg, s, h)
    if cp:
        return _attention_context_parallel(cfg, q, k, v, causal=causal,
                                           window=window,
                                           q_offset=q_offset, tp=cp,
                                           bq=cp_bq)
    if (cfg.use_pallas and jax.default_backend() == "tpu"
            and window == 0 and q_offset == 0 and s == k.shape[1]):
        # TPU hot path: fused flash kernel — no S^2 HBM traffic
        from repro.kernels.flash_attention.ops import flash_attention
        ke = expand_kv(k, h)
        ve = expand_kv(v, h)
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        kf = ke.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        vf = ve.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        o = flash_attention(qf, kf, vf, causal=causal)
        return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    k = expand_kv(k, q.shape[2])
    v = expand_kv(v, q.shape[2])
    t = k.shape[1]
    q_pos = q_offset + jnp.arange(s)
    k_pos = jnp.arange(t)

    if s <= cfg.attn_blockwise_threshold:
        bias = _mask_bias(q_pos, k_pos, causal, window, None)
        return _sdpa(q, k, v, bias, f32=cfg.attn_softmax_f32)

    # ---- q-chunked path: scan over query blocks ----
    bq = cfg.attn_block_q
    assert s % bq == 0, (s, bq)
    nblk = s // bq
    qb = q.reshape(b, nblk, bq, h, d).transpose(1, 0, 2, 3, 4)  # [n,B,bq,H,D]

    def body(carry, qi):
        blk, qc = qi
        qp = q_offset + blk * bq + jnp.arange(bq)
        bias = _mask_bias(qp, k_pos, causal, window, None)
        return carry, _sdpa(qc, k, v, bias, f32=cfg.attn_softmax_f32)

    _, out = jax.lax.scan(body, None, (jnp.arange(nblk), qb))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def _attention_context_parallel(cfg, q, k, v, *, causal, window, q_offset,
                                tp: int, bq: int):
    """Query-block context parallelism (grouped GQA, compact KV).

    q blocks laid out [n_local(scan), tp(sharded over `model`), ...];
    each scan step computes tp blocks in parallel, one per model shard —
    the per-device S² logit footprint divides by tp.
    """
    from repro.parallel.ctx import shard_activation
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    t = k.shape[1]
    n_local = s // (tp * bq)
    scale = 1.0 / math.sqrt(d)
    k_pos = jnp.arange(t)

    # Block-major layout: device j owns the CONTIGUOUS query chunk
    # [j*S/tp, (j+1)*S/tp), so merging back to the seq-sharded residual
    # layout is a no-op (no resharding collectives — §Perf C iteration 2).
    # [n_local, tp, B, bq, Hkv, G, D]
    qb = q.reshape(b, tp, n_local, bq, hkv, g, d)
    qb = qb.transpose(2, 1, 0, 3, 4, 5, 6)
    qb = shard_activation(
        qb, (None, "act_seq", None, None, None, None, None))

    def body(carry, inp):
        i, qc = inp                       # qc: [tp, B, bq, Hkv, G, D]
        j = jax.lax.broadcasted_iota(jnp.int32, (tp, bq), 0)
        r = jax.lax.broadcasted_iota(jnp.int32, (tp, bq), 1)
        qp = q_offset + (j * n_local + i) * bq + r       # [tp, bq]
        acc = jnp.float32 if cfg.attn_softmax_f32 else q.dtype
        logits = jnp.einsum("jbqhgd,bthd->jbhgqt", qc, k,
                            preferred_element_type=acc) * scale
        ok = k_pos[None, None, :] <= qp[:, :, None] if causal else \
            jnp.ones((tp, bq, t), bool)
        if window > 0:
            ok &= k_pos[None, None, :] > (qp[:, :, None] - window)
        bias = jnp.where(ok, 0.0, NEG_INF).astype(acc)   # [tp, bq, t]
        logits = logits + bias[:, None, None, None, :, :]
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        o = jnp.einsum("jbhgqt,bthd->jbqhgd", w, v)
        return carry, o

    _, out = jax.lax.scan(body, None, (jnp.arange(n_local), qb))
    # [n_local, tp, B, bq, Hkv, G, D] -> [B, S, Hq, D] (tp-major merge)
    out = out.transpose(2, 1, 0, 3, 4, 5, 6).reshape(b, s, hq, d)
    return shard_activation(out, ("act_batch", "act_seq", None, None))


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


def cache_defs(cfg, batch: int, max_len: int, n_layers: int,
               window: int = 0) -> Dict[str, ParamDef]:
    """Stacked-over-layers KV cache defs. window>0 -> ring buffer length.

    kv_cache_dtype == 'int8': k/v stored int8 with per-(pos, head) f32
    scales (symmetric quantization over head_dim) — halves decode HBM
    traffic at <1% quantization error."""
    hd = cfg.resolved_head_dim()
    length = min(max_len, window) if window > 0 else max_len
    shp = (n_layers, batch, length, cfg.n_kv_heads, hd)
    axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    from repro.models.common import dtype_of
    if cfg.kv_cache_dtype == "int8":
        sshp = shp[:-1]
        saxes = axes[:-1]
        return {"k": ParamDef(shp, axes, "zeros", dtype=jnp.int8),
                "v": ParamDef(shp, axes, "zeros", dtype=jnp.int8),
                "k_scale": ParamDef(sshp, saxes, "zeros",
                                    dtype=jnp.float32),
                "v_scale": ParamDef(sshp, saxes, "zeros",
                                    dtype=jnp.float32)}
    dt = dtype_of(cfg.dtype)
    return {"k": ParamDef(shp, axes, "zeros", dtype=dt),
            "v": ParamDef(shp, axes, "zeros", dtype=dt)}


def _quant_kv(x: jnp.ndarray):
    """[B,1,H,D] -> (int8 values, [B,1,H] scales)."""
    scale = (jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
             + 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def cache_update(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                 k: jnp.ndarray, v: jnp.ndarray, pos: jnp.ndarray,
                 window: int = 0, scales=None):
    """Write one step (k,v: [B,1,Hkv,D]) at position pos. Ring-buffer write
    when the cache is a sliding window. scales=(k_scale, v_scale) arrays
    enable int8 mode; returns (ck, cv) or (ck, cv, ks, vs)."""
    length = cache_k.shape[1]
    idx = pos % length if window > 0 else pos
    if scales is not None:
        kq, ks1 = _quant_kv(k)
        vq, vs1 = _quant_kv(v)
        ck = jax.lax.dynamic_update_slice(cache_k, kq, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_v, vq, (0, idx, 0, 0))
        ks = jax.lax.dynamic_update_slice(scales[0], ks1, (0, idx, 0))
        vs = jax.lax.dynamic_update_slice(scales[1], vs1, (0, idx, 0))
        return ck, cv, ks, vs
    ck = jax.lax.dynamic_update_slice(cache_k, k, (0, idx, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v, (0, idx, 0, 0))
    return ck, cv


def decode_attention(cfg, q: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray,
                     window: int = 0, scales=None) -> jnp.ndarray:
    """One-token attention against the cache.

    q: [B,1,Hq,D]; cache: [B,L,Hkv,D]; pos: current absolute position.
    For ring-buffer (window) caches, positions are reconstructed modulo
    the window so the causal mask stays exact. scales=(k_scale, v_scale)
    dequantizes an int8 cache: the k-scale folds into the logits (per-t
    multiply, no bf16 cache materialization in the einsum itself).
    """
    b, _, h, d = q.shape
    length = cache_k.shape[1]
    if scales is not None:
        ks, vs = scales                                # [B,L,Hkv]
        cache_k = cache_k.astype(jnp.bfloat16)
        cache_v = (cache_v.astype(jnp.float32)
                   * vs[..., None]).astype(q.dtype)
    k = expand_kv(cache_k, h)
    v = expand_kv(cache_v, h)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bthd->bhqt", q, k).astype(jnp.float32) * scale
    if scales is not None:
        logits = logits * expand_kv(
            ks[..., None], h)[..., 0].transpose(0, 2, 1)[:, :, None, :]
    slot = jnp.arange(length)
    if window > 0:
        # slot i holds the largest absolute position <= pos that is
        # congruent to i (mod length); valid iff within the window.
        abs_pos = pos - jnp.mod(pos - slot, length)
        valid = (abs_pos >= 0) & (abs_pos > pos - window)
    else:
        valid = slot <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqt,bthd->bqhd", w, v)
