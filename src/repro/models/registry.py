"""build_model(cfg) -> model object with a uniform API:

  init(key) / param_defs() / param_shapes()
  apply(params, tokens[, frames]) -> (logits, aux)
  loss(params, batch) -> scalar
  prefill(params, ...) -> (last_logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  init_cache / cache_shapes
"""
from __future__ import annotations

from repro.models.encdec import EncDecLM
from repro.models.lm import DecoderLM


def build_model(cfg):
    if cfg.family == "audio":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
