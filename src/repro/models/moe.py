"""Mixture-of-Experts layer with two dispatch policies.

This is where the paper's contribution lands in the LM stack (DESIGN.md §3):

- ``clustered`` (default): tokens are *sorted by expert id* within each
  token group — the analogue of the paper's prefix-hash bucketing. All
  tokens bound for one expert form a contiguous bucket, move across the
  mesh ONCE (one all-to-all on the dispatched [G, E, C, D] buckets when
  experts are sharded over `model`), and the expert's weights are applied
  to the whole bucket as a single batched matmul (weight reuse == the
  paper's TID-prefix reuse).
- ``onehot``: the GShard-style dense one-hot dispatch einsum — the
  "unclustered" baseline. Same routing semantics, but every token slot
  participates in every expert's dispatch product; its HLO FLOP count
  shows the waste the clustered policy removes (EXPERIMENTS.md §Perf).

SPMD layout: token groups G map to the DP axes, so per-group argsort /
scatter / one-hot work is device-local (never replicated); experts map to
`model`. Capacity C = ceil(cf · Tg · k / E) per group; overflow tokens are
dropped from expert compute (GShard semantics; the clustered policy drops
later-*token* entries, onehot drops later-*k* entries — both valid, noted
for the equivalence tests which use ample capacity).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamDef
from repro.parallel.ctx import current as sharding_ctx, shard_activation


def moe_defs(cfg, d: int) -> Dict[str, ParamDef]:
    m = cfg.moe
    e, f = m.n_experts, cfg.d_ff
    scale_o = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "router": ParamDef((d, e), ("embed", "experts"), "normal"),
        "wi": ParamDef((e, d, f), ("experts", "embed", "ff"), "normal"),
        "wg": ParamDef((e, d, f), ("experts", "embed", "ff"), "normal"),
        "wo": ParamDef((e, f, d), ("experts", "ff", "embed"), "normal",
                       scale=scale_o),
    }


def _capacity(cfg, tg: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.capacity_factor * tg * m.top_k / m.n_experts))
    return max(4, min(c, tg))


def _n_groups(cfg, t: int) -> int:
    m = cfg.moe
    if m.n_groups:
        return m.n_groups if t % m.n_groups == 0 else 1
    if m.dispatch == "onehot":
        g = max(1, t // m.onehot_group)
        while t % g:
            g -= 1
        return g
    ctx = sharding_ctx()
    if ctx is None:
        return 1
    from repro.parallel.sharding import dp_size
    dp = dp_size(ctx[0])
    return dp if (t % dp == 0 and t >= 64 * dp) else 1


def _router(cfg, p, x):
    """x:[G,Tg,D] -> (top_e, top_p, aux). Router always in fp32."""
    m = cfg.moe
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # [G,Tg,E]
    top_p, top_e = jax.lax.top_k(probs, m.top_k)             # [G,Tg,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], m.n_experts,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * ce)
    return top_e, top_p, aux


def _expert_ffn(cfg, p, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: [G, E, C, D] -> [G, E, C, D]; batched per-expert SwiGLU."""
    dt = xe.dtype
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# clustered (sort-based, bucket) dispatch
# ---------------------------------------------------------------------------


def _dispatch_group(cfg, x, top_e, top_p, c: int):
    """One group. x:[Tg,D]; top_e/p:[Tg,k] -> (xe [E*C,D], combine info)."""
    m = cfg.moe
    tg, d = x.shape
    k, e = m.top_k, m.n_experts
    flat_e = top_e.reshape(-1)                     # [Tg*k]
    flat_t = jnp.repeat(jnp.arange(tg), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)       # bucket by expert
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(tg * k) - starts[se]
    keep = pos < c
    slot = jnp.where(keep, se * c + pos, e * c)    # overflow -> sentinel
    xs = x[st] * keep[:, None].astype(x.dtype)
    xe = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(xs)
    return xe[:-1], (st, sp, slot, keep)


def _combine_group(ye, info, tg: int):
    """ye: [E*C, D]; scatter-add weighted expert outputs back to tokens."""
    st, sp, slot, keep = info
    yk = jnp.where(keep[:, None], ye[jnp.where(keep, slot, 0)], 0.0)
    contrib = yk * sp[:, None].astype(yk.dtype)
    return jnp.zeros((tg, ye.shape[1]), ye.dtype).at[st].add(contrib)


def moe_clustered(cfg, p, x: jnp.ndarray, g: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [T, D] -> ([T, D], aux). Pure-pjit path (no mesh context):
    group-parallel sort-based dispatch via vmap."""
    m = cfg.moe
    t, d = x.shape
    tg = t // g
    c = _capacity(cfg, tg)
    xg = x.reshape(g, tg, d)
    top_e, top_p, aux = _router(cfg, p, xg)

    xe, info = jax.vmap(
        lambda xi, ei, pi: _dispatch_group(cfg, xi, ei, pi, c))(
            xg, top_e, top_p)
    xe = xe.reshape(g, m.n_experts, c, d)
    ye = _expert_ffn(cfg, p, xe)
    ye = ye.reshape(g, m.n_experts * c, d)
    y = jax.vmap(lambda yi, ii: _combine_group(yi, ii, tg))(ye, info)
    return y.reshape(t, d), aux


def moe_clustered_shmap(cfg, p, x: jnp.ndarray, mesh, rules
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit shard_map clustered dispatch — the paper's owner-computes
    bucket placement with hand-written collectives (DESIGN.md §3).

    Layout: tokens sharded over the DP axes ("one group per data shard"),
    experts owned by `model` columns. Per device: local router + stable
    sort into expert buckets (device-local, never replicated), slice out
    the buckets of MY experts, all_gather them over DP (every expert
    owner receives its whole bucket — one bulk transfer per layer, the
    bucket-granularity move), local batched FFN, slice back, weighted
    scatter-add, psum over `model` to sum expert contributions.

    Backward of all_gather is reduce-scatter; backward of psum is free —
    so the gradient path is collective-optimal too.
    """
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import dp_axes
    import functools as _ft

    mcfg = cfg.moe
    t, d = x.shape
    dp = dp_axes(mesh)
    model_ax = "model" if "model" in mesh.shape else None
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    msize = mesh.shape[model_ax] if model_ax else 1
    e = mcfg.n_experts
    if (t % dp_size) or (e % msize) or model_ax is None or dp_size == 1:
        return moe_clustered(cfg, p, x, _n_groups(cfg, t))
    t_loc = t // dp_size
    c = _capacity(cfg, t_loc)
    e_loc = e // msize

    def gather_dp(v, axis):
        for ax in reversed(dp):
            v = jax.lax.all_gather(v, ax, axis=axis, tiled=True)
        return v

    def local_fn(x_loc, router, wi, wg, wo):
        # x_loc: [T_loc, D] — identical across the model axis. Each
        # device applies ITS model-column's experts to ITS tokens'
        # buckets: no token movement at all; partial token outputs are
        # psum'd over `model` (the only activation collective).
        x2 = x_loc[None]                            # [1, T_loc, D]
        top_e, top_p, aux = _router(cfg, {"router": router}, x2)
        top_e, top_p = top_e[0], top_p[0]
        mi = jax.lax.axis_index(model_ax)
        e0 = mi * e_loc

        # slot-indexed dispatch: per local expert-slot, which token and
        # gate feeds it (integer scatters only — no [T*k, D] tensors)
        k = mcfg.top_k
        flat_e = top_e.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc), k)
        flat_p = top_p.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)    # bucket by expert
        se, st, sp = flat_e[order], flat_t[order], flat_p[order]
        starts = jnp.searchsorted(se, jnp.arange(e), side="left")
        pos = jnp.arange(t_loc * k) - starts[se]
        keep = pos < c
        local = keep & (se >= e0) & (se < e0 + e_loc)
        slot = jnp.where(local, (se - e0) * c + pos, e_loc * c)
        tok = jnp.zeros((e_loc * c + 1,), jnp.int32).at[slot].set(st)
        gate = jnp.zeros((e_loc * c + 1,), jnp.float32).at[slot].set(
            jnp.where(local, sp, 0.0))
        tok, gate = tok[:-1], gate[:-1]

        xe = x_loc[tok] * (gate > 0)[:, None].astype(x_loc.dtype)
        xe = xe.reshape(1, e_loc, c, d)
        # FSDP weights: explicit per-layer gather of the sharded dim
        ye = _expert_ffn(cfg, {"wi": gather_dp(wi, 1),
                               "wg": gather_dp(wg, 1),
                               "wo": gather_dp(wo, 2)},
                         xe)[0].reshape(e_loc * c, d)
        y_part = jnp.zeros((t_loc, d), ye.dtype).at[tok].add(
            ye * gate[:, None].astype(ye.dtype))
        y = jax.lax.psum(y_part, model_ax)
        aux = jax.lax.pmean(aux, dp + (model_ax,))
        return y, aux

    P = jax.sharding.PartitionSpec
    dspec = dp if len(dp) > 1 else dp[0]
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dspec, None), P(None, None),
                  P(model_ax, dspec, None), P(model_ax, dspec, None),
                  P(model_ax, None, dspec)),
        out_specs=(P(dspec, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["wi"], p["wg"], p["wo"])


# ---------------------------------------------------------------------------
# onehot (GShard einsum) dispatch — the unclustered baseline
# ---------------------------------------------------------------------------


def moe_onehot(cfg, p, x: jnp.ndarray, g: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    t, d = x.shape
    tg = t // g
    k, e = m.top_k, m.n_experts
    c = _capacity(cfg, tg)
    xg = x.reshape(g, tg, d)
    xg = shard_activation(xg, ("batch", None, None))
    top_e, top_p, aux = _router(cfg, p, xg)

    oh = jax.nn.one_hot(top_e.transpose(2, 0, 1), e,
                        dtype=jnp.int32)                    # [k,G,Tg,E]
    # position-in-expert, GShard priority order: all k=0 picks outrank
    # k=1 picks, then token order within a k level.
    csum = jnp.cumsum(oh, axis=2)                            # within k level
    totals = jnp.sum(oh, axis=2, keepdims=True)              # [k,G,1,E]
    prior = jnp.cumsum(totals, axis=0) - totals              # earlier levels
    pos = csum - oh + prior                                  # [k,G,Tg,E]
    within = jnp.sum(pos * oh, axis=-1)                      # [k,G,Tg]
    keep = (within < c) & (jnp.sum(oh, -1) > 0)
    poh = jax.nn.one_hot(within, c, dtype=x.dtype) * keep[..., None]
    ohf = oh.astype(x.dtype)
    disp = jnp.einsum("kgte,kgtc->gtec", ohf, poh)           # [G,Tg,E,C]
    xe = jnp.einsum("gtd,gtec->gecd", xg, disp)
    xe = shard_activation(xe, ("batch", "experts", None, None))
    ye = _expert_ffn(cfg, p, xe)
    ye = shard_activation(ye, ("batch", "experts", None, None))
    gates = top_p.transpose(2, 0, 1).astype(x.dtype)         # [k,G,Tg]
    comb = jnp.einsum("kgte,kgtc,kgt->gtec", ohf, poh, gates)
    y = jnp.einsum("gecd,gtec->gtd", ye, comb)
    y = shard_activation(y, ("batch", None, None))
    return y.reshape(t, d), aux


def apply_moe(cfg, p, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    if cfg.moe.dispatch == "clustered":
        ctx = sharding_ctx()
        if ctx is not None:
            y, aux = moe_clustered_shmap(cfg, p, x.reshape(t, d),
                                         ctx[0], ctx[1])
            return y.reshape(b, s, d), aux
        y, aux = moe_clustered(cfg, p, x.reshape(t, d), _n_groups(cfg, t))
        return y.reshape(b, s, d), aux
    y, aux = moe_onehot(cfg, p, x.reshape(t, d), _n_groups(cfg, t))
    return y.reshape(b, s, d), aux
