"""Shared model machinery: parameter definitions, norms, RoPE, FFN, loss.

Params are plain pytrees (nested dicts of jnp arrays). Structure is driven
by ``ParamDef`` trees so that init, logical-sharding-axes, and
ShapeDtypeStruct views are always consistent (one source of truth —
required for the dry-run, which lowers against shape trees without ever
allocating the full model).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names per dim
    init: str = "normal"              # normal | zeros | ones | <special ids>
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_defs(tree: PyTree, n: int, axis_name: str = "layers") -> PyTree:
    """Prepend a stacking (scan) dimension to every ParamDef in the tree."""
    def f(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init,
                        d.scale, d.dtype)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamDef))


def _init_one(d: ParamDef, key) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale
                ).astype(d.dtype)
    if d.init == "mamba_a_log":
        # A in [1, 16) spread deterministically per head (A = -exp(A_log))
        base = jnp.linspace(1.0, 16.0, num=d.shape[-1], dtype=jnp.float32)
        out = jnp.broadcast_to(jnp.log(base), d.shape)
        return out.astype(d.dtype)
    if d.init == "mamba_dt_bias":
        # dt ~ exp(U[log 1e-3, log 1e-1]); store inv-softplus
        lo, hi = math.log(1e-3), math.log(1e-1)
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(lo + u * (hi - lo))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs: PyTree, key) -> PyTree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def shapes_tree(defs: PyTree) -> PyTree:
    """ShapeDtypeStruct tree — lets the dry-run lower without allocation."""
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
                        defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def nonparametric_ln(x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm_defs(cfg, d: int) -> Dict[str, ParamDef]:
    if cfg.norm == "rmsnorm":
        return {"w": ParamDef((d,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        return {"w": ParamDef((d,), ("embed",), "ones"),
                "b": ParamDef((d,), ("embed",), "zeros")}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg, p: Dict, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return nonparametric_ln(x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    ang = ang[..., None, :]                          # [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings [n, d]."""
    log_timescale = math.log(10000.0) / max(d // 2 - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
    t = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_defs(cfg, d: int, dff: int) -> Dict[str, ParamDef]:
    if cfg.glu:
        return {
            "wi": ParamDef((d, dff), ("embed", "ff"), "normal",
                           scale=0.02),
            "wg": ParamDef((d, dff), ("embed", "ff"), "normal",
                           scale=0.02),
            "wo": ParamDef((dff, d), ("ff", "embed"), "normal",
                           scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
        }
    return {
        "wi": ParamDef((d, dff), ("embed", "ff"), "normal", scale=0.02),
        "wo": ParamDef((dff, d), ("ff", "embed"), "normal",
                       scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def apply_ffn(cfg, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = x @ p["wi"].astype(dt)
    if cfg.glu:
        h = act(x @ p["wg"].astype(dt)) * h
    else:
        h = act(h)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Vocab padding + loss
# ---------------------------------------------------------------------------


def padded_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  vocab_size: int) -> jnp.ndarray:
    """Mean next-token CE. logits: [B,S,Vp] (padded vocab masked out)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp != vocab_size:
        neg = jnp.full((vp - vocab_size,), -1e9, jnp.float32)
        logits = logits.at[..., vocab_size:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
