"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings [B, n_frames, d_model]. Positions are
sinusoidal (whisper's encoder is sinusoidal; we use sinusoidal on the
decoder too so any decode length lowers with O(1) params).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.lm import maybe_scan
from repro.models.common import (ParamDef, apply_ffn, apply_norm,
                                 cross_entropy, dtype_of, ffn_defs,
                                 init_params, norm_defs, padded_vocab,
                                 shapes_tree, sinusoidal_positions,
                                 stack_defs)

PyTree = Any


class EncDecLM:
    def __init__(self, cfg):
        assert cfg.encdec is not None
        self.cfg = cfg
        self.vp = padded_vocab(cfg.vocab_size)
        self._defs = self._param_defs()

    # ------------------------------------------------------------- defs --
    def _enc_block_defs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": norm_defs(cfg, d), "attn": attn.attn_defs(cfg, d),
                "ln2": norm_defs(cfg, d), "ffn": ffn_defs(cfg, d, cfg.d_ff)}

    def _dec_block_defs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {"ln1": norm_defs(cfg, d), "self_attn": attn.attn_defs(cfg, d),
                "ln2": norm_defs(cfg, d), "cross_attn": attn.attn_defs(cfg, d),
                "ln3": norm_defs(cfg, d), "ffn": ffn_defs(cfg, d, cfg.d_ff)}

    def _param_defs(self):
        cfg = self.cfg
        return {
            "embed": ParamDef((self.vp, cfg.d_model), ("vocab", "embed"),
                              "normal"),
            "enc_blocks": stack_defs(self._enc_block_defs(),
                                     cfg.encdec.encoder_layers),
            "enc_norm": norm_defs(cfg, cfg.d_model),
            "dec_blocks": stack_defs(self._dec_block_defs(), cfg.n_layers),
            "final_norm": norm_defs(cfg, cfg.d_model),
        }

    def param_defs(self):
        return self._defs

    def init(self, key):
        return init_params(self._defs, key)

    def param_shapes(self):
        return shapes_tree(self._defs)

    # ------------------------------------------------------------ encode --
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: [B, F, D] (stub frontend output) -> [B, F, D]."""
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        b, f, d = frames.shape
        x = frames.astype(dt) + sinusoidal_positions(f, d).astype(dt)[None]
        positions = jnp.arange(f)[None, :]

        def body(x, p):
            h = apply_norm(cfg, p["ln1"], x)
            q, k, v = attn.qkv(cfg, p["attn"], h, positions, rope=False)
            o = attn.attention(cfg, q, k, v, causal=False)
            x = x + jnp.einsum("bshe,hed->bsd", o,
                               p["attn"]["wo"].astype(x.dtype))
            x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
            return x, None

        x, _ = maybe_scan(cfg, body, x, params["enc_blocks"])
        return apply_norm(cfg, params["enc_norm"], x)

    # ------------------------------------------------------------ decode --
    def _dec_block(self, p, x, mem, positions):
        cfg = self.cfg
        h = apply_norm(cfg, p["ln1"], x)
        q, k, v = attn.qkv(cfg, p["self_attn"], h, positions, rope=False)
        o = attn.attention(cfg, q, k, v, causal=True)
        x = x + jnp.einsum("bshe,hed->bsd", o,
                           p["self_attn"]["wo"].astype(x.dtype))
        h = apply_norm(cfg, p["ln2"], x)
        qc = jnp.einsum("bsd,dhe->bshe", h, p["cross_attn"]["wq"].astype(
            x.dtype))
        kc = jnp.einsum("bfd,dhe->bfhe", mem, p["cross_attn"]["wk"].astype(
            x.dtype))
        vc = jnp.einsum("bfd,dhe->bfhe", mem, p["cross_attn"]["wv"].astype(
            x.dtype))
        oc = attn.attention(cfg, qc, kc, vc, causal=False)
        x = x + jnp.einsum("bshe,hed->bsd", oc,
                           p["cross_attn"]["wo"].astype(x.dtype))
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln3"], x))
        return x

    def apply(self, params, tokens, frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        mem = self.encode(params, frames)
        b, s = tokens.shape
        x = params["embed"].astype(dt)[tokens]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
        positions = jnp.arange(s)[None, :]

        def body(x, p):
            return self._dec_block(p, x, mem, positions), None

        x, _ = maybe_scan(cfg, body, x, params["dec_blocks"])
        x = apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["embed"].T.astype(dt)   # whisper ties head
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch) -> jnp.ndarray:
        logits, _ = self.apply(params, batch["tokens"], batch["frames"])
        return cross_entropy(logits, batch["labels"], self.cfg.vocab_size)

    # ------------------------------------------------------------- cache --
    def cache_defs(self, batch: int, max_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim()
        dt = dtype_of(cfg.dtype)
        f = cfg.encdec.n_frames
        ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        axf = ("layers", "batch", "frames", "kv_heads", "head_dim")
        L = cfg.n_layers
        return {
            "self_kv": {
                "k": ParamDef((L, batch, max_len, cfg.n_kv_heads, hd), ax,
                              "zeros", dtype=dt),
                "v": ParamDef((L, batch, max_len, cfg.n_kv_heads, hd), ax,
                              "zeros", dtype=dt)},
            "cross_kv": {
                "k": ParamDef((L, batch, f, cfg.n_kv_heads, hd), axf,
                              "zeros", dtype=dt),
                "v": ParamDef((L, batch, f, cfg.n_kv_heads, hd), axf,
                              "zeros", dtype=dt)},
        }

    def init_cache(self, batch: int, max_len: int):
        return init_params(self.cache_defs(batch, max_len),
                           jax.random.PRNGKey(0))

    def cache_shapes(self, batch: int, max_len: int):
        return shapes_tree(self.cache_defs(batch, max_len))

    def prefill(self, params, tokens, frames):
        """Encode + fill cross-attn KV + run decoder over prompt tokens."""
        cfg = self.cfg
        mem = self.encode(params, frames)

        def kv(p):
            kc = jnp.einsum("bfd,dhe->bfhe", mem,
                            p["cross_attn"]["wk"].astype(mem.dtype))
            vc = jnp.einsum("bfd,dhe->bfhe", mem,
                            p["cross_attn"]["wv"].astype(mem.dtype))
            return kc, vc

        ks, vs = jax.vmap(kv)(params["dec_blocks"])
        logits, _ = self.apply(params, tokens, frames)
        b, s = tokens.shape
        cache = self.init_cache(b, s)
        cache["cross_kv"] = {"k": ks, "v": vs}
        return logits[:, -1:], cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        dt = dtype_of(cfg.dtype)
        x = params["embed"].astype(dt)[tokens]
        # sinusoidal embedding evaluated at the current absolute position
        import math as _m
        half = cfg.d_model // 2
        inv = jnp.exp(-(_m.log(10000.0) / max(half - 1, 1))
                      * jnp.arange(half, dtype=jnp.float32))
        ang = pos.astype(jnp.float32) * inv
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pe.astype(dt)[None, None, :]
        positions = jnp.full(tokens.shape, pos)

        def f(x, xs):
            p, ck, cv, xk, xv = xs
            h = apply_norm(cfg, p["ln1"], x)
            q, k, v = attn.qkv(cfg, p["self_attn"], h, positions, rope=False)
            ck, cv = attn.cache_update(ck, cv, k, v, pos)
            o = attn.decode_attention(cfg, q, ck, cv, pos)
            x = x + jnp.einsum("bshe,hed->bsd", o,
                               p["self_attn"]["wo"].astype(x.dtype))
            h = apply_norm(cfg, p["ln2"], x)
            qc = jnp.einsum("bsd,dhe->bshe", h,
                            p["cross_attn"]["wq"].astype(x.dtype))
            kx = attn.expand_kv(xk, cfg.n_heads)
            vx = attn.expand_kv(xv, cfg.n_heads)
            import math as _m
            lg = jnp.einsum("bqhd,bthd->bhqt", qc, kx).astype(jnp.float32)
            lg = lg / _m.sqrt(qc.shape[-1])
            w = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
            oc = jnp.einsum("bhqt,bthd->bqhd", w, vx)
            x = x + jnp.einsum("bshe,hed->bsd", oc,
                               p["cross_attn"]["wo"].astype(x.dtype))
            x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln3"], x))
            return x, (ck, cv)

        x, (ks, vs) = maybe_scan(
            cfg, f, x, (params["dec_blocks"], cache["self_kv"]["k"],
                   cache["self_kv"]["v"], cache["cross_kv"]["k"],
                   cache["cross_kv"]["v"]))
        new_cache = {"self_kv": {"k": ks, "v": vs},
                     "cross_kv": cache["cross_kv"]}
        x = apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["embed"].T.astype(dt)
        return logits, new_cache
