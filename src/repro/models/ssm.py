"""Mamba2 (SSD — state-space duality) block, TPU-native chunked form.

The SSD algorithm (arXiv:2405.21060) is implemented in its matmul
("quadratic-within-chunk, recurrent-across-chunks") form: within a chunk the
output is an attention-like masked gram product (MXU work); across chunks a
small [H, P, N] state is carried by a lax.scan. This is the right mapping for
the TPU memory hierarchy — the chunk working set lives in VMEM and the
cross-chunk state is tiny — as opposed to the GPU implementation's
warp-parallel selective scan, which has no TPU analogue (DESIGN.md §3).

Decode is the O(1) recurrence: h' = exp(dt*A) h + dt * B ⊗ x.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, rmsnorm


def ssm_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.headdim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nheads, conv_dim


def ssm_defs(cfg) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nheads, conv_dim = ssm_dims(cfg)
    # in_proj emits [z (d_in), x (d_in), B (G*N), C (G*N), dt (H)]
    out_dim = 2 * d_in + 2 * s.n_groups * s.d_state + nheads
    return {
        "in_proj": ParamDef((d, out_dim), ("embed", "ssm_inner"), "normal"),
        "conv_w": ParamDef((s.d_conv, conv_dim), (None, "ssm_inner"),
                           "normal", scale=0.2),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), "zeros"),
        "a_log": ParamDef((nheads,), ("ssm_heads",), "mamba_a_log"),
        "dt_bias": ParamDef((nheads,), ("ssm_heads",), "mamba_dt_bias"),
        "d_skip": ParamDef((nheads,), ("ssm_heads",), "ones"),
        "norm_w": ParamDef((d_in,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((d_in, d), ("ssm_inner", "embed"), "normal",
                             scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_in, nheads, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, b, c, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, b, c, dt


def _causal_conv(conv_w, conv_b, xbc, state=None):
    """Depthwise causal conv. xbc: [B,S,C]; conv_w: [K,C].

    state (decode): [B, K-1, C] previous inputs; returns (out, new_state).
    """
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
        full = jnp.concatenate([pad, xbc], axis=1)
        out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
                  for i in range(k))
        return jax.nn.silu(out + conv_b.astype(xbc.dtype)), None
    # decode: xbc is [B,1,C]
    full = jnp.concatenate([state, xbc], axis=1)          # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", full, conv_w.astype(xbc.dtype))[:, None]
    new_state = full[:, 1:]
    return jax.nn.silu(out + conv_b.astype(xbc.dtype)), new_state


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """SSD scan. x:[B,S,H,P] dt:[B,S,H] b,c:[B,S,G,N] -> [B,S,H,P].

    Chunked matmul form. The lax.scan over chunks carries the [B,H,P,N]
    state AND computes the within-chunk quadratic term, so only one
    chunk's [B,Q,Q,H] gram/decay tensors are ever live (VMEM-sized).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, s)
    if s % chunk:
        # pad to a chunk multiple; padded steps have dt=0 => exp(0) decay
        # and zero dt-weighted input, so they do not perturb the state.
        pad = chunk - s % chunk
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        out = ssd_chunked(zp(x), zp(dt), a_log, zp(b), zp(c), chunk)
        return out[:, :s]
    nc = s // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))               # [H], negative
    dta = (dt * a[None, None, :]).astype(jnp.float32)     # [B,S,H]
    xdt = (x.astype(jnp.float32) * dt[..., None])         # dt-weighted input

    def r(t):  # reshape into chunks: [nc, B, chunk, ...]
        return t.reshape((bsz, nc) + (chunk,) + t.shape[2:]).swapaxes(0, 1)

    xc, dtac = r(xdt), r(dta)
    bh = r(b.astype(jnp.float32))                          # [nc,B,Q,G,N]
    ch = r(c.astype(jnp.float32))
    if g != h:
        bh = jnp.repeat(bh, rep, axis=3)                   # [nc,B,Q,H,N]
        ch = jnp.repeat(ch, rep, axis=3)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_body(h_prev, inp):
        xi, dti, bi, ci = inp                              # per-chunk slices
        seg = jnp.cumsum(dti, axis=1)                      # [B,Q,H]
        total = seg[:, -1]                                 # [B,H]
        # within-chunk: masked decay gram (MXU-friendly matmul form)
        diff = seg[:, :, None, :] - seg[:, None, :, :]     # [B,Q,Q,H]
        decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bqhn,bkhn->bqkh", ci, bi)         # [B,Q,Q,H]
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", cb * decay, xi)
        # inter-chunk: read the carried state
        decay_from_start = jnp.exp(seg)                    # [B,Q,H]
        y_off = jnp.einsum("bqhn,bqh,bhpn->bqhp",
                           ci, decay_from_start, h_prev)
        # update state with this chunk's contribution
        decay_to_end = jnp.exp(total[:, None, :] - seg)    # [B,Q,H]
        states = jnp.einsum("bqhn,bqh,bqhp->bhpn", bi, decay_to_end, xi)
        h_new = h_prev * jnp.exp(total)[..., None, None] + states
        return h_new, (y_diag + y_off)

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, y = jax.lax.scan(scan_body, h0, (xc, dtac, bh, ch))
    return y.swapaxes(0, 1).reshape(bsz, s, h, p).astype(x.dtype)


def apply_ssm_block(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    """Full mamba2 mixer. x: [B,S,D] -> [B,S,D] (train/prefill path)."""
    s = cfg.ssm
    d_in, nheads, conv_dim = ssm_dims(cfg)
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xin, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, b, c], axis=-1)
    xbc, _ = _causal_conv(p["conv_w"], p["conv_b"], xbc)
    xin, b, c = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    bsz, slen, _ = x.shape
    xh = xin.reshape(bsz, slen, nheads, s.headdim)
    bg = b.reshape(bsz, slen, s.n_groups, s.d_state)
    cg = c.reshape(bsz, slen, s.n_groups, s.d_state)
    dth = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    y = ssd_chunked(xh, dth, p["a_log"], bg, cg, s.chunk)
    y = y + p["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(bsz, slen, d_in)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_)


# ---------------------------------------------------------------------------
# Decode (O(1) state recurrence)
# ---------------------------------------------------------------------------


def ssm_cache_defs(cfg, batch: int, n_layers: int) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d_in, nheads, conv_dim = ssm_dims(cfg)
    return {
        "h": ParamDef((n_layers, batch, nheads, s.headdim, s.d_state),
                      ("layers", "batch", "ssm_heads", None, None), "zeros",
                      dtype=jnp.float32),
        "conv": ParamDef((n_layers, batch, s.d_conv - 1, conv_dim),
                         ("layers", "batch", None, "ssm_inner"), "zeros",
                         dtype=jnp.bfloat16),
    }


def ssm_decode_step(cfg, p, x, h, conv_state):
    """x: [B,1,D]; h: [B,H,P,N]; conv_state: [B,K-1,C]."""
    s = cfg.ssm
    d_in, nheads, _ = ssm_dims(cfg)
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xin, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, b, c], axis=-1)
    xbc, conv_state = _causal_conv(p["conv_w"], p["conv_b"], xbc,
                                   conv_state.astype(dt_))
    xin, b, c = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    bsz = x.shape[0]
    xh = xin.reshape(bsz, nheads, s.headdim).astype(jnp.float32)
    bg = b.reshape(bsz, s.n_groups, s.d_state).astype(jnp.float32)
    cg = c.reshape(bsz, s.n_groups, s.d_state).astype(jnp.float32)
    rep = nheads // s.n_groups
    bh = jnp.repeat(bg, rep, axis=1)                      # [B,H,N]
    chd = jnp.repeat(cg, rep, axis=1)
    dth = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dth * a[None])                           # [B,H]
    h = h * da[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dth, xh, bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, chd)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, d_in).astype(dt_)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_), h, conv_state
