"""AdamW with warmup+cosine schedule and global-norm clipping.

Optimizer state (mu, nu) reuses the parameters' logical sharding axes, so
under the default FSDP ruleset the state is fully sharded over both the DP
and model axes — ZeRO-3 equivalent, no extra machinery needed. (The
``zero`` flag in OptimizerConfig selects the FSDP ruleset vs ``no_fsdp``
in the launcher.)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray       # int32 scalar
    mu: PyTree
    nu: PyTree


def schedule(ocfg, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10% of peak."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(ocfg.warmup_steps, 1),
                       1.0)
    t = jnp.clip((step.astype(jnp.float32) - ocfg.warmup_steps)
                 / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return ocfg.lr * warm * cos


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), gn


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def state_shapes(param_shapes: PyTree) -> AdamWState:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(zeros, param_shapes),
                      nu=jax.tree.map(zeros, param_shapes))


def state_axes(param_axes: PyTree) -> AdamWState:
    """Logical axes for the state: mirror the params (ZeRO-3 via FSDP)."""
    return AdamWState(step=(),
                      mu=jax.tree.map(lambda a: a, param_axes),
                      nu=jax.tree.map(lambda a: a, param_axes))


def update(ocfg, grads: PyTree, state: AdamWState, params: PyTree
           ) -> Tuple[PyTree, AdamWState, Dict[str, jnp.ndarray]]:
    b1, b2 = ocfg.betas
    step = state.step + 1
    lr = schedule(ocfg, step)
    grads, gn = clip_by_global_norm(grads, ocfg.grad_clip)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        delta = delta + ocfg.weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = tdef.unflatten([o[0] for o in out])
    mu = tdef.unflatten([o[1] for o in out])
    nu = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gn}
    return updates, AdamWState(step, mu, nu), metrics


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
