"""The one documented stats-dict schema every layer reports through.

Before this module, the repo had two drifting ``merged_stats()``
conventions — the scheduler returned a mixed int/float dict whose
derived ratio was recomputed by hand at three call sites
(``TaskScheduler.merged_stats``, ``MiningRun.finalize``,
``cluster.merge_metrics``), while the serving layer's
``PatternServer.merged_stats`` returned bare query counters with its
own derived total. This module is now the single place those shapes
are defined: COUNTER keys are monotonic ints (summable across workers,
hosts, and deltas), DERIVED keys are floats recomputed from counters
after any merge/delta — never summed, never subtracted.

Builders (``scheduler_stats``/``device_stats``/``query_stats``/
``host_stats``) take a raw counter mapping and return a fully-typed
dict with every schema key present; ``validate`` checks an arbitrary
dict against a schema (the tests run both real producers through it).
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Tuple

__all__ = [
    "SCHEDULER_COUNTERS", "SCHEDULER_DERIVED",
    "DEVICE_ID_KEYS", "DEVICE_COUNTERS", "DEVICE_DERIVED",
    "QUERY_COUNTERS", "QUERY_DERIVED",
    "HOST_ID_KEYS", "HOST_COUNTERS", "HOST_DERIVED",
    "scheduler_stats", "device_stats", "query_stats", "host_stats",
    "merge_counters", "delta_counters", "validate",
]

# ---- scheduler: TaskScheduler.merged_stats / MiningMetrics.scheduler --
SCHEDULER_COUNTERS: Tuple[str, ...] = (
    "tasks_run", "spawned", "steals", "tasks_stolen", "steal_attempts",
    "bucket_switches", "steal_migrations", "rows_touched",
    "bytes_swept", "sweeps_submitted", "dense_sweeps", "sparse_sweeps",
    "sparse_bytes_swept",
)
SCHEDULER_DERIVED: Tuple[str, ...] = ("tasks_per_steal",)

# ---- per-device: dispatcher gauges / MiningMetrics.per_device rows --
DEVICE_ID_KEYS: Tuple[str, ...] = ("device",)      # +"host" in cluster rows
DEVICE_COUNTERS: Tuple[str, ...] = (
    "flushes", "sweep_requests", "query_requests", "queue_flushes",
    "queue_requests",
)
DEVICE_DERIVED: Tuple[str, ...] = ("batch_occupancy", "sweep_s")

# ---- serving: PatternServer.merged_stats / TenantHub.tenant_stats --
QUERY_COUNTERS: Tuple[str, ...] = ("hit", "sweep", "top_k")
QUERY_DERIVED: Tuple[str, ...] = ("queries",)       # int derived: sum

# ---- per-host: cluster merge_metrics MiningMetrics.per_host rows --
HOST_ID_KEYS: Tuple[str, ...] = ("host",)
HOST_COUNTERS: Tuple[str, ...] = ("bytes_swept", "eval_bytes")
HOST_DERIVED: Tuple[str, ...] = ("sweep_s", "eval_s")


def scheduler_stats(raw: Mapping[str, Any]) -> Dict[str, Any]:
    """Normalize scheduler counters; recompute the derived ratio."""
    out: Dict[str, Any] = {k: int(raw.get(k, 0))
                           for k in SCHEDULER_COUNTERS}
    out["tasks_per_steal"] = (out["tasks_stolen"]
                              / max(out["steals"], 1))
    return out


def device_stats(raw: Mapping[str, Any]) -> Dict[str, Any]:
    """Normalize one dispatcher's gauge row (``device`` id preserved,
    ``host`` passed through when a cluster merge stamped one)."""
    out: Dict[str, Any] = {"device": int(raw.get("device", 0))}
    if "host" in raw:
        out["host"] = int(raw["host"])
    for k in DEVICE_COUNTERS:
        out[k] = int(raw.get(k, 0))
    out["batch_occupancy"] = (out["sweep_requests"] / out["flushes"]
                              if out["flushes"] else 0.0)
    out["sweep_s"] = float(raw.get("sweep_s", 0.0))
    return out


def query_stats(raw: Mapping[str, Any]) -> Dict[str, Any]:
    """Normalize per-kind query counters; ``queries`` is their sum."""
    out: Dict[str, Any] = {k: int(raw.get(k, 0)) for k in QUERY_COUNTERS}
    out["queries"] = sum(out[k] for k in QUERY_COUNTERS)
    return out


def host_stats(raw: Mapping[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {"host": int(raw.get("host", 0))}
    for k in HOST_COUNTERS:
        out[k] = int(raw.get(k, 0))
    for k in HOST_DERIVED:
        out[k] = float(raw.get(k, 0.0))
    return out


def merge_counters(rows, counters: Tuple[str, ...]) -> Dict[str, int]:
    """Sum counter keys across rows (derived keys are NOT summable —
    rebuild them with the schema builder afterwards)."""
    out = {k: 0 for k in counters}
    for row in rows:
        for k in counters:
            out[k] += int(row.get(k, 0))
    return out


def delta_counters(now: Mapping[str, Any], base: Mapping[str, Any],
                   counters: Tuple[str, ...]) -> Dict[str, int]:
    """now − base over counter keys only (a derived ratio's delta is
    meaningless; rebuild it from the counter deltas)."""
    return {k: int(now.get(k, 0)) - int(base.get(k, 0))
            for k in counters}


_SCHEMAS = {
    "scheduler": ((), SCHEDULER_COUNTERS, SCHEDULER_DERIVED),
    "device": (DEVICE_ID_KEYS, DEVICE_COUNTERS, DEVICE_DERIVED),
    "query": ((), QUERY_COUNTERS, QUERY_DERIVED),
    "host": (HOST_ID_KEYS, HOST_COUNTERS, HOST_DERIVED),
}


def validate(kind: str, stats: Mapping[str, Any]) -> None:
    """Raise ``ValueError`` unless ``stats`` carries every schema key
    with the schema type (counters int, derived float — ``query``'s
    derived total is an int sum). Extra keys: only ``host`` on device
    rows (the cluster merge's stamp)."""
    ids, counters, derived = _SCHEMAS[kind]
    for k in ids + counters:
        if k not in stats:
            raise ValueError(f"{kind} stats missing key {k!r}")
        if not isinstance(stats[k], int) or isinstance(stats[k], bool):
            raise ValueError(
                f"{kind} stats key {k!r} must be int, "
                f"got {type(stats[k]).__name__}")
    for k in derived:
        if k not in stats:
            raise ValueError(f"{kind} stats missing derived key {k!r}")
        want = int if (kind, k) == ("query", "queries") else float
        if not isinstance(stats[k], want):
            raise ValueError(
                f"{kind} stats derived key {k!r} must be "
                f"{want.__name__}, got {type(stats[k]).__name__}")
    allowed = set(ids) | set(counters) | set(derived)
    if kind == "device":
        allowed.add("host")
    extra = set(stats) - allowed
    if extra:
        raise ValueError(f"{kind} stats has off-schema keys {sorted(extra)}")
