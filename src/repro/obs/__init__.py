"""Observability: ring-buffer tracing, exporters, metrics, stats schema.

Usage (batch mining)::

    from repro.obs import Tracer, write_chrome_trace, summary_table
    tr = Tracer()
    supports, met = mine(bitmaps, min_support, trace=tr)
    write_chrome_trace(tr, "mine.trace.json")   # open in ui.perfetto.dev
    print(summary_table(tr, wall_s=met.wall_s))

Tracing is off by default: every instrumented site holds a tracer
reference that is ``None`` unless the caller passed one, so the
disabled fast path is a single ``is not None`` test. See
``repro.obs.tracer`` for the ring-buffer design, ``repro.obs.schema``
for the unified merged-stats schema, ``repro.obs.registry`` for the
pull-based metrics snapshot API.
"""
from repro.obs.export import (  # noqa: F401
    check_nesting, chrome_trace, summary_table, time_in_state,
    write_chrome_trace,
)
from repro.obs.registry import LatencyRecorder, MetricsRegistry  # noqa: F401
from repro.obs.tracer import TraceEvent, Tracer  # noqa: F401
from repro.obs import schema  # noqa: F401

__all__ = [
    "Tracer", "TraceEvent", "chrome_trace", "write_chrome_trace",
    "summary_table", "time_in_state", "check_nesting",
    "MetricsRegistry", "LatencyRecorder", "schema",
]
