"""Pull-based metrics: a snapshot registry + an exact-quantile recorder.

``MetricsRegistry`` is deliberately passive — sources register a
zero-arg callable and ``snapshot()`` pulls them all under no shared
lock (each source guards its own state). That keeps the hot paths free
of any push-side bookkeeping: the scheduler/dispatcher/arena already
maintain their counters; the registry just knows how to read them.

``LatencyRecorder`` backs the serving-layer histogram. Samples land in
a per-kind bounded deque (drop-oldest beyond ``cap``), so p50/p95/p99
are EXACT over the retained window — no bucketing error — at the cost
of one lock + append per query, which is noise next to even a 5µs
snapshot hit.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional

__all__ = ["MetricsRegistry", "LatencyRecorder"]


class MetricsRegistry:
    """Named gauge sources, snapshotted on demand."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._sources: Dict[str, Callable[[], Any]] = {}

    def register(self, name: str, fn: Callable[[], Any]) -> None:
        with self._lock:
            self._sources[name] = fn

    def unregister(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._sources)

    def snapshot(self) -> Dict[str, Any]:
        """Pull every source once; a failing source reports its error
        instead of poisoning the snapshot."""
        with self._lock:
            sources = list(self._sources.items())
        out: Dict[str, Any] = {}
        for name, fn in sources:
            try:
                out[name] = fn()
            except Exception as e:  # pragma: no cover - defensive
                out[name] = {"error": f"{type(e).__name__}: {e}"}
        return out


def _percentile(sorted_xs: List[float], q: float) -> float:
    """Exact nearest-rank percentile over a sorted sample list."""
    if not sorted_xs:
        return 0.0
    n = len(sorted_xs)
    k = max(0, min(n - 1, int(round(q / 100.0 * (n - 1)))))
    return sorted_xs[k]


class LatencyRecorder:
    """Per-kind latency samples with exact p50/p95/p99.

    ``record(kind, seconds, n)`` books ``n`` queries that each took
    ``seconds`` (a batched call records its per-query share). The
    window keeps the most recent ``cap`` samples per kind.
    """

    def __init__(self, cap: int = 100_000):
        self.cap = int(cap)
        self._lock = threading.Lock()
        self._samples: Dict[str, deque] = {}
        self._count: Dict[str, int] = {}

    def record(self, kind: str, seconds: float, n: int = 1) -> None:
        if n <= 0:
            return
        with self._lock:
            d = self._samples.get(kind)
            if d is None:
                d = self._samples[kind] = deque(maxlen=self.cap)
                self._count[kind] = 0
            if n == 1:
                d.append(seconds)
            else:
                d.extend([seconds] * n)
            self._count[kind] += n

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._count)

    def percentiles(self, kind: Optional[str] = None) -> Dict[str, Any]:
        """{kind: {n, p50, p95, p99, max}} (seconds), or one kind's row."""
        with self._lock:
            items = [(k, list(d)) for k, d in self._samples.items()
                     if kind is None or k == kind]
            counts = dict(self._count)
        out: Dict[str, Any] = {}
        for k, xs in items:
            xs.sort()
            out[k] = {
                "n": counts.get(k, len(xs)),
                "p50": _percentile(xs, 50.0),
                "p95": _percentile(xs, 95.0),
                "p99": _percentile(xs, 99.0),
                "max": xs[-1] if xs else 0.0,
            }
        if kind is not None:
            return out.get(kind, {"n": 0, "p50": 0.0, "p95": 0.0,
                                  "p99": 0.0, "max": 0.0})
        return out
