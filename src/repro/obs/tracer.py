"""Lock-minimal per-worker ring-buffer tracer.

Every thread that emits events owns a private ``_Ring`` — a fixed-size
circular buffer reached through ``threading.local`` — so the record
path takes NO lock: one ``perf_counter`` read, one tuple build, one
list-slot store. The tracer's global lock is touched only when a
thread registers its ring (once per thread) and at collection time.
When a ring fills, new events overwrite the oldest (drop-oldest); the
``dropped`` counter keeps the loss honest.

The disabled fast path is structural, not a flag check inside the
tracer: instrumentation sites hold ``tracer = None`` and guard with
``if tr is not None`` — one local load and an identity test, so an
untraced run pays nothing per event. A constructed ``Tracer`` is
always live.

Spans are recorded as *complete* events at span end (Chrome trace
``ph="X"``): the site captures ``t0 = tracer.now()`` before the work
and calls ``tracer.span(name, t0)`` after, which stamps the duration.
That makes one ring append per span and means per-lane append order is
span *end* order — sorting by start time (ties: longer first)
reconstructs the nesting, which is how the exporter's time-in-state
accounting works.

Lanes map onto Chrome trace (pid, tid): ``pid`` is the host rank
(cluster mode gives every host its own process row in Perfetto) and
``tid`` is a per-ring serial; ``set_lane`` names the calling thread's
lane ("worker-3", "dispatcher-0", "driver", ...) and pins its sort
position.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Tuple

__all__ = ["Tracer", "TraceEvent"]


class TraceEvent(NamedTuple):
    """One collected event, flattened with its lane identity.

    ``ts``/``dur`` are seconds relative to the tracer epoch; the
    Chrome exporter converts to µs. ``ph`` follows the trace-event
    format: "X" complete span, "I" instant, "C" counter.
    """

    ph: str
    name: str
    cat: str
    ts: float
    dur: float
    args: Optional[Dict[str, Any]]
    pid: int
    tid: int
    lane: str


class _Ring:
    """Single-writer circular event buffer (one owner thread)."""

    __slots__ = ("cap", "buf", "idx", "n", "tid", "name", "pid", "sort")

    def __init__(self, cap: int, tid: int, name: str, pid: int = 0,
                 sort: Optional[int] = None):
        self.cap = cap
        self.buf: List[Optional[tuple]] = [None] * cap
        self.idx = 0        # next write slot
        self.n = 0          # total events ever appended
        self.tid = tid
        self.name = name
        self.pid = pid
        self.sort = sort

    def append(self, ev: tuple) -> None:
        i = self.idx
        self.buf[i] = ev
        self.idx = 0 if i + 1 == self.cap else i + 1
        self.n += 1

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.cap)

    def snapshot(self) -> List[tuple]:
        """Events in append order, oldest first (last ``cap`` kept)."""
        if self.n <= self.cap:
            return [e for e in self.buf[: self.idx] if e is not None]
        i = self.idx
        return [e for e in self.buf[i:] + self.buf[:i] if e is not None]


class Tracer:
    """Collects span/instant/counter events into per-thread rings.

    Record methods (``span``/``instant``/``counter``) are safe from any
    thread and lock-free after the thread's first event. Collection
    (``events()``/``rings()``) merges all rings preserving each lane's
    internal order; it is meant to run at quiescence (after
    ``mine()``/``refresh()`` returns) but tolerates concurrent writers
    — a torn read can at worst miss or duplicate boundary events, never
    corrupt collected tuples.
    """

    def __init__(self, ring_size: int = 65536):
        if ring_size < 8:
            raise ValueError("ring_size must be >= 8")
        self.ring_size = int(ring_size)
        self._epoch = time.perf_counter()
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._rings: List[_Ring] = []
        self._next_tid = 1

    # ---- record path -------------------------------------------------

    def now(self) -> float:
        return time.perf_counter()

    def _new_ring(self, name: str, pid: int = 0,
                  sort: Optional[int] = None) -> _Ring:
        with self._lock:
            r = _Ring(self.ring_size, self._next_tid, name, pid, sort)
            self._next_tid += 1
            self._rings.append(r)
        self._tls.ring = r
        return r

    def _ring(self) -> _Ring:
        r = getattr(self._tls, "ring", None)
        if r is None:
            r = self._new_ring(threading.current_thread().name)
        return r

    def set_lane(self, name: str, sort_index: Optional[int] = None,
                 pid: int = 0) -> None:
        """Name the calling thread's lane (idempotent, renames in place)."""
        r = getattr(self._tls, "ring", None)
        if r is None:
            self._new_ring(name, pid, sort_index)
        else:
            r.name, r.pid = name, pid
            if sort_index is not None:
                r.sort = sort_index

    def span(self, name: str, t0: float, cat: str = "span",
             args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete span that started at ``t0 = tracer.now()``."""
        t1 = time.perf_counter()
        self._ring().append(("X", name, cat, t0 - self._epoch, t1 - t0, args))

    def instant(self, name: str, cat: str = "event",
                args: Optional[Dict[str, Any]] = None) -> None:
        ts = time.perf_counter() - self._epoch
        self._ring().append(("I", name, cat, ts, 0.0, args))

    def counter(self, name: str, values: Dict[str, Any]) -> None:
        """Record a counter sample (Perfetto draws these as tracks)."""
        ts = time.perf_counter() - self._epoch
        self._ring().append(("C", name, "counter", ts, 0.0, dict(values)))

    # ---- collection --------------------------------------------------

    def rings(self) -> List[_Ring]:
        with self._lock:
            rs = list(self._rings)
        rs.sort(key=lambda r: (r.pid, r.sort if r.sort is not None else 1 << 30,
                               r.tid))
        return rs

    def events(self) -> List[TraceEvent]:
        """All events, lane by lane, per-lane append order preserved."""
        out: List[TraceEvent] = []
        for r in self.rings():
            for ph, name, cat, ts, dur, args in r.snapshot():
                out.append(TraceEvent(ph, name, cat, ts, dur, args,
                                      r.pid, r.tid, r.name))
        return out

    def dropped(self) -> int:
        return sum(r.dropped for r in self.rings())

    def lanes(self) -> List[Tuple[int, int, str]]:
        """(pid, tid, name) per registered lane, display order."""
        return [(r.pid, r.tid, r.name) for r in self.rings()]

    def lane_names(self) -> List[str]:
        return [r.name for r in self.rings()]
