"""Trace exporters: Chrome trace-event JSON + terminal time-in-state.

``chrome_trace``/``write_chrome_trace`` emit the Chrome trace-event
format (the ``{"traceEvents": [...]}`` object form) that
https://ui.perfetto.dev loads directly: one process row per host
(``pid`` = host rank), one thread lane per ring (``tid``), span events
as ``ph="X"`` with µs timestamps, counters as ``ph="C"``. Lane names
and ordering travel as ``"M"`` metadata events.

``time_in_state`` turns each lane's spans into per-state self-time:
spans are sorted by start (ties: longer first) and walked with an
interval stack so a nested span's duration is billed to ITS category
and subtracted from the parent's — a worker's "task" span containing a
blocking "sweep" span yields eval = task − sweep. Categories map to
the summary states: task→eval, sweep/flush→sweep, idle→idle,
steal→steal, everything else→other. ``summary_table`` renders that per
worker with a coverage column against ``wall_s``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.tracer import TraceEvent, Tracer

__all__ = [
    "chrome_trace", "write_chrome_trace", "time_in_state",
    "summary_table", "check_nesting", "STATE_OF_CAT",
]

# span category -> summary state
STATE_OF_CAT = {
    "task": "eval",
    "level": "eval",
    "sweep": "sweep",
    "flush": "sweep",
    "net": "sweep",
    "arena": "sweep",
    "idle": "idle",
    "steal": "steal",
}
STATES = ("eval", "sweep", "idle", "steal", "other")


def chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    """Build the Chrome trace-event JSON object for ``tracer``."""
    evs = tracer.events()
    out: List[Dict[str, Any]] = []
    seen_pids = set()
    for pid, tid, name in tracer.lanes():
        if pid not in seen_pids:
            seen_pids.add(pid)
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": f"host-{pid}"}})
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": name}})
    for i, (pid, tid, _name) in enumerate(tracer.lanes()):
        out.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                    "tid": tid, "args": {"sort_index": i}})
    for ev in evs:
        rec: Dict[str, Any] = {
            "ph": ev.ph, "name": ev.name, "cat": ev.cat,
            "pid": ev.pid, "tid": ev.tid,
            "ts": round(ev.ts * 1e6, 3),
        }
        if ev.ph == "X":
            rec["dur"] = round(ev.dur * 1e6, 3)
        if ev.args is not None:
            rec["args"] = ev.args
        elif ev.ph == "C":
            rec["args"] = {}
        out.append(rec)
    doc: Dict[str, Any] = {"traceEvents": out, "displayTimeUnit": "ms"}
    dropped = tracer.dropped()
    if dropped:
        doc["otherData"] = {"dropped_events": dropped}
    return doc


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
    return path


def _lane_spans(events: Sequence[TraceEvent]):
    lanes: Dict[Tuple[int, int], Tuple[str, List[TraceEvent]]] = {}
    for ev in events:
        key = (ev.pid, ev.tid)
        if key not in lanes:
            lanes[key] = (ev.lane, [])
        if ev.ph == "X":
            lanes[key][1].append(ev)
    return lanes


def check_nesting(events: Sequence[TraceEvent], eps: float = 1e-6) -> List[str]:
    """Well-formedness: per lane, spans either nest or are disjoint.

    Returns a list of violation descriptions (empty = well formed).
    Partial overlap — a span starting inside another and ending after
    it by more than ``eps`` — is the corruption this catches.
    """
    bad: List[str] = []
    for (pid, tid), (lane, spans) in _lane_spans(events).items():
        ordered = sorted(spans, key=lambda e: (e.ts, -e.dur))
        stack: List[TraceEvent] = []
        for ev in ordered:
            while stack and stack[-1].ts + stack[-1].dur <= ev.ts + eps:
                stack.pop()
            if stack:
                parent = stack[-1]
                if ev.ts + ev.dur > parent.ts + parent.dur + eps:
                    bad.append(
                        f"lane {lane} (pid={pid} tid={tid}): span "
                        f"{ev.name}@{ev.ts:.6f}+{ev.dur:.6f} straddles "
                        f"{parent.name}@{parent.ts:.6f}+{parent.dur:.6f}")
            stack.append(ev)
    return bad


def time_in_state(tracer: Tracer) -> Dict[Tuple[int, int], Dict[str, Any]]:
    """Per-lane self-time by state, plus the lane's covered extent.

    Returns ``{(pid, tid): {"lane": name, "eval": s, "sweep": s,
    "idle": s, "steal": s, "other": s, "total": s, "extent": s}}``
    where ``total`` is the sum of the five states (self-time — nested
    spans bill their own category) and ``extent`` is last span end
    minus first span start on that lane.
    """
    out: Dict[Tuple[int, int], Dict[str, Any]] = {}
    for key, (lane, spans) in _lane_spans(tracer.events()).items():
        acc = {s: 0.0 for s in STATES}
        if not spans:
            continue
        ordered = sorted(spans, key=lambda e: (e.ts, -e.dur))
        # stack entries: [end, state, child_time]
        stack: List[List[Any]] = []

        def bill(entry: List[Any]) -> None:
            end, state, child, dur = entry
            acc[state] += max(0.0, dur - child)

        for ev in ordered:
            while stack and stack[-1][0] <= ev.ts + 1e-9:
                bill(stack.pop())
            state = STATE_OF_CAT.get(ev.cat, "other")
            if stack:
                stack[-1][2] += ev.dur
            stack.append([ev.ts + ev.dur, state, 0.0, ev.dur])
        while stack:
            bill(stack.pop())
        first = min(e.ts for e in ordered)
        last = max(e.ts + e.dur for e in ordered)
        row: Dict[str, Any] = {"lane": lane}
        row.update(acc)
        row["total"] = sum(acc.values())
        row["extent"] = last - first
        out[key] = row
    return out


def summary_table(tracer: Tracer, wall_s: Optional[float] = None) -> str:
    """Terminal table: time-in-state per lane, coverage vs ``wall_s``."""
    rows = time_in_state(tracer)
    hdr = f"{'lane':<18} {'pid':>3}  " + "".join(
        f"{s + '_s':>9}" for s in STATES) + f"  {'total_s':>9}"
    if wall_s:
        hdr += f"  {'cover%':>7}"
    lines = [hdr, "-" * len(hdr)]
    for (pid, _tid), row in rows.items():
        line = f"{row['lane']:<18} {pid:>3}  " + "".join(
            f"{row[s]:>9.3f}" for s in STATES) + f"  {row['total']:>9.3f}"
        if wall_s:
            line += f"  {100.0 * row['total'] / wall_s:>6.1f}%"
        lines.append(line)
    if wall_s:
        lines.append(f"{'wall_s':<18} {wall_s:>13.3f}")
    dropped = tracer.dropped()
    if dropped:
        lines.append(f"(ring overflow: {dropped} oldest events dropped)")
    return "\n".join(lines)
