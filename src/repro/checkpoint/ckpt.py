"""Sharded checkpointing: atomic, async, elastic.

Format: one directory per step —
    step_<N>/
      manifest.json     tree structure, per-leaf shape/dtype/spec, step,
                        mesh shape at save time
      arrays.npz        flat leaf arrays (globally materialized)

Design points for the 1000+-node posture:
- *atomic*: written to step_<N>.tmp, fsync'd, then renamed — a crash
  mid-save never corrupts the latest checkpoint.
- *async*: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes on a daemon thread, overlapping I/O with the next steps.
- *elastic*: the manifest stores GLOBAL shapes + logical specs, not
  device layouts, so ``load`` can re-shard onto ANY mesh (different pod
  count / device count) — restart-time elasticity (DESIGN.md §5).
- On a real multi-host pod, each host writes its addressable shards and
  the manifest carries the shard index; here (single process) leaves are
  gathered to host numpy. The format is deliberately host-count-agnostic.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str | Path, step: int, tree: PyTree,
         extra: Optional[Dict[str, Any]] = None) -> Path:
    """Synchronous atomic save. Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "saved_at": time.time()}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync the directory entries then atomically rename
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread. One in-flight save at a time
    (a newer save waits for the previous write to land — bounded memory)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[Dict[str, Any]] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}",
                          ignore_errors=True)


def list_steps(ckpt_dir: str | Path) -> List[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp"):
            out.append(int(d.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load(ckpt_dir: str | Path, step: int, like: PyTree,
         shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``like`` (a shape/array tree).

    ``shardings``: optional matching tree of NamedShardings — leaves are
    device_put with them (elastic re-shard: the target mesh may differ
    from the mesh at save time)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves_like = _flatten_with_paths(like)
    out_leaves = []
    for key, leaf in leaves_like:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {want_shape}")
        out_leaves.append(arr)
    treedef = jax.tree.structure(like)
    tree = jax.tree.unflatten(treedef, out_leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda x, l: jax.device_put(np.asarray(x).astype(l.dtype)),
            tree, like)
    return tree, manifest
