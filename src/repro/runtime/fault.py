"""Fault-tolerant step-loop runner.

At thousand-node scale, *something* is always failing. The posture here:

- every N steps, checkpoint asynchronously (atomic rename — see
  repro.checkpoint.ckpt);
- the step loop runs under a supervisor that catches worker failures
  (surfaced in JAX as RuntimeError/XlaRuntimeError from a dead slice, or
  injected in tests via FaultInjector), restores the last checkpoint and
  resumes — optionally on a *different* device count (elastic re-mesh:
  the checkpoint stores global arrays, `reshard` places them on the new
  mesh);
- a step deadline flags stragglers: on real pods the remediation is
  re-scheduling the slow host's data shard (cluster-granularity stealing,
  the paper's policy at the pipeline level — see repro.data.lm_pipeline);
  here we record the event and re-dispatch the shard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import ckpt as ckpt_lib

PyTree = Any


class FaultInjector:
    """Deterministic failure injection for tests: fail at given steps."""

    def __init__(self, fail_at=(), exc=RuntimeError):
        self.fail_at = set(fail_at)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclasses.dataclass
class RunReport:
    steps_done: int = 0
    restarts: int = 0
    straggler_events: int = 0
    last_ckpt_step: int = -1
    wall_s: float = 0.0


def run_with_recovery(
    *,
    step_fn: Callable[[PyTree, PyTree, Any], tuple],
    init_state: tuple,               # (params, opt_state)
    batch_iter: Callable[[int], Any],
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    max_restarts: int = 3,
    step_deadline_s: Optional[float] = None,
    fault_injector: Optional[FaultInjector] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> tuple:
    """Run the training loop; recover from failures via checkpoints.

    Returns ((params, opt_state), RunReport).
    """
    report = RunReport()
    t0 = time.time()
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    params, opt_state = init_state
    step = 0

    # resume if a checkpoint exists (restart-in-anger path)
    latest = ckpt_lib.latest_step(ckpt_dir)
    if latest is not None:
        (params, opt_state), man = ckpt_lib.load(
            ckpt_dir, latest, (params, opt_state))
        step = man["step"]
        report.last_ckpt_step = step

    restarts = 0
    while step < n_steps:
        try:
            batch = batch_iter(step)
            if fault_injector is not None:
                fault_injector.maybe_fail(step)
            ts = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - ts
            if step_deadline_s is not None and dt > step_deadline_s:
                report.straggler_events += 1
            step += 1
            report.steps_done += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % ckpt_every == 0 or step == n_steps:
                saver.save_async(step, (params, opt_state))
                report.last_ckpt_step = step
        except (RuntimeError, ValueError) as e:  # worker failure
            restarts += 1
            report.restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts; last error: {e}"
                ) from e
            saver.wait()
            latest = ckpt_lib.latest_step(ckpt_dir)
            if latest is None:
                # nothing saved yet: restart from initial state
                params, opt_state = init_state
                step = 0
            else:
                (params, opt_state), man = ckpt_lib.load(
                    ckpt_dir, latest, (params, opt_state))
                step = man["step"]
    saver.wait()
    report.wall_s = time.time() - t0
    return (params, opt_state), report
