"""Elastic re-meshing: resume a run on a different device count.

Checkpoints store *global* arrays (repro.checkpoint.ckpt), so elasticity
reduces to re-resolving the sharding rules against the new mesh and
device_put-ing each leaf — logical axes are mesh-independent by design
(repro.parallel.sharding). Divisibility fallbacks in `spec_for` mean a
16-wide model axis checkpoint restores cleanly onto 8- or 4-wide meshes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.models.common import axes_tree
from repro.parallel import sharding as shd

PyTree = Any


def reshard_params(params_host: PyTree, model, mesh, rules) -> PyTree:
    """Place host (numpy) param arrays onto a new mesh."""
    shardings = shd.tree_shardings(model.param_shapes(),
                                   axes_tree(model.param_defs()),
                                   mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s),
                        params_host, shardings)


def scale_batch_for_mesh(global_batch: int, mesh) -> int:
    """Keep per-shard batch constant when the DP width changes
    (elastic scale-down halves the global batch, scale-up doubles it)."""
    dp = shd.dp_size(mesh)
    per_shard = max(1, global_batch // max(dp, 1))
    return per_shard * dp
