"""LM token pipeline with length-clustered batching + bucket stealing.

The paper's clustered scheduling applied to the *input* pipeline
(DESIGN.md §3, layer 3): documents are bucketed by length (a locality/
cost proxy — same-bucket sequences pad to the same target, wasting no
FLOPs), each host shard drains its own buckets, and a slow shard's
remaining *whole buckets* can be stolen by fast shards — cluster
granularity, never single documents, so the stolen work is still
uniformly shaped.

Synthetic corpus: a Zipf-token generator with a long-tailed document
length distribution (matching real web-corpus length skew).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineStats:
    batches: int = 0
    pad_fraction: float = 0.0
    stolen_buckets: int = 0


def synth_corpus(n_docs: int, vocab: int, seed: int = 0,
                 mean_len: int = 512, max_len: int = 4096
                 ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    lens = np.minimum(rng.lognormal(np.log(mean_len), 0.7,
                                    n_docs).astype(int) + 8, max_len)
    # Zipf unigram tokens (cheap stand-in for BPE text)
    return [rng.zipf(1.3, size=n) % vocab for n in lens]


def length_buckets(docs: Sequence[np.ndarray],
                   edges: Sequence[int] = (128, 256, 512, 1024, 2048, 4096)
                   ) -> Dict[int, List[int]]:
    """doc index -> bucket keyed by padded target length."""
    buckets: Dict[int, List[int]] = {e: [] for e in edges}
    for i, d in enumerate(docs):
        for e in edges:
            if len(d) <= e:
                buckets[e].append(i)
                break
        else:
            buckets[edges[-1]].append(i)
    return {e: v for e, v in buckets.items() if v}


class ClusteredLoader:
    """Per-host-shard bucketed loader with bucket-granularity stealing."""

    def __init__(self, docs: Sequence[np.ndarray], batch: int,
                 seq_len: int, n_shards: int = 1, seed: int = 0):
        self.docs = docs
        self.batch = batch
        self.seq_len = seq_len
        self.n_shards = n_shards
        self.rng = np.random.default_rng(seed)
        self.stats = PipelineStats()
        buckets = length_buckets(docs)
        # deal whole buckets to shards round-robin by total size
        self.shard_buckets: List[Dict[int, List[int]]] = [
            {} for _ in range(n_shards)]
        loads = np.zeros(n_shards, np.int64)
        for e, idxs in sorted(buckets.items(), key=lambda kv: -len(kv[1])):
            tgt = int(np.argmin(loads))
            self.shard_buckets[tgt][e] = list(idxs)
            loads[tgt] += sum(len(docs[i]) for i in idxs)

    def steal(self, thief: int, victim: int) -> Optional[int]:
        """Move one whole bucket from victim to thief. Returns its key."""
        vb = self.shard_buckets[victim]
        if not vb:
            return None
        key = max(vb, key=lambda e: len(vb[e]))
        bucket = vb.pop(key)
        tb = self.shard_buckets[thief]
        tb.setdefault(key, []).extend(bucket)
        self.stats.stolen_buckets += 1
        return key

    def batches(self, shard: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (tokens, loss_mask) [batch, bucket_edge] — each batch is
        padded only to ITS bucket's edge (same-bucket sequences share a
        target shape, so almost no padding; the compiled step for each
        bucket shape is reused across all of that bucket's batches)."""
        sb = self.shard_buckets[shard]
        total_tok = 0
        pad_tok = 0
        for e in sorted(sb):
            edge = min(e, self.seq_len)
            idxs = sb[e]
            self.rng.shuffle(idxs)
            for i0 in range(0, len(idxs) - self.batch + 1, self.batch):
                chosen = idxs[i0:i0 + self.batch]
                toks = np.zeros((self.batch, edge), np.int32)
                mask = np.zeros((self.batch, edge), np.float32)
                for r, di in enumerate(chosen):
                    d = self.docs[di][:edge]
                    toks[r, :len(d)] = d
                    mask[r, :len(d)] = 1.0
                    total_tok += edge
                    pad_tok += edge - len(d)
                self.stats.batches += 1
                yield toks, mask
        if total_tok:
            self.stats.pad_fraction = pad_tok / total_tok


def unclustered_pad_fraction(docs: Sequence[np.ndarray], batch: int,
                             seq_len: int, seed: int = 0) -> float:
    """Baseline: random batching, pad everything to seq_len."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(docs))
    total = pad = 0
    for i0 in range(0, len(docs) - batch + 1, batch):
        for di in order[i0:i0 + batch]:
            n = min(len(docs[di]), seq_len)
            total += seq_len
            pad += seq_len - n
    return pad / max(total, 1)


def make_batch_iter(vocab: int, batch: int, seq_len: int, seed: int = 0):
    """Simple infinite random-token batcher for train smoke/integration."""
    rng = np.random.default_rng(seed)

    def it(step: int):
        rs = np.random.default_rng(seed + step)
        toks = rs.integers(0, vocab, size=(batch, seq_len),
                           dtype=np.int32)
        labels = np.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}

    return it
