"""Synthetic transaction databases (FIMI analogues — DESIGN.md §7.1).

The FIMI repository datasets are not redistributable offline, so we
implement the IBM Quest generator (Agrawal & Srikant, VLDB'94 — the
generator behind T10I4D100K / T40I10D100K) plus dense-profile generators
matching the density character of chess / connect / mushroom / pumsb.

Each profile returns (db, n_items) with db = list of item-id lists, and a
``support`` fraction mirroring Table 1's per-dataset support column.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Profile:
    name: str
    support: float          # min-support fraction (paper Table 1 analogue)
    kind: str               # 'quest' | 'dense'
    n_transactions: int = 10000
    n_items: int = 200
    avg_len: int = 10       # quest: mean transaction length (T)
    avg_pattern: int = 4    # quest: mean maximal-pattern length (I)
    n_patterns: int = 100   # quest: number of maximal patterns (L)
    density: float = 0.35   # dense: per-item probability
    n_dense_items: int = 40
    zipf: float = 0.75      # quest: item-popularity skew exponent


PROFILES: Dict[str, Profile] = {
    # quest-parameterized sparse market-basket data (T10I4 / T40I10)
    "t10i4":   Profile("t10i4", 0.005, "quest", 20000, 500, 10, 4, 200),
    "t40i10":  Profile("t40i10", 0.02, "quest", 8000, 500, 40, 10, 200),
    "kosarak": Profile("kosarak", 0.006, "quest", 20000, 800, 8, 4, 400),
    # retail-like sparse long tail: many items, steep Zipf skew, long
    # correlated patterns at low support — frequent itemsets form deep,
    # NARROW equivalence classes (few siblings per prefix). This is the
    # stress regime for the depth-first engine's memory bound and
    # barrier-freedom (a level-synchronous driver barriers on a handful
    # of live branches), and also where Eclat's unpruned class-local
    # sweeps cost the most vs Apriori — the benchmark records both.
    "retail":  Profile("retail", 0.012, "quest", 12000, 1200, 12, 5, 500,
                       zipf=1.05),
    # dense UCI-style datasets (high support thresholds, like the paper)
    "chess":      Profile("chess", 0.60, "dense", 3196, 75,
                          density=0.49, n_dense_items=75),
    "connect":    Profile("connect", 0.82, "dense", 6000, 90,
                          density=0.47, n_dense_items=90),
    "mushroom":   Profile("mushroom", 0.20, "dense", 8124, 100,
                          density=0.22, n_dense_items=100),
    "pumsb":      Profile("pumsb", 0.80, "dense", 8000, 120,
                          density=0.55, n_dense_items=120),
    "accidents":  Profile("accidents", 0.35, "dense", 10000, 150,
                          density=0.30, n_dense_items=150),
}


def gen_quest(p: Profile, seed: int = 0) -> List[List[int]]:
    """IBM Quest: build L maximal patterns (item subsets with geometric
    sizes), then compose each transaction from overlapping patterns."""
    rng = np.random.default_rng(seed)
    # pattern item pools are Zipf-weighted so some items are very
    # frequent; ``p.zipf`` sets the skew (retail-like long tails ~1.05)
    weights = 1.0 / np.arange(1, p.n_items + 1) ** p.zipf
    weights /= weights.sum()
    patterns = []
    for _ in range(p.n_patterns):
        size = max(1, int(rng.geometric(1.0 / p.avg_pattern)))
        patterns.append(np.unique(
            rng.choice(p.n_items, size=min(size, p.n_items), p=weights,
                       replace=False)))
    pat_weights = rng.exponential(size=p.n_patterns)
    pat_weights /= pat_weights.sum()
    corruption = rng.uniform(0.2, 0.8, size=p.n_patterns)
    db = []
    for _ in range(p.n_transactions):
        target = max(1, int(rng.poisson(p.avg_len)))
        txn: set = set()
        while len(txn) < target:
            pi = rng.choice(p.n_patterns, p=pat_weights)
            pat = patterns[pi]
            keep = rng.random(len(pat)) > corruption[pi] * 0.5
            txn.update(pat[keep].tolist())
            if rng.random() < 0.1:              # occasional noise item
                txn.add(int(rng.choice(p.n_items, p=weights)))
            if len(patterns[pi]) == 0:
                break
        db.append(sorted(txn)[:3 * p.avg_len])
    return db


def gen_dense(p: Profile, seed: int = 0) -> List[List[int]]:
    """Dense UCI-style data: correlated blocks of frequently-co-occurring
    items (chess/connect-like), giving deep frequent itemsets."""
    rng = np.random.default_rng(seed)
    n, m = p.n_transactions, p.n_dense_items
    # correlated latent factors -> co-occurrence structure
    n_factors = max(4, m // 12)
    loadings = rng.random((n_factors, m)) < 0.35
    base = rng.random(m) * p.density * 1.4
    db = []
    factors = rng.random((n, n_factors)) < 0.5
    noise = rng.random((n, m))
    for t in range(n):
        active = noise[t] < base
        for f in np.nonzero(factors[t])[0]:
            active |= loadings[f] & (noise[t] < p.density * 2.2)
        items = np.nonzero(active)[0]
        if len(items) == 0:
            items = rng.choice(m, size=2, replace=False)
        db.append(items.tolist())
    return db


def load(profile: str, seed: int = 0,
         scale: int = 1) -> Tuple[List[List[int]], Profile]:
    """``scale`` multiplies n_transactions — the paper's datasets have
    10^5..10^6 transactions, where the per-task TID-join dominates
    scheduling overhead; benchmarks use scale>1 to match that regime
    (tests use scale=1 for speed)."""
    p = PROFILES[profile]
    if scale != 1:
        p = dataclasses.replace(p,
                                n_transactions=p.n_transactions * scale)
    db = gen_quest(p, seed) if p.kind == "quest" else gen_dense(p, seed)
    return db, p


def min_support_count(p: Profile, db) -> int:
    return max(1, int(p.support * len(db)))
