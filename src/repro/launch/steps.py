"""Step builders shared by train.py / serve.py / dryrun.py.

Everything here is shape-driven: ``input_specs`` returns ShapeDtypeStruct
stand-ins (weak-type-correct, shardable, no allocation) so the dry-run can
lower + compile the production mesh without a single real buffer.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, OptimizerConfig
from repro.models.common import axes_tree, dtype_of
from repro.models.registry import build_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.ctx import use_sharding_ctx

PyTree = Any


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs + shardings)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    model = build_model(cfg)
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["batch"] = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.family == "audio":
            out["batch"]["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.n_frames, cfg.d_model), dtype_of(cfg.dtype))
    elif shape.kind == "prefill":
        out["tokens"] = tok((b, s))
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.n_frames, cfg.d_model), dtype_of(cfg.dtype))
    elif shape.kind == "decode":
        out["cache"] = model.cache_shapes(b, s)
        out["tokens"] = tok((b, 1))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return out


def _batch_shardings(cfg, shape, mesh, rules):
    bspec = shd.spec_for((shape.global_batch, shape.seq_len),
                         ("batch", "seq"), mesh, rules)
    ns = NamedSharding(mesh, bspec)
    out = {"tokens": ns, "labels": ns}
    if cfg.family == "audio":
        out["frames"] = NamedSharding(
            mesh, shd.spec_for(
                (shape.global_batch, cfg.encdec.n_frames, cfg.d_model),
                ("batch", "frames", "act_embed"), mesh, rules))
    return out


def cache_shardings(model, b, s, mesh, rules):
    defs = model.cache_defs(b, s)
    from repro.models.common import shapes_tree
    return shd.tree_shardings(shapes_tree(defs), axes_tree(defs), mesh,
                              rules)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def param_shardings(model, mesh, rules):
    return shd.tree_shardings(model.param_shapes(),
                              axes_tree(model.param_defs()), mesh, rules)


def build_train_step(cfg: ModelConfig, ocfg: OptimizerConfig, mesh, rules,
                     microbatches: int = 1):
    """Returns (train_step_fn, in_shardings, out_shardings, arg_shapes)."""
    model = build_model(cfg)

    def loss_fn(params, batch):
        with use_sharding_ctx(mesh, rules):
            return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(micro, zero, mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state, metrics = adamw.update(ocfg, grads, opt_state,
                                                   params)
        params = adamw.apply_updates(params, updates)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    psh = param_shardings(model, mesh, rules)
    osh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                           mu=jax.tree.map(lambda s: s, psh),
                           nu=jax.tree.map(lambda s: s, psh))
    return model, train_step, psh, osh


def build_prefill_step(cfg: ModelConfig, mesh, rules):
    model = build_model(cfg)

    def prefill(params, tokens, frames=None):
        with use_sharding_ctx(mesh, rules):
            if cfg.family == "audio":
                return model.prefill(params, tokens, frames)
            return model.prefill(params, tokens)

    return model, prefill, param_shardings(model, mesh, rules)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    model = build_model(cfg)
    window = (model.window_for(shape.seq_len)
              if hasattr(model, "window_for") else 0)

    def serve_step(params, cache, tokens, pos):
        with use_sharding_ctx(mesh, rules):
            if window:
                return model.decode_step(params, cache, tokens, pos,
                                         window=window)
            return model.decode_step(params, cache, tokens, pos)

    return model, serve_step, param_shardings(model, mesh, rules)
