"""Training launcher: end-to-end driver (deliverable (b)).

CPU-scale by default (smoke configs); the full configs are exercised via
dryrun.py. Fault tolerance (checkpoint/restart) is always on; pass
--inject-fault to watch a failure + recovery live.

Example:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
        --smoke --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.data.lm_pipeline import make_batch_iter
from repro.launch import steps as steps_mod
from repro.models.registry import build_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.fault import FaultInjector, run_with_recovery


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-fault", type=int, default=None,
                    help="inject a failure at this step (demo/testing)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    ocfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 20, 5))
    model = build_model(cfg)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = adamw.init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    def step_fn(params, opt_state, batch):
        return _jitted(params, opt_state, batch)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        updates, opt_state, metrics = adamw.update(ocfg, grads, opt_state,
                                                   params)
        params = adamw.apply_updates(params, updates)
        return params, opt_state, dict(metrics, loss=loss)

    _jitted = jax.jit(train_step, donate_argnums=(0, 1))

    batch_iter = make_batch_iter(cfg.vocab_size, args.batch, args.seq)
    if cfg.family == "audio":
        base_iter = batch_iter

        def batch_iter(step):  # noqa: F811 — wrap with frames
            b = base_iter(step)
            rs = np.random.default_rng(step)
            b["frames"] = rs.standard_normal(
                (args.batch, cfg.encdec.n_frames, cfg.d_model)
            ).astype(np.float32)
            return b

    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)

    inj = (FaultInjector(fail_at=[args.inject_fault])
           if args.inject_fault else None)
    t0 = time.time()
    (params, opt_state), report = run_with_recovery(
        step_fn=step_fn, init_state=(params, opt_state),
        batch_iter=batch_iter, n_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fault_injector=inj, on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"done: {report.steps_done} steps in {dt:.1f}s "
          f"({report.steps_done / max(dt, 1e-9):.2f} steps/s), "
          f"restarts={report.restarts}, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
