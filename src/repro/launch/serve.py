"""Serving launcher: batched prefill + decode with a KV cache.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    max_len = args.prompt_len + args.gen

    b = args.batch
    prompt = jax.random.randint(key, (b, args.prompt_len), 0,
                                cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(
            key, (b, cfg.encdec.n_frames, cfg.d_model), jnp.float32)

    # prefill: teacher-force the prompt through decode steps to fill the
    # cache (exactly equal to model.apply — see tests), then decode.
    cache = model.init_cache(b, max_len)
    if cfg.family == "audio":
        _, c2 = model.prefill(params, prompt[:, :1], frames)
        cache["cross_kv"] = c2["cross_kv"]

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    t0 = time.time()
    tok = prompt[:, :1]
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompt[:, i:i + 1],
                               jnp.int32(i))
    t_prefill = time.time() - t0

    outs = []
    t0 = time.time()
    for i in range(args.gen):
        pos = args.prompt_len + i
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
    t_gen = time.time() - t0

    gen = np.concatenate(outs, axis=1)
    tps = b * args.gen / max(t_gen, 1e-9)
    print(f"arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_gen*1e3:.1f} ms "
          f"({tps:.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
