"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — "
            f"run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # more devices than the mesh needs (single-pod mesh in a 512-dev
    # process): take the first pod's worth.
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (subprocess sets device count)."""
    import jax
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)
