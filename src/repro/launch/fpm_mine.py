"""FPM mining launcher — the paper's application end-to-end.

Example (Fig. 1 reproduction on one dataset):
    PYTHONPATH=src python -m repro.launch.fpm_mine --dataset chess \
        --workers 8 --policies cilk clustered
"""
from __future__ import annotations

import argparse
import time

from repro.core.buckets import REPRESENTATIONS
from repro.core.fpm import (GRANULARITIES, mesh_over_devices, mine,
                            mine_serial)
from repro.core.tidlist import pack_database
from repro.data.transactions import PROFILES, load, min_support_count
from repro.obs import Tracer, summary_table, write_chrome_trace


def _finish_trace(args, tracer, wall_s: float) -> None:
    """Flush the run's tracer: Chrome-trace JSON for ``--trace`` (one
    lane per worker/dispatcher, loadable at https://ui.perfetto.dev)
    and the terminal time-in-state table for ``--trace-summary``."""
    if tracer is None:
        return
    if args.trace:
        write_chrome_trace(tracer, args.trace)
        print(f"trace: wrote {args.trace} "
              f"({len(tracer.events())} events) — open in "
              f"https://ui.perfetto.dev")
    if args.trace_summary:
        print(summary_table(tracer, wall_s))


def _spawn_hosts(args) -> None:
    """Parent of a ``--hosts N`` run: pick a coordinator port, spawn
    one rank subprocess per host with the CPU-cluster environment
    (``JAX_PLATFORMS=cpu`` plus the collective-combine XLA thresholds
    the big-model launchers tune, so a per-flush reduction fuses into
    one transfer rather than many), forward rank 0's report, and
    propagate the first failing exit code."""
    import os
    import socket
    import subprocess
    import sys

    if args.stream:
        raise SystemExit("--hosts and --stream are mutually exclusive "
                         "(use StreamingMiner(hosts=N) for multi-host "
                         "streaming)")
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        coord = f"127.0.0.1:{s.getsockname()[1]}"
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + " --xla_gpu_all_reduce_combine_threshold_bytes=134217728"
        + " --xla_gpu_all_gather_combine_threshold_bytes=134217728"
        + " --xla_gpu_reduce_scatter_combine_threshold_bytes"
        + "=134217728").strip()
    base = [sys.executable, "-m", "repro.launch.fpm_mine",
            "--dataset", args.dataset,
            "--workers", str(args.workers),
            "--policies", args.policies[0],
            "--granularity", args.granularity,
            "--max-k", str(args.max_k),
            "--seed", str(args.seed),
            "--_coordinator", coord,
            "--_nprocs", str(args.hosts)]
    if args.support is not None:
        base += ["--support", str(args.support)]
    print(f"hosts: spawning {args.hosts} ranks @ {coord} "
          f"(JAX_PLATFORMS=cpu, collective-combine XLA flags)")
    procs = [subprocess.Popen(
        base + ["--_rank", str(r)], env=env,
        stdout=None if r == 0 else subprocess.DEVNULL)
        for r in range(args.hosts)]
    codes = [p.wait() for p in procs]
    for r, c in enumerate(codes):
        if c:
            raise SystemExit(f"rank {r} exited with {c}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="chess", choices=list(PROFILES))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--policies", nargs="+",
                    default=["cilk", "clustered"])
    ap.add_argument("--granularity", default="bucket",
                    choices=list(GRANULARITIES),
                    help="task grain: bucket (level-sync sweep), "
                         "candidate (scalar joins), or depth-first "
                         "(barrier-free class recursion)")
    ap.add_argument("--representation", default="auto",
                    choices=list(REPRESENTATIONS),
                    help="row representation: bitmap (word-columns "
                         "only), sparse (force tid-list/diffset rows), "
                         "auto (density-driven per-subtree choice)")
    ap.add_argument("--backend", default="auto",
                    help="join backend: auto|numpy|pallas-interpret|"
                         "pallas-jit")
    ap.add_argument("--arena", default="auto",
                    choices=["auto", "numpy", "jax"],
                    help="bitmap arena backing: auto (lazy device "
                         "mirror), jax (eager upload), numpy "
                         "(host-only; Pallas backends re-upload per "
                         "batch — the transfer-bound baseline)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="sweep dispatcher: max requests per batched "
                         "kernel launch")
    ap.add_argument("--flush-us", type=float, default=200.0,
                    help="sweep dispatcher: µs to wait for straggler "
                         "requests before flushing a partial batch")
    ap.add_argument("--mesh", type=int, default=0,
                    help="run the engine mesh-aware over N device "
                         "shards (sharded arena, one dispatcher per "
                         "device, device-affine workers). Uses the "
                         "first N jax devices when available, logical "
                         "shards otherwise; 0 = shared-memory run")
    ap.add_argument("--hosts", type=int, default=0, metavar="N",
                    help="multi-host mode: spawn N worker processes "
                         "forming a jax.distributed CPU cluster; each "
                         "owns a word-slice of the transaction axis "
                         "and support counting is two-phase (local "
                         "partial counts + per-flush cross-host "
                         "reduction). 0 = single process")
    # child-rank plumbing for --hosts (set by the parent, not by hand)
    ap.add_argument("--_rank", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_nprocs", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_coordinator", default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--support", type=float, default=None,
                    help="override the profile's min-support fraction")
    ap.add_argument("--max-k", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="streaming mode: hold back the tail of the "
                         "dataset and replay it as N ingest+refresh "
                         "rounds through a StreamingMiner (prints "
                         "per-round border/reuse stats; the final "
                         "generation is verified against the serial "
                         "batch miner)")
    ap.add_argument("--stream-frac", type=float, default=0.1,
                    help="fraction of the dataset replayed as the "
                         "ingest stream (with --stream)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a time-resolved trace of the run "
                         "(task/flush/steal spans, one lane per "
                         "worker) and write Chrome trace-event JSON "
                         "loadable in Perfetto")
    ap.add_argument("--trace-summary", action="store_true",
                    help="print the per-worker time-in-state table "
                         "(sweep/eval/idle/steal) after the run; "
                         "implies tracing even without --trace")
    ap.add_argument("--serve", type=int, default=0, metavar="N",
                    help="after the stream replay, serve N queries of "
                         "each kind (known-hit, batched unknown-itemset "
                         "sweep, top-k) through the PatternServer and "
                         "print per-kind p50/p95/p99 (with --stream)")
    args = ap.parse_args()

    if args.hosts >= 2 and args._rank is None:
        return _spawn_hosts(args)

    db, prof = load(args.dataset, args.seed)
    n_items = (prof.n_dense_items if prof.kind == "dense"
               else prof.n_items)
    bitmaps, item_counts = pack_database(db, n_items,
                                         return_counts=True)
    frac = args.support if args.support is not None else prof.support
    ms = max(1, int(frac * len(db)))
    print(f"dataset=synth:{args.dataset} |D|={len(db)} items={n_items} "
          f"min_support={ms} ({frac:.4f})")

    if args._rank is not None:
        # one rank of a --hosts cluster: every process packed the same
        # database above, keeps only its word-slice, and mines with
        # the KV-store reduction transport
        from repro.core.cluster import mine_distributed_process
        res, met = mine_distributed_process(
            bitmaps, ms, rank=args._rank, n_procs=args._nprocs,
            coordinator=args._coordinator, policy=args.policies[0],
            n_workers=args.workers, max_k=args.max_k,
            granularity=args.granularity)
        if args._rank == 0:
            s = met.scheduler
            print(f"{args.policies[0]:10s} hosts={met.n_hosts} "
                  f"wall={met.wall_s:6.2f}s "
                  f"frequent={len(res)} "
                  f"steals={int(s.get('steals', 0)):6d} "
                  f"net={met.net_bytes}B steal_net={met.steal_net}B")
        return

    mesh = mesh_over_devices(args.mesh)
    if mesh is not None:
        print(f"mesh: {args.mesh} device shards "
              f"({'logical' if isinstance(mesh, int) else 'jax devices'})")

    t0 = time.time()
    ref = mine_serial(bitmaps, ms, max_k=args.max_k)
    t_serial = time.time() - t0
    print(f"serial: {len(ref)} frequent itemsets in {t_serial:.2f}s")

    tracer = (Tracer() if (args.trace or args.trace_summary)
              else None)

    if args.stream:
        from repro.core.streaming import PatternServer, StreamingMiner
        n_stream = max(args.stream, int(args.stream_frac * len(db)))
        init, tail = db[:-n_stream], db[-n_stream:]
        per = max(1, len(tail) // args.stream)
        sm = StreamingMiner(n_items, ms, initial_db=init,
                            policy=args.policies[0],
                            n_workers=args.workers, max_k=args.max_k,
                            granularity=args.granularity,
                            backend=args.backend, arena=args.arena,
                            max_batch=args.max_batch,
                            flush_us=args.flush_us, mesh=mesh,
                            representation=args.representation,
                            tracer=tracer)
        t_stream0 = time.perf_counter()
        rep = sm.refresh()
        print(f"stream gen1: |D|={rep.n_transactions} "
              f"frequent={rep.frequent} wall={rep.wall_s:.2f}s "
              f"rows={rep.rows_touched}")
        for r in range(args.stream):
            batch = tail[r * per:] if r == args.stream - 1 \
                else tail[r * per:(r + 1) * per]
            if not batch:
                break
            ing = sm.ingest(batch)
            rep = sm.refresh()
            print(f"stream gen{rep.generation}: +{ing.n_transactions}tx "
                  f"(seg {ing.segment}, {ing.payload_bytes}B) "
                  f"wall={rep.wall_s:.2f}s rows={rep.rows_touched} "
                  f"reused={rep.reused} delta={rep.swept_delta} "
                  f"full={rep.swept_full} born={rep.born} "
                  f"died={rep.died}")
        assert dict(sm.snapshot.supports) == ref, "stream mismatch!"
        srv = PatternServer(sm)
        top = srv.top_k((), 5)
        print(f"stream final == serial ✓; top-5: {top}")
        if args.serve:
            import itertools

            hot = [x for x, _ in top] or [(0,)]
            fresh = itertools.chain.from_iterable(
                itertools.combinations(range(n_items), k)
                for k in range(args.max_k + 1, n_items + 1))
            lat = {"hit": [], "sweep": [], "top_k": []}
            for i in range(args.serve):
                x = hot[i % len(hot)]
                t0 = time.perf_counter_ns()
                srv.support(x)
                lat["hit"].append((time.perf_counter_ns() - t0) / 1e3)
                t0 = time.perf_counter_ns()
                srv.top_k(x[:1], 5)
                lat["top_k"].append((time.perf_counter_ns() - t0) / 1e3)
            batch = 8
            for _ in range(args.serve):
                xs = list(itertools.islice(fresh, batch))
                t0 = time.perf_counter_ns()
                srv.support_many(xs)
                lat["sweep"].append(
                    (time.perf_counter_ns() - t0) / 1e3 / len(xs))
            import numpy as np
            for kind, us in lat.items():
                a = np.asarray(us)
                print(f"serve {kind:6s}: n={len(us):4d} "
                      f"p50={np.percentile(a, 50):8.1f}us "
                      f"p95={np.percentile(a, 95):8.1f}us "
                      f"p99={np.percentile(a, 99):8.1f}us")
            print(f"serve stats: {srv.merged_stats()} "
                  f"query_sweeps={sm.query_sweeps} "
                  f"query_sweep_bytes={sm.query_sweep_bytes}")
            print(f"serve recorder: {srv.latency_percentiles()}")
        _finish_trace(args, tracer,
                      time.perf_counter() - t_stream0)
        sm.close()
        return

    traced_wall = 0.0
    for policy in args.policies:
        res, met = mine(bitmaps, ms, policy=policy,
                        n_workers=args.workers, max_k=args.max_k,
                        granularity=args.granularity,
                        backend=args.backend, arena=args.arena,
                        max_batch=args.max_batch, flush_us=args.flush_us,
                        mesh=mesh, representation=args.representation,
                        item_counts=item_counts, trace=tracer)
        traced_wall += met.wall_s
        assert res == ref, f"{policy} result mismatch!"
        s = met.scheduler
        line = (f"{policy:10s} wall={met.wall_s:6.2f}s "
                f"speedup={t_serial / met.wall_s:5.2f}x "
                f"cache_hit={met.cache_hit_rate:5.1%} "
                f"steals={int(s['steals']):6d} "
                f"tasks/steal={s['tasks_per_steal']:5.2f} "
                f"bucket_switches={int(s['bucket_switches']):5d}")
        if met.flushes:
            line += (f" batch_occ={met.batch_occupancy:4.2f} "
                     f"flushes={met.flushes} h2d={met.h2d_bytes}B")
        if met.n_devices > 1:
            occ = "/".join(f"{d['batch_occupancy']:.2f}"
                           for d in met.per_device)
            line += (f" d2d={met.d2d_bytes}B "
                     f"migrations={met.migrations} "
                     f"dev_occ={occ}")
        if met.n_hosts > 1:
            line += (f" hosts={met.n_hosts} net={met.net_bytes}B "
                     f"steal_net={met.steal_net}B")
        if args.granularity == "depth-first":
            line += (f" peak_retained={met.peak_retained_bitmaps}"
                     f" ({met.peak_bytes_retained} B)")
        if met.sparse_sweeps or met.sparse_rows:
            line += (f"\n{'':10s} rep[{met.representation}]: "
                     f"sweeps dense={met.dense_sweeps} "
                     f"sparse={met.sparse_sweeps} "
                     f"sparse_bytes={met.sparse_bytes_swept}B "
                     f"rows={met.sparse_rows} "
                     f"picks={met.rep_picks} "
                     f"densify={met.densify_ops}"
                     f"/{met.densify_bytes}B "
                     f"sparsify={met.sparsify_ops}"
                     f"/{met.sparsify_bytes}B")
        print(line)
    _finish_trace(args, tracer, traced_wall)


if __name__ == "__main__":
    main()
