import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  - proof the sharding config is coherent (compile succeeds),
  - memory_analysis (fits-per-device evidence),
  - cost_analysis FLOPs/bytes (roofline compute & memory terms),
  - collective bytes parsed from the post-SPMD optimized HLO
    (roofline collective term),
all written to results/dryrun/<arch>__<shape>__<mesh>[__tag].json.

MUST be imported/run before any other jax usage: the XLA_FLAGS line above
forces 512 host platform devices and jax locks device count on first init.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import OptimizerConfig, SHAPES
from repro.configs.registry import (ARCH_IDS, all_cells, applicable_shapes,
                                    get_config)
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.parallel import sharding as shd

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~3 links usable per chip)

_COLL_RE = re.compile(
    r"(\w+(?:\.\d+)?)\s*=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred)\[([\d,]*)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1}


def _buffer_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of collective ops in optimized HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"^\S+\s*=\s*(.+?)\s*(all-reduce|all-gather|reduce-scatter|"
            r"all-to-all|collective-permute)(?:-start)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _buffer_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


def collective_link_bytes(coll: dict) -> float:
    """Approximate bytes crossing a chip's ICI links.

    ring algorithms: all-reduce moves ~2x its buffer; gather/scatter/a2a/
    permute move ~1x (per-device result bytes are already post-SPMD local
    shapes)."""
    b = coll["bytes"]
    return (2.0 * b["all-reduce"] + b["all-gather"] + b["reduce-scatter"]
            + b["all-to-all"] + b["collective-permute"])


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               ruleset: str = "default", moe_dispatch: str | None = None,
               unroll: bool = False, cfg_overrides: dict | None = None):
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe:
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                dispatch=moe_dispatch))
    if unroll:
        cfg = cfg.with_(scan_layers=False)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "decode" and ruleset == "default":
        ruleset = "decode"
    rules = shd.RULESETS[ruleset]
    specs = steps_mod.input_specs(cfg, shape)

    if shape.kind == "train":
        model, train_step, psh, osh = steps_mod.build_train_step(
            cfg, OptimizerConfig(), mesh, rules)
        bsh = steps_mod._batch_shardings(cfg, shape, mesh, rules)
        if cfg.family != "audio":
            bsh = {k: v for k, v in bsh.items() if k != "frames"}
        pshapes = model.param_shapes()
        oshapes = adamw.state_shapes(pshapes)
        fn = jax.jit(train_step,
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pshapes, oshapes, specs["batch"])
    elif shape.kind == "prefill":
        model, prefill, psh = steps_mod.build_prefill_step(cfg, mesh, rules)
        bsp = NamedSharding(mesh, shd.spec_for(
            (shape.global_batch, shape.seq_len), ("batch", "seq"), mesh,
            rules))
        if cfg.family == "audio":
            fsh = NamedSharding(mesh, shd.spec_for(
                specs["frames"].shape, ("batch", "frames", "act_embed"),
                mesh, rules))
            fn = jax.jit(prefill, in_shardings=(psh, bsp, fsh))
            lowered = fn.lower(model.param_shapes(), specs["tokens"],
                               specs["frames"])
        else:
            fn = jax.jit(prefill, in_shardings=(psh, bsp))
            lowered = fn.lower(model.param_shapes(), specs["tokens"])
    else:  # decode
        model, serve_step, psh = steps_mod.build_decode_step(
            cfg, shape, mesh, rules)
        csh = steps_mod.cache_shardings(model, shape.global_batch,
                                        shape.seq_len, mesh, rules)
        tsh = NamedSharding(mesh, shd.spec_for(
            (shape.global_batch, 1), ("batch", None), mesh, rules))
        fn = jax.jit(serve_step,
                     in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
                     out_shardings=(None, csh),
                     donate_argnums=(1,))
        lowered = fn.lower(model.param_shapes(), specs["cache"],
                           specs["tokens"], specs["pos"])
    return cfg, shape, mesh, lowered


UNROLL_DEPTH_CAP = 12      # above this, extrapolate per-layer costs


def _cost_once(arch, shape_name, ruleset, moe_dispatch, cfg_overrides,
               n_layers=None):
    ov = dict(cfg_overrides or {})
    if n_layers is not None:
        ov["n_layers"] = n_layers
    _, _, _, lowered = lower_cell(
        arch, shape_name, multi_pod=False, ruleset=ruleset,
        moe_dispatch=moe_dispatch, unroll=True, cfg_overrides=ov)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _coll_combine(a: dict, b: dict, sa: float, sb: float) -> dict:
    out = {"bytes": {}, "counts": {}}
    for sec in ("bytes", "counts"):
        for k in a[sec]:
            v = sa * a[sec][k] + sb * b[sec][k]
            out[sec][k] = max(0.0, v)
    return out


def _cost_terms(arch, shape_name, ruleset, moe_dispatch, cfg_overrides,
                cfg):
    """(flops, bytes, collectives) per device, full depth."""
    L = cfg.n_layers
    if L <= UNROLL_DEPTH_CAP:
        return _cost_once(arch, shape_name, ruleset, moe_dispatch,
                          cfg_overrides)
    # two shallow unrolled lowerings -> linear extrapolation in depth
    step = cfg.hybrid.attn_every if cfg.family == "hybrid" else 1
    la, lb = 2 * step, 6 * step
    fa, ba, ca = _cost_once(arch, shape_name, ruleset, moe_dispatch,
                            cfg_overrides, n_layers=la)
    fb, bb, cb = _cost_once(arch, shape_name, ruleset, moe_dispatch,
                            cfg_overrides, n_layers=lb)
    t = (L - la) / (lb - la)             # layers beyond la, in lb-la units
    flops = fa + t * (fb - fa)
    bytes_acc = ba + t * (bb - ba)
    coll = _coll_combine(ca, cb, 1.0 - t, t)
    return flops, bytes_acc, coll


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             ruleset: str = "default", outdir: Path,
             moe_dispatch: str | None = None, tag: str = "",
             cfg_overrides: dict | None = None) -> dict:
    meshname = "multipod" if multi_pod else "pod"
    cell_id = f"{arch}__{shape_name}__{meshname}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    try:
        # artifact lowering: production config (scanned layers)
        cfg, shape, mesh, lowered = lower_cell(
            arch, shape_name, multi_pod=multi_pod, ruleset=ruleset,
            moe_dispatch=moe_dispatch, cfg_overrides=cfg_overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        # cost lowering: unrolled layers (single-pod) — XLA cost_analysis
        # counts while-loop bodies once, so the scanned artifact
        # under-reports per-step FLOPs/bytes/collectives by ~n_layers.
        # Deep stacks are depth-extrapolated from two shallow unrolled
        # lowerings (exact for homogeneous layer stacks; hybrid uses
        # group-multiples — see _cost_terms).
        if not multi_pod:
            flops, bytes_acc, coll = _cost_terms(
                arch, shape_name, ruleset, moe_dispatch, cfg_overrides, cfg)
        else:
            cost = compiled.cost_analysis()
            coll = parse_collectives(compiled.as_text())
            flops = float(cost.get("flops", 0.0))
            bytes_acc = float(cost.get("bytes accessed", 0.0))

        n_chips = mesh.devices.size
        link_bytes = collective_link_bytes(coll)
        # MODEL_FLOPS: 6·N·D for training (fwd 2ND + bwd 4ND), 2·N·D for
        # inference; D = tokens processed. N = active params (MoE: top-k).
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind in ("train", "prefill")
                  else shape.global_batch)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.active_param_count() * tokens

        result = {
            "cell": cell_id, "arch": arch, "shape": shape_name,
            "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
            "ok": True,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            },
            "per_device": {
                "hlo_flops": flops,
                "hlo_bytes": bytes_acc,
                "collective_bytes": coll["bytes"],
                "collective_counts": coll["counts"],
                "collective_link_bytes": link_bytes,
            },
            "roofline": {
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": bytes_acc / HBM_BW,
                "collective_s": link_bytes / ICI_BW,
            },
            "model_flops_global": model_flops,
            "model_flops_per_device": model_flops / n_chips,
            "useful_flops_ratio": (model_flops / n_chips) / max(flops, 1.0),
        }
        terms = result["roofline"]
        result["dominant"] = max(terms, key=terms.get)
    except Exception as e:  # noqa: BLE001 — report failures as data
        result = {"cell": cell_id, "arch": arch, "shape": shape_name,
                  "ok": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / f"{cell_id}.json").write_text(json.dumps(result, indent=1))
    status = "OK " if result.get("ok") else "FAIL"
    dom = result.get("dominant", "-")
    print(f"[{status}] {cell_id:56s} dom={dom} "
          f"compile={result.get('compile_s', '-')}s", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ruleset", default="default")
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--remat", default=None,
                    help="override remat policy (none|dots|full|collectives)")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override, e.g. --set attn_softmax_f32=False")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)

    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required unless --all"
        shapes = ([args.shape] if args.shape else
                  [s.name for s in applicable_shapes(get_config(args.arch))])
        cells = [(args.arch, s) for s in shapes]

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            mn = "multipod" if mp else "pod"
            cid = f"{arch}__{shape}__{mn}" + (f"__{args.tag}" if args.tag
                                              else "")
            if args.skip_existing and (outdir / f"{cid}.json").exists():
                prev = json.loads((outdir / f"{cid}.json").read_text())
                if prev.get("ok"):
                    print(f"[SKIP] {cid}", flush=True)
                    continue
            overrides = {"remat": args.remat} if args.remat else {}
            import ast
            for kv in getattr(args, "set"):
                key, val = kv.split("=", 1)
                try:
                    val = ast.literal_eval(val)
                except (ValueError, SyntaxError):
                    pass
                overrides[key] = val
            overrides = overrides or None
            r = run_cell(arch, shape, multi_pod=mp, ruleset=args.ruleset,
                         outdir=outdir, moe_dispatch=args.moe_dispatch,
                         tag=args.tag, cfg_overrides=overrides)
            n_fail += 0 if r.get("ok") else 1
    print(f"done; failures={n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
