from repro.kernels.gather_intersect.ops import (  # noqa: F401
    gather_intersect_many)
