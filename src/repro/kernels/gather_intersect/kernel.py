"""Pallas TPU kernel: sparse gather-intersect support counting.

counts[b, e] = Σ_s bit(exts[b, e], tids[b, s])

This is the hybrid representation's sparse sweep: each request's
prefix row arrives as a sorted tid-list (or dEclat diffset — the
kernel doesn't care which), and instead of AND+popcount over all W
words, the kernel walks the S tids and for each one gathers a single
ext word and tests a single bit — O(S) work per extension regardless
of row width.

Layout: the extension block is held WORD-MAJOR ([W, E_TILE]) so the
per-tid dynamic index lands on the sublane axis (supported scalar
dynamic indexing, per the Pallas TPU guide) and the gathered slice
``exts_t[ds(w, 1), :]`` is a full E_TILE lane vector — one VPU op per
tid covers the whole extension tile. The tid walk is a fori_loop with
padded lanes carrying the sentinel -1 (masked, not skipped: the loop
trip count must be static).

VMEM: the whole W axis of one request's extension tile is resident
([W_pad, E_TILE] uint32 = W_pad·512 B), fine up to ~16K words (512K
transactions per segment). Past that a W-tiled variant with a
tid-in-tile guard would be needed; the cost model picks the dense
kernel long before rows get both that wide and sparse-worthy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

E_TILE = 128     # lane width of one extension tile
W_SUB = 8        # sublane multiple for the word-major axis


def _many_kernel(tids_ref, exts_ref, out_ref):
    # tids_ref: [1, S]; exts_ref: [1, W, E_TILE] (word-major);
    # out_ref: [1, E_TILE]
    s_len = tids_ref.shape[1]

    def body(s, acc):
        t = tids_ref[0, s]
        tt = jnp.maximum(t, 0)
        w = tt >> 5
        bit = (tt & 31).astype(jnp.uint32)
        row = exts_ref[0, pl.ds(w, 1), :]              # [1, E_TILE]
        bits = ((row >> bit) & jnp.uint32(1)).astype(jnp.int32)
        return acc + jnp.where(t >= 0, bits, 0)

    acc0 = jnp.zeros(out_ref.shape, jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, s_len, body, acc0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_intersect_many_kernel(tids: jnp.ndarray, exts: jnp.ndarray,
                                 *, interpret: bool = False
                                 ) -> jnp.ndarray:
    """tids: [B, S] int32 (-1 = padded lane); exts: [B, E, W] uint32
    -> counts [B, E] int32.

    E is padded to E_TILE, W to a sublane multiple (padded words are
    never gathered: every valid tid is < 32·W). The extension block is
    transposed word-major on device before the launch.
    """
    b, e, w = exts.shape
    ep = (e + E_TILE - 1) // E_TILE * E_TILE
    wp = max((w + W_SUB - 1) // W_SUB * W_SUB, W_SUB)
    if (ep, wp) != (e, w):
        exts = jnp.pad(exts, ((0, 0), (0, ep - e), (0, wp - w)))
    exts_t = jnp.transpose(exts, (0, 2, 1))            # [B, Wp, Ep]
    grid = (b, ep // E_TILE)
    out = pl.pallas_call(
        _many_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tids.shape[1]), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, wp, E_TILE), lambda bi, i: (bi, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, E_TILE), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, ep), jnp.int32),
        interpret=interpret,
    )(tids, exts_t)
    return out[:, :e]
