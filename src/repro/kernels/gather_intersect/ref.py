"""Oracles for the gather_intersect kernel (jnp + pure numpy).

counts[b, e] = |{s : tids[b, s] valid and bit tids[b, s] set in
exts[b, e]}| — the sparse sweep: one word gathered and one bit tested
per (ext, tid) pair, O(S) per extension regardless of row width W.
Invalid (padded) tid lanes carry the sentinel -1.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gather_intersect_many_ref(tids: jnp.ndarray, exts: jnp.ndarray
                              ) -> jnp.ndarray:
    """tids: [B, S] int32 (-1 = padded lane); exts: [B, E, W] uint32
    -> counts [B, E] int32."""
    w = exts.shape[-1]
    valid = tids >= 0
    t = jnp.where(valid, tids, 0).astype(jnp.uint32)
    wi = jnp.minimum((t >> 5).astype(jnp.int32), w - 1)
    bi = (t & jnp.uint32(31)).astype(jnp.uint32)
    words = jnp.take_along_axis(exts, wi[:, None, :], axis=2)  # [B,E,S]
    bits = (words >> bi[:, None, :]) & jnp.uint32(1)
    bits = jnp.where(valid[:, None, :], bits, 0)
    return bits.sum(axis=2).astype(jnp.int32)


def gather_intersect_many_np(tids: np.ndarray, exts: np.ndarray
                             ) -> np.ndarray:
    """Pure-numpy twin of :func:`gather_intersect_many_ref` — the
    host-side reference the parity tests pit against pallas-interpret."""
    w = exts.shape[-1]
    valid = tids >= 0
    t = np.where(valid, tids, 0).astype(np.uint32)
    wi = np.minimum((t >> np.uint32(5)).astype(np.int64), w - 1)
    bi = (t & np.uint32(31)).astype(np.uint32)
    words = np.take_along_axis(exts, wi[:, None, :], axis=2)   # [B,E,S]
    bits = (words >> bi[:, None, :]) & np.uint32(1)
    bits = np.where(valid[:, None, :], bits, 0)
    return bits.sum(axis=2).astype(np.int32)
