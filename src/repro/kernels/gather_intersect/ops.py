"""jit'd public wrapper for the sparse gather-intersect sweep.

Mirrors ``bitmap_join.ops``: one lru-cached jit wrapper per reference
function (fresh per-call ``jax.jit`` would re-trace every shape), and
the same four execution modes so ``SweepDispatcher`` backends can put
dense and sparse batches of one flush through matching strategies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_intersect.kernel import (
    gather_intersect_many_kernel)
from repro.kernels.gather_intersect.ref import gather_intersect_many_ref

MODES = ("auto", "ref", "pallas-interpret", "pallas-jit")


@functools.lru_cache(maxsize=None)
def _jitted(fn):
    return jax.jit(fn)


def gather_intersect_many(tids: jnp.ndarray, exts: jnp.ndarray,
                          mask: jnp.ndarray | None = None,
                          *, mode: str = "auto") -> jnp.ndarray:
    """Batched sparse sweep: counts[b, e] = |tids[b] ∩ exts[b, e]|.

    tids: [B, S] int32 sorted per row, padded with -1 (ragged batches);
    exts: [B, E, W] uint32 word-columns; optional mask [B, E] bool
    zeroes padded extension lanes. An empty tid axis (S == 0) is the
    all-empty-intersection fast path — no launch at all.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    b, e, _ = exts.shape
    if tids.shape[1] == 0:
        return jnp.zeros((b, e), jnp.int32)
    if mode == "ref":
        counts = _jitted(gather_intersect_many_ref)(tids, exts)
    elif mode == "pallas-interpret":
        counts = gather_intersect_many_kernel(tids, exts, interpret=True)
    elif mode == "pallas-jit":
        counts = gather_intersect_many_kernel(tids, exts, interpret=False)
    else:                                     # auto: Pallas on TPU only
        if jax.default_backend() == "tpu":
            counts = gather_intersect_many_kernel(tids, exts,
                                                  interpret=False)
        else:
            counts = _jitted(gather_intersect_many_ref)(tids, exts)
    if mask is not None:
        counts = jnp.where(mask, counts, 0)
    return counts
