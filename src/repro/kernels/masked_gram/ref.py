"""Pure-jnp oracle for the masked_gram kernel."""
from __future__ import annotations

import jax.numpy as jnp


def masked_gram_ref(a: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """a: [I, T] {0,1}; mask: [T] {0,1} -> C [I, I] f32."""
    a32 = a.astype(jnp.float32)
    am = a32 * mask.astype(jnp.float32)[None, :]
    return am @ a32.T
