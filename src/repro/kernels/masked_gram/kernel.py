"""Pallas TPU kernel: masked Gram matrix — whole-cluster pair counting.

C[i, j] = Σ_t A[i, t] · A[j, t] · m[t]

A is a cluster's item-presence matrix in {0,1} bf16, m the (k-1)-prefix
transaction mask: C[i, j] = support(prefix ∪ {i, j}) for ALL extension
pairs at once. This is the beyond-paper TPU adaptation (DESIGN.md §3): the
paper co-schedules a cluster's tasks for cache reuse; the MXU lets us fuse
the entire cluster into ONE systolic matmul — the prefix mask is applied
to a VMEM-resident tile and reused across the full j-sweep.

Tiling: 128×128 output tiles, T streamed in 512-column steps; bf16
multiplies, f32 accumulation — MXU-native shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I_TILE = 128
T_TILE = 512


def _kernel(a_ref, b_ref, m_ref, out_ref):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                                 # [It, Tt] bf16
    b = b_ref[...]                                 # [Jt, Tt] bf16
    m = m_ref[...]                                 # [1, Tt] bf16
    am = a * m                                     # prefix mask fused once
    out_ref[...] += jax.lax.dot_general(
        am, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_gram_kernel(a: jnp.ndarray, mask: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """a: [I, T] bf16 {0,1}; mask: [T] bf16 {0,1} -> C [I, I] f32."""
    i, t = a.shape
    ip = (i + I_TILE - 1) // I_TILE * I_TILE
    tp = (t + T_TILE - 1) // T_TILE * T_TILE
    if (ip, tp) != (i, t):
        a = jnp.pad(a, ((0, ip - i), (0, tp - t)))
        mask = jnp.pad(mask, (0, tp - t))
    grid = (ip // I_TILE, ip // I_TILE, tp // T_TILE)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((I_TILE, T_TILE), lambda i, j, k: (i, k)),
            pl.BlockSpec((I_TILE, T_TILE), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, T_TILE), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((I_TILE, I_TILE), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ip, ip), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.bfloat16), a.astype(jnp.bfloat16),
      mask.astype(jnp.bfloat16)[None, :])
    return out[:i, :i]
