"""jit'd public wrapper for masked_gram."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_gram.kernel import masked_gram_kernel
from repro.kernels.masked_gram.ref import masked_gram_ref


def masked_gram(a: jnp.ndarray, mask: jnp.ndarray,
                *, use_pallas: bool | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Pair supports for a whole cluster: C[i,j] = |prefix ∩ i ∩ j|."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return jax.jit(masked_gram_ref)(a, mask)
    return masked_gram_kernel(a, mask,
                              interpret=bool(interpret if interpret
                                             is not None else not on_tpu))
