from repro.kernels.masked_gram.ops import masked_gram  # noqa: F401
