"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q: [BH, S, D]; k, v: [BH, T, D] -> [BH, S, D] (fp32 softmax)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qn, kn = q.shape[1], k.shape[1]
        mask = jnp.arange(kn)[None, :] <= jnp.arange(qn)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
