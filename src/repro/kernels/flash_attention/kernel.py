"""Pallas TPU kernel: causal flash attention (online softmax).

Grid (batch*heads, q_blocks, kv_blocks) with the kv sweep innermost. The
q tile, running max m, running denominator l, and the f32 accumulator
live in VMEM scratch across the kv sweep; k/v tiles stream HBM→VMEM.
The LM stack uses this on TPU for the 32k-prefill hot spot
(cfg.use_pallas); the q-chunked jnp path in models/attention.py is the
CPU/dry-run equivalent, and ref.py is the oracle both must match.

Block sizes 128 (q) × 128 (kv): MXU-aligned; VMEM per step ≈
q(128·D) + k,v(2·128·D) + acc(128·D f32) ≈ 256 KiB at D=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLK = 128
KV_BLK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [Qb, D]
    k = k_ref[0]                                   # [Kb, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * Q_BLK + jax.lax.broadcasted_iota(jnp.int32,
                                                      (Q_BLK, KV_BLK), 0)
        k_pos = ki * KV_BLK + jax.lax.broadcasted_iota(jnp.int32,
                                                       (Q_BLK, KV_BLK), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]                            # [Qb, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # [Qb, Kb]
    alpha = jnp.exp(m_prev - m_new)                # rescale old state
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "interpret"))
def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [BH, S, D]; k, v: [BH, T, D] -> o: [BH, S, D].

    S, T padded to block multiples; padded kv columns are masked out by
    the causal mask (pad queries produce garbage rows that are sliced
    off; with causal=False, pad kv is masked via an explicit length
    check baked into the k-position iota when T % KV_BLK != 0 — callers
    should pad-and-slice, which the ops wrapper does).
    """
    bh, s, d = q.shape
    t = k.shape[1]
    sp = (s + Q_BLK - 1) // Q_BLK * Q_BLK
    tp = (t + KV_BLK - 1) // KV_BLK * KV_BLK
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0)))
    if tp != t:
        k = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    grid = (bh, sp // Q_BLK, tp // KV_BLK)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q_BLK, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, KV_BLK, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, KV_BLK, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_BLK, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q_BLK, 1), jnp.float32),    # running max m
            pltpu.VMEM((Q_BLK, 1), jnp.float32),    # running denom l
            pltpu.VMEM((Q_BLK, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
