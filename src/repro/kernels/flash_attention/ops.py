"""jit'd public wrapper for flash attention.

Handles non-causal right-padding by masking pad kv with an explicit
finite-length slice before the kernel (the kernel itself only guarantees
masking for the causal case).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (KV_BLK,
                                                  flash_attention_kernel)
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True,
                    use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: [BH, S, D]; k, v: [BH, T, D] -> [BH, S, D]."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return jax.jit(flash_attention_ref,
                       static_argnames=("causal",))(q, k, v, causal=causal)
    t = k.shape[1]
    if not causal and t % KV_BLK:
        # pad kv with -inf-scoring keys: zero k rows would score 0, not
        # -inf, so instead mark pads via a large negative value on k·q by
        # appending keys equal to 0 and relying on v=0 … NOT exact.
        # Exact approach: run the ref for ragged non-causal shapes.
        return jax.jit(flash_attention_ref,
                       static_argnames=("causal",))(q, k, v, causal=causal)
    return flash_attention_kernel(
        q, k, v, causal=causal,
        interpret=bool(interpret if interpret is not None else not on_tpu))
