"""jit'd public wrapper: Pallas on TPU, interpret-mode or jnp on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_join.kernel import bitmap_join_kernel
from repro.kernels.bitmap_join.ref import bitmap_join_ref

MODES = ("auto", "ref", "pallas-interpret", "pallas-jit")


def bitmap_join(prefix: jnp.ndarray, exts: jnp.ndarray,
                *, use_pallas: bool | None = None,
                interpret: bool | None = None,
                mode: str = "auto") -> jnp.ndarray:
    """Support counts of prefix∧ext for a cluster of extension bitmaps.

    ``mode`` names an execution strategy explicitly (used by
    ``repro.core.join_backend``): "ref" runs the jnp oracle, "pallas-jit"
    compiles the Pallas kernel for the current backend, and
    "pallas-interpret" runs the same kernel under the Pallas interpreter
    (bit-exact with "pallas-jit", available on CPU). "auto" keeps the
    legacy behaviour: Pallas on TPU, jnp ref elsewhere, unless the
    ``use_pallas``/``interpret`` flags override it.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "ref":
        return jax.jit(bitmap_join_ref)(prefix, exts)
    if mode == "pallas-interpret":
        return bitmap_join_kernel(prefix, exts, interpret=True)
    if mode == "pallas-jit":
        return bitmap_join_kernel(prefix, exts, interpret=False)
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return jax.jit(bitmap_join_ref)(prefix, exts)
    return bitmap_join_kernel(prefix, exts,
                              interpret=bool(interpret if interpret
                                             is not None else not on_tpu))
