"""jit'd public wrapper: Pallas on TPU, interpret-mode or jnp on CPU.

Compiled-function caching: the Pallas kernels are jitted once at module
level (``kernel.py``), and the jnp reference paths go through
:func:`_jitted`, an lru-cached factory — so a wrapper is built once per
function and jax's own shape-keyed cache handles the rest. The old
pattern of calling ``jax.jit(fn)`` inline created a FRESH wrapper per
call, which re-traced every level of a mining run (the per-level
recompilation bug the distributed driver used to have with its
``functools.partial``-wrapped ``shard_map`` bodies)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_join.kernel import (bitmap_join_kernel,
                                              bitmap_join_many_kernel)
from repro.kernels.bitmap_join.ref import (bitmap_join_many_ref,
                                           bitmap_join_ref)

MODES = ("auto", "ref", "pallas-interpret", "pallas-jit")


@functools.lru_cache(maxsize=None)
def _jitted(fn):
    """One persistent jit wrapper per reference function. jax keys its
    compile cache on the wrapper object, so re-wrapping per call would
    re-trace on every invocation."""
    return jax.jit(fn)


def bitmap_join(prefix: jnp.ndarray, exts: jnp.ndarray,
                *, use_pallas: bool | None = None,
                interpret: bool | None = None,
                mode: str = "auto") -> jnp.ndarray:
    """Support counts of prefix∧ext for a cluster of extension bitmaps.

    ``mode`` names an execution strategy explicitly (used by
    ``repro.core.join_backend``): "ref" runs the jnp oracle, "pallas-jit"
    compiles the Pallas kernel for the current backend, and
    "pallas-interpret" runs the same kernel under the Pallas interpreter
    (bit-exact with "pallas-jit", available on CPU). "auto" keeps the
    legacy behaviour: Pallas on TPU, jnp ref elsewhere, unless the
    ``use_pallas``/``interpret`` flags override it.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "ref":
        return _jitted(bitmap_join_ref)(prefix, exts)
    if mode == "pallas-interpret":
        return bitmap_join_kernel(prefix, exts, interpret=True)
    if mode == "pallas-jit":
        return bitmap_join_kernel(prefix, exts, interpret=False)
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return _jitted(bitmap_join_ref)(prefix, exts)
    return bitmap_join_kernel(prefix, exts,
                              interpret=bool(interpret if interpret
                                             is not None else not on_tpu))


def bitmap_join_many(prefixes: jnp.ndarray, exts: jnp.ndarray,
                     mask: jnp.ndarray | None = None,
                     *, mode: str = "auto") -> jnp.ndarray:
    """Batched multi-prefix join: counts[b, e] = |prefixes[b] ∧ exts[b, e]|.

    prefixes: [B, W] uint32; exts: [B, E_max, W] uint32; optional mask
    [B, E_max] bool zeroes padded lanes of ragged batches (the sweep
    dispatcher pads every request to E_max). One kernel launch covers
    all B requests — the dispatcher's coalescing unit.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "ref":
        counts = _jitted(bitmap_join_many_ref)(prefixes, exts)
    elif mode == "pallas-interpret":
        counts = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    elif mode == "pallas-jit":
        counts = bitmap_join_many_kernel(prefixes, exts, interpret=False)
    else:                                     # auto: Pallas on TPU only
        if jax.default_backend() == "tpu":
            counts = bitmap_join_many_kernel(prefixes, exts,
                                             interpret=False)
        else:
            counts = _jitted(bitmap_join_many_ref)(prefixes, exts)
    if mask is not None:
        counts = jnp.where(mask, counts, 0)
    return counts
