"""jit'd public wrapper: Pallas on TPU, interpret-mode or jnp on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_join.kernel import bitmap_join_kernel
from repro.kernels.bitmap_join.ref import bitmap_join_ref


def bitmap_join(prefix: jnp.ndarray, exts: jnp.ndarray,
                *, use_pallas: bool | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Support counts of prefix∧ext for a cluster of extension bitmaps."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return jax.jit(bitmap_join_ref)(prefix, exts)
    return bitmap_join_kernel(prefix, exts,
                              interpret=bool(interpret if interpret
                                             is not None else not on_tpu))
