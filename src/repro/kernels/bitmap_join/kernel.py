"""Pallas TPU kernel: TID-bitmap join (AND + popcount) support counting.

counts[e] = Σ_w popcount(prefix[w] & exts[e, w])

This is the paper's per-task join restructured for the TPU memory
hierarchy: the shared (k-1)-prefix bitmap tile is held in VMEM across the
whole extension-tile sweep (the clustered policy's cache reuse, made
structural), while extension bitmaps stream HBM→VMEM. Popcount is
`lax.population_count` on the VPU; the W-tile accumulation runs in the
innermost grid dimension with an @pl.when(first)-guarded init.

Tiling: E×W = 256×512 words per step → exts tile 512 KiB (uint32),
prefix tile 2 KiB, counts tile 1 KiB — comfortably VMEM-resident, lanes
aligned (512 words = 4×128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

E_TILE = 256
W_TILE = 512


def _kernel(prefix_ref, exts_ref, out_ref):
    w_idx = pl.program_id(1)

    @pl.when(w_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = prefix_ref[...]                       # [1, Wt] uint32 (VMEM)
    e = exts_ref[...]                         # [Et, Wt] uint32
    joined = jnp.bitwise_and(e, p)            # broadcast over E
    counts = jax.lax.population_count(joined).astype(jnp.int32)
    out_ref[...] += jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_join_kernel(prefix: jnp.ndarray, exts: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """prefix: [W] uint32; exts: [E, W] uint32 -> counts [E] int32.

    E and W are padded to tile multiples (zero words count nothing).
    """
    e, w = exts.shape
    ep = (e + E_TILE - 1) // E_TILE * E_TILE
    wp = (w + W_TILE - 1) // W_TILE * W_TILE
    if (ep, wp) != (e, w):
        exts = jnp.pad(exts, ((0, ep - e), (0, wp - w)))
        prefix = jnp.pad(prefix, (0, wp - w))
    grid = (ep // E_TILE, wp // W_TILE)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, W_TILE), lambda i, j: (0, j)),
            pl.BlockSpec((E_TILE, W_TILE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((E_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), jnp.int32),
        interpret=interpret,
    )(prefix[None, :], exts)
    return out[:e]


# ---------------------------------------------------------------------------
# Multi-prefix (batched) variant: one grid launch for B coalesced sweeps
# ---------------------------------------------------------------------------

# The batched kernel serves dispatcher batches where most requests are
# narrow (tens of extensions), so its E-tile is smaller than the
# single-prefix kernel's: [64, 512] words = 128 KiB uint32 per exts
# block, still lane-aligned (512 = 4×128) and VMEM-comfortable.
EB_TILE = 64
WB_TILE = 512


def _many_kernel(prefixes_ref, exts_ref, out_ref):
    w_idx = pl.program_id(2)

    @pl.when(w_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = prefixes_ref[...]                     # [1, Wt] uint32 (VMEM,
                                              # resident across the
                                              # request's E sweep)
    e = exts_ref[0]                           # [Et, Wt] uint32
    joined = jnp.bitwise_and(e, p)            # broadcast over E
    counts = jax.lax.population_count(joined).astype(jnp.int32)
    out_ref[0, :] += jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_join_many_kernel(prefixes: jnp.ndarray, exts: jnp.ndarray,
                            *, interpret: bool = False) -> jnp.ndarray:
    """prefixes: [B, W] uint32; exts: [B, E, W] uint32 -> [B, E] int32.

    B coalesced sweep requests share one grid launch; within each
    batch row the request's prefix tile stays VMEM-resident across its
    extension sweep (same reuse as the single-prefix kernel). E and W
    are padded to tile multiples — zero words count nothing, and the
    dispatcher slices each request's real extension count out.
    """
    b, e, w = exts.shape
    ep = (e + EB_TILE - 1) // EB_TILE * EB_TILE
    wp = (w + WB_TILE - 1) // WB_TILE * WB_TILE
    if (ep, wp) != (e, w):
        exts = jnp.pad(exts, ((0, 0), (0, ep - e), (0, wp - w)))
        prefixes = jnp.pad(prefixes, ((0, 0), (0, wp - w)))
    grid = (b, ep // EB_TILE, wp // WB_TILE)
    out = pl.pallas_call(
        _many_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, WB_TILE), lambda bi, i, j: (bi, j)),
            pl.BlockSpec((1, EB_TILE, WB_TILE), lambda bi, i, j: (bi, i, j)),
        ],
        out_specs=pl.BlockSpec((1, EB_TILE), lambda bi, i, j: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, ep), jnp.int32),
        interpret=interpret,
    )(prefixes, exts)
    return out[:, :e]
