"""Pallas TPU kernel: TID-bitmap join (AND + popcount) support counting.

counts[e] = Σ_w popcount(prefix[w] & exts[e, w])

This is the paper's per-task join restructured for the TPU memory
hierarchy: the shared (k-1)-prefix bitmap tile is held in VMEM across the
whole extension-tile sweep (the clustered policy's cache reuse, made
structural), while extension bitmaps stream HBM→VMEM. Popcount is
`lax.population_count` on the VPU; the W-tile accumulation runs in the
innermost grid dimension with an @pl.when(first)-guarded init.

Tiling: E×W = 256×512 words per step → exts tile 512 KiB (uint32),
prefix tile 2 KiB, counts tile 1 KiB — comfortably VMEM-resident, lanes
aligned (512 words = 4×128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

E_TILE = 256
W_TILE = 512


def _kernel(prefix_ref, exts_ref, out_ref):
    w_idx = pl.program_id(1)

    @pl.when(w_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = prefix_ref[...]                       # [1, Wt] uint32 (VMEM)
    e = exts_ref[...]                         # [Et, Wt] uint32
    joined = jnp.bitwise_and(e, p)            # broadcast over E
    counts = jax.lax.population_count(joined).astype(jnp.int32)
    out_ref[...] += jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_join_kernel(prefix: jnp.ndarray, exts: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """prefix: [W] uint32; exts: [E, W] uint32 -> counts [E] int32.

    E and W are padded to tile multiples (zero words count nothing).
    """
    e, w = exts.shape
    ep = (e + E_TILE - 1) // E_TILE * E_TILE
    wp = (w + W_TILE - 1) // W_TILE * W_TILE
    if (ep, wp) != (e, w):
        exts = jnp.pad(exts, ((0, ep - e), (0, wp - w)))
        prefix = jnp.pad(prefix, (0, wp - w))
    grid = (ep // E_TILE, wp // W_TILE)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, W_TILE), lambda i, j: (0, j)),
            pl.BlockSpec((E_TILE, W_TILE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((E_TILE,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), jnp.int32),
        interpret=interpret,
    )(prefix[None, :], exts)
    return out[:e]
