"""Pure-jnp oracle for the bitmap_join kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitmap_join_ref(prefix: jnp.ndarray, exts: jnp.ndarray) -> jnp.ndarray:
    """prefix: [W] uint32; exts: [E, W] uint32 -> counts [E] int32."""
    joined = jnp.bitwise_and(exts, prefix[None, :])
    return jnp.sum(jax.lax.population_count(joined).astype(jnp.int32),
                   axis=1)


def bitmap_join_many_ref(prefixes: jnp.ndarray, exts: jnp.ndarray
                         ) -> jnp.ndarray:
    """Batched (multi-prefix) oracle: prefixes [B, W] uint32, exts
    [B, E, W] uint32 -> counts [B, E] int32. One batch row per sweep
    request; masking of ragged/padded lanes happens in ops."""
    joined = jnp.bitwise_and(exts, prefixes[:, None, :])
    return jnp.sum(jax.lax.population_count(joined).astype(jnp.int32),
                   axis=2)
