from repro.kernels.bitmap_join.ops import bitmap_join  # noqa: F401
