from repro.kernels.bitmap_join.ops import (bitmap_join,  # noqa: F401
                                           bitmap_join_many)
