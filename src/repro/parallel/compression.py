"""Gradient compression: int8 quantized all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound DP at scale: gradients
are quantized to int8 per-tensor-scale before the cross-pod all-reduce
and dequantized after; the quantization residual is carried into the next
step (error feedback keeps the optimizer unbiased in expectation).
Applied only to the DP reduction (the `pod` axis is the thin inter-pod
link where 4x byte reduction matters most).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: PyTree, residual: PyTree
                   ) -> Tuple[PyTree, PyTree, PyTree]:
    """Returns (quantized int8 tree, scales tree, new residual tree).

    residual: error-feedback carry from the previous step (same structure
    as grads; pass zeros on step 0)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return q, s, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]),
            tdef.unflatten([o[2] for o in out]))


def decompress_grads(qs: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(
        lambda q, s: dequantize_int8(q, s), qs, scales)


def zeros_like_residual(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def psum_compressed(grads: PyTree, residual: PyTree, axis_name: str
                    ) -> Tuple[PyTree, PyTree]:
    """Inside shard_map/pmap: all-reduce int8 (4x fewer bytes on the
    wire), dequantize, return (mean grads, new residual)."""
    qs, scales, new_res = compress_grads(grads, residual)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), qs)
    # per-shard scales differ: reduce with max-scale dequantization bound
    smax = jax.tree.map(lambda s: jax.lax.pmax(s, axis_name), scales)
    mean = jax.tree.map(
        lambda acc, s: acc.astype(jnp.float32) * s / n, summed, smax)
    return mean, new_res
