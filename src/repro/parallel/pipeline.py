"""Pipeline parallelism: GPipe-style microbatch schedule under shard_map.

Stages are laid out on a mesh axis; activations move stage→stage with
collective_permute. The schedule is the classic fill-drain loop written
as a lax.scan over (n_micro + n_stages - 1) ticks: at tick t, stage s
processes microbatch (t - s) — a deterministic, compiler-visible
schedule (no host round-trips), which is what makes it usable at pod
scale. Bubble fraction = (S-1)/(M+S-1).

This module is deliberately self-contained (stage_fn in, schedule out) so
any of the zoo's block stacks can be pipelined; used by the optional
`pipeline_stages > 1` RunConfig path and tested on a CPU mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_apply(stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
                   stage_params: PyTree, x: jnp.ndarray, *, mesh: Mesh,
                   axis: str = "stage", n_micro: int = 4) -> jnp.ndarray:
    """x: [B, ...] -> stage_{S-1}(...stage_0(x)); stages sharded on `axis`.

    stage_params: leaves with leading dim = n_stages (sharded over axis).
    """
    n_stages = mesh.shape[axis]
    assert x.shape[0] % n_micro == 0, (x.shape, n_micro)
    mb = x.shape[0] // n_micro

    def local(params_s, x_all):
        # params_s: this stage's params (leading dim 1); x_all: [B, ...]
        params_s = jax.tree.map(lambda a: a[0], params_s)
        sid = jax.lax.axis_index(axis)
        micro = x_all.reshape((n_micro, mb) + x_all.shape[1:])
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        outs = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            take = jnp.clip(t, 0, n_micro - 1)
            fresh = micro[take]
            inp = jnp.where(sid == 0, fresh, buf)
            valid = (t - sid >= 0) & (t - sid < n_micro)
            y = stage_fn(params_s, inp)
            y = jnp.where(valid, y, buf)
            # last stage banks its result at slot (t - S + 1)
            slot = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            done = (sid == n_stages - 1) & (t - sid >= 0) & (t - sid
                                                             < n_micro)
            outs = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (slot,) + (0,) * y.ndim),
                lambda o: o, outs)
            # shift the pipe: stage s -> stage s+1
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages)
                          for i in range(n_stages)])
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs.reshape(x_all.shape)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
