"""Logical-axis → mesh-axis sharding rules (MaxText-style).

A *ruleset* maps each logical axis name to an ordered list of candidate
physical axis groups. Resolution is shape-aware: a candidate is taken only
if the dimension is divisible by the group's total mesh size and none of
its axes are already used in the spec — so the same ruleset serves every
architecture (40-head models silently fall back to replicated attention
rather than failing to partition; see DESIGN.md §5).

DP axes are ("pod", "data") — "pod" exists only on the multi-pod mesh and
is skipped automatically on single-pod meshes.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]

# candidates: logical name -> list of physical axis groups (tried in order)
RULESETS: Dict[str, Dict[str, List[Axes]]] = {
    # FSDP default: weights sharded over DP axes on their 'embed' dim and
    # over 'model' on their width dims; activations sharded over batch.
    "default": {
        "batch":     [("pod", "data"), ("data",)],
        "act_batch": [("pod", "data"), ("data",)],
        # Megatron-style sequence parallelism: residual-stream activations
        # (and remat-saved layer inputs) shard their seq dim over `model`
        "act_seq":   [("model",)],
        "act_embed": [],
        "act_heads": [("model",)],
        "embed":     [("pod", "data"), ("data",)],   # FSDP / ZeRO-3
        "vocab":     [("model",)],
        "heads":     [("model",)],
        "kv_heads":  [("model",)],
        "head_dim":  [],
        "ff":        [("model",)],
        "experts":   [("model",)],
        "ssm_inner": [("model",)],
        "ssm_heads": [("model",)],
        "cache_seq": [],
        "layers":    [],
        "seq":       [],
        "frames":    [],
    },
    # pure DP + TP (no FSDP): weights replicated over data axes
    "no_fsdp": {
        "batch":     [("pod", "data"), ("data",)],
        "act_batch": [("pod", "data"), ("data",)],
        "act_seq":   [],
        "act_embed": [],
        "act_heads": [("model",)],
        "embed":     [],
        "vocab":     [("model",)],
        "heads":     [("model",)],
        "kv_heads":  [("model",)],
        "head_dim":  [],
        "ff":        [("model",)],
        "experts":   [("model",)],
        "ssm_inner": [("model",)],
        "ssm_heads": [("model",)],
        "cache_seq": [],
        "layers":    [],
        "seq":       [],
        "frames":    [],
    },
    # decode ruleset: KV-cache sequence axis takes `model` when the head
    # axes cannot (sequence-parallel decode attention).
    "decode": {
        "batch":     [("pod", "data"), ("data",)],
        "act_batch": [("pod", "data"), ("data",)],
        "act_seq":   [],   # decode: seq dim is 1
        "act_embed": [],
        "act_heads": [("model",)],
        "embed":     [("pod", "data"), ("data",)],
        "vocab":     [("model",)],
        "heads":     [("model",)],
        "kv_heads":  [("model",)],
        "head_dim":  [],
        "ff":        [("model",)],
        "experts":   [("model",)],
        "ssm_inner": [("model",)],
        "ssm_heads": [("model",)],
        "cache_seq": [("model",)],
        "layers":    [],
        "seq":       [],
        "frames":    [],
    },
}


def _mesh_size(mesh: Mesh, group: Axes) -> int:
    return int(np.prod([mesh.shape[a] for a in group]))


# low-priority logical axes only claim mesh axes AFTER everything else
# had a chance (e.g. decode cache_seq takes `model` only when the head
# axes can't use it)
_LOW_PRIORITY = {"cache_seq": 1, "act_seq": 1}


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Dict[str, List[Axes]]) -> P:
    """Resolve logical axes for a concrete shape into a PartitionSpec."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    parts: List[Optional[Axes]] = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda i: _LOW_PRIORITY.get(logical[i] or "", 0))
    for i in order:
        dim, name = shape[i], logical[i]
        chosen: Optional[Axes] = None
        if name is not None:
            for group in rules.get(name, []):
                group = tuple(a for a in group if a in mesh.shape)
                if not group or any(a in used for a in group):
                    continue
                if dim % _mesh_size(mesh, group) == 0:
                    chosen = group
                    break
        if chosen:
            used.update(chosen)
            parts[i] = chosen if len(chosen) > 1 else chosen[0]
    return P(*parts)


def tree_specs(shapes, axes, mesh: Mesh, rules) -> "jax.tree":
    """Map matching (ShapeDtypeStruct tree, logical-axes tree) -> spec tree."""
    # shapes' treedef drives flattening: the axes tree's tuple leaves are
    # matched via flatten_up_to, so they are NOT traversed as containers.
    return jax.tree.map(
        lambda s, a: spec_for(s.shape, a, mesh, rules), shapes, axes)


def tree_shardings(shapes, axes, mesh: Mesh, rules):
    specs = tree_specs(shapes, axes, mesh, rules)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh) -> Axes:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh) -> P:
    axes = dp_axes(mesh)
    return P(axes if len(axes) > 1 else axes[0])


def dp_size(mesh: Mesh) -> int:
    return _mesh_size(mesh, dp_axes(mesh))
