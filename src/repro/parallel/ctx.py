"""Sharding context: lets model code annotate activations with *logical*
axis names without ever referencing physical mesh axes.

``use_sharding_ctx(mesh, rules)`` installs a context; ``shard_activation``
then applies ``jax.lax.with_sharding_constraint`` with the resolved
PartitionSpec. Outside any context (CPU smoke tests, kernels), it is a
no-op — model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax

_state = threading.local()


def current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding_ctx(mesh, rules):
    prev = current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def shard_activation(x, logical_axes: Sequence[Optional[str]]):
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    from repro.parallel.sharding import spec_for
    spec = spec_for(x.shape, tuple(logical_axes), mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
