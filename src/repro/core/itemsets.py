"""Apriori itemset machinery: candidate generation, prefix clustering.

Itemsets are sorted tuples of item ids. The paper clusters k-itemset tasks
by their (k-1)-prefix via XOR of per-item hashes (Section 4); we reproduce
that hash exactly (std::hash of an integer is the identity in libstdc++ —
we use a mixing hash to avoid degenerate buckets, but keep the XOR
combiner).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

Itemset = Tuple[int, ...]


def _mix(x: int) -> int:
    """64-bit integer mixing hash (splitmix64 finalizer)."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


def itemset_hash(items: Iterable[int]) -> int:
    """XOR of per-item mixing hashes — the paper's §4 combiner. Used
    directly by the depth-first engine to key an equivalence class by
    its full prefix."""
    h = 0
    for item in items:
        h ^= _mix(item)
    return h


def prefix_hash(itemset: Itemset) -> int:
    """Paper §4: XOR of per-item hashes over the first (k-1) items —
    itemsets sharing a (k-1)-prefix land in the same bucket."""
    return itemset_hash(itemset[:-1])


def prefix_of(itemset: Itemset) -> Itemset:
    return itemset[:-1]


def gen_candidates(frequent: Sequence[Itemset],
                   known_frequent: Iterable[Itemset] = ()) -> List[Itemset]:
    """F_{k-1} -> C_k by prefix join + anti-monotone prune (Apriori).

    ``known_frequent`` widens the prune set beyond the join frontier:
    granularity="auto" detaches whole subtrees to depth-first class
    tasks, so their itemsets never re-enter ``frequent`` — without the
    full known-frequent membership, a candidate whose (k-1)-subset was
    mined inside a detached subtree would be falsely pruned."""
    fset = set(frequent)
    fset.update(known_frequent)
    if not frequent:
        return []
    k = len(frequent[0]) + 1
    # group by (k-2)-prefix; join pairs within a group
    by_prefix: Dict[Itemset, List[int]] = {}
    for it in frequent:
        by_prefix.setdefault(it[:-1], []).append(it[-1])
    out: List[Itemset] = []
    for pref, lasts in by_prefix.items():
        lasts.sort()
        for i, a in enumerate(lasts):
            for b in lasts[i + 1:]:
                cand = pref + (a, b)
                # prune: every (k-1)-subset must be frequent
                if k <= 2 or all(
                        cand[:j] + cand[j + 1:] in fset
                        for j in range(k)):
                    out.append(cand)
    return out


def brute_force_frequent(db: Sequence[Sequence[int]], min_support: int,
                         max_k: int = 6) -> Dict[Itemset, int]:
    """Oracle for tests: enumerate all itemsets by breadth-first Apriori
    over explicit set intersections (no bitmaps, no scheduler)."""
    from itertools import combinations
    tidsets: Dict[int, set] = {}
    for t, txn in enumerate(db):
        for i in set(txn):
            tidsets.setdefault(i, set()).add(t)
    result: Dict[Itemset, int] = {}
    frequent = []
    for i, tids in sorted(tidsets.items()):
        if len(tids) >= min_support:
            result[(i,)] = len(tids)
            frequent.append((i,))
    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        frequent = []
        for c in cands:
            tids = tidsets[c[0]]
            for i in c[1:]:
                tids = tids & tidsets[i]
            if len(tids) >= min_support:
                result[c] = len(tids)
                frequent.append(c)
        k += 1
    return result
