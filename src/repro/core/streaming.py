"""Streaming ingestion + incremental re-mining + pattern serving.

The batch engine (``repro.core.fpm``) answers "what is frequent in this
database" once; a deployed miner faces a database that never stops
growing and queries that cannot wait for a re-mine. This module closes
that gap with three pieces on top of the existing arena/scheduler/
dispatcher stack:

``StreamingMiner.ingest(batch)``
    packs the new transactions into a FRESH arena segment
    (``BitmapArena.add_segment``): per-item word-columns for the new
    transactions only. Existing segments are never repacked, and a
    device-backed arena uploads exactly the new segment's payload
    (``seg_nbytes``) — ingest cost is proportional to the batch, not
    the database.

``StreamingMiner.refresh()``
    folds the pending segments in incrementally. Per-item support
    deltas over ONLY the fresh segments classify the *dirty items* (an
    itemset's support can change only if every one of its items occurs
    in the new batch); the border of the previous generation then
    splits into stayed-frequent / newly-frequent / died. The engine
    cores re-mine ONLY invalidated equivalence classes (``DeltaPlan``):
    clean known candidates are looked up (zero rows), dirty ones are
    delta-swept over the pending segments, and never-seen candidates
    get full sweeps. Re-mine tasks carry a *staleness priority* (the
    stale prefix's popularity) in ``Task.priority`` — the clustered /
    nearest-neighbour drain rules serve stale-HOT buckets first, so
    the published patterns converge on popular prefixes earliest:
    the paper's task-attribute machinery doing live scheduling work.

``PatternServer``
    answers ``support`` / ``top_k`` / ``frequent`` queries from the
    last PUBLISHED generation: every refresh builds an immutable
    ``PatternSnapshot`` and swaps it in atomically (one reference
    assignment), so queries never block on mining and never observe a
    half-updated result.

Correctness anchor: after ANY ingest sequence, ``refresh()`` yields
exactly the frequent itemsets (and supports) of a from-scratch
``fpm.mine`` on the concatenated database — for every granularity,
policy, and mesh shape. ``_known`` keeps the support of every
candidate ever swept (frequent and negative border); it grows with the
pattern space, not the transaction count, and is what makes clean
subtrees skippable without a sweep.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from types import MappingProxyType
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.fpm import (DeltaPlan, MiningMetrics, MiningRun,
                            _resolve_mesh, mine_more)
from repro.core.itemsets import Itemset
from repro.core.join_backend import FLUSH_US, MAX_BATCH
from repro.core.tidlist import BitmapArena, pack_database


# ---------------------------------------------------------------------------
# snapshots + serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PatternSnapshot:
    """One published generation of mining results — immutable, so a
    reader holding it can answer any number of queries consistently
    while newer generations are mined and swapped in behind it.

    ``supports`` maps every frequent itemset (singletons included) to
    its exact support over the ``n_transactions`` the generation
    covers. The prefix index for ``top_k`` is built lazily on the
    first ranked query — publishing a generation costs one dict copy,
    not an index build inside the refresh wall (a racing build is
    benign: both threads produce the identical index and the reference
    store is atomic)."""
    generation: int
    n_transactions: int
    min_support: int
    supports: Mapping[Itemset, int]

    def __post_init__(self):
        object.__setattr__(self, "supports",
                           MappingProxyType(dict(self.supports)))
        object.__setattr__(self, "_by_prefix_cache", None)

    @property
    def _by_prefix(self) -> Mapping[Itemset, tuple]:
        idx = self._by_prefix_cache
        if idx is None:
            acc: Dict[Itemset, List[Tuple[int, Itemset]]] = {}
            for x, s in self.supports.items():
                for cut in range(len(x)):
                    acc.setdefault(x[:cut], []).append((-s, x))
            idx = MappingProxyType(
                {p: tuple((x, -ns) for ns, x in sorted(v))
                 for p, v in acc.items()})
            object.__setattr__(self, "_by_prefix_cache", idx)
        return idx

    def support(self, itemset: Sequence[int]) -> Optional[int]:
        """Exact support of a FREQUENT itemset; None if it was not
        frequent at this generation (its true support is below
        ``min_support`` — or it was never counted)."""
        return self.supports.get(tuple(sorted(itemset)))

    def top_k(self, prefix: Sequence[int] = (), k: int = 10
              ) -> List[Tuple[Itemset, int]]:
        """The k highest-support frequent itemsets strictly extending
        ``prefix`` (itemsets whose leading items equal it), best
        first. ``prefix=()`` ranks everything."""
        return list(self._by_prefix.get(tuple(sorted(prefix)), ())[:k])

    def frequent(self, min_support: Optional[int] = None
                 ) -> Dict[Itemset, int]:
        """All frequent itemsets, optionally re-thresholded UPWARD
        (supports below this generation's mining threshold were never
        published, so a lower one cannot be answered)."""
        if min_support is None or min_support <= self.min_support:
            return dict(self.supports)
        return {x: s for x, s in self.supports.items()
                if s >= min_support}


class PatternServer:
    """Query layer over a :class:`StreamingMiner`: every query reads
    the miner's current snapshot ONCE (one atomic reference load) and
    answers from it — no lock is shared with mining, so a refresh in
    flight never blocks a query and a query never sees generation
    N+1's itemsets with generation N's supports."""

    def __init__(self, miner: "StreamingMiner"):
        self._miner = miner
        self.queries = 0          # served-query gauge (approximate
                                  # under concurrency; serving metric,
                                  # not an invariant)

    @property
    def snapshot(self) -> PatternSnapshot:
        return self._miner.snapshot

    def support(self, itemset: Sequence[int]) -> Optional[int]:
        self.queries += 1
        return self.snapshot.support(itemset)

    def top_k(self, prefix: Sequence[int] = (), k: int = 10
              ) -> List[Tuple[Itemset, int]]:
        self.queries += 1
        return self.snapshot.top_k(prefix, k)

    def frequent(self, min_support: Optional[int] = None
                 ) -> Dict[Itemset, int]:
        self.queries += 1
        return self.snapshot.frequent(min_support)


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

@dataclass
class IngestReport:
    segment: int              # arena segment id the batch landed in
    n_transactions: int       # transactions in the batch
    words: int                # packed words per item row (W_seg)
    payload_bytes: int        # the segment's base-bitmap payload
    h2d_bytes: int            # device upload billed by the ingest
                              # (== payload_bytes with eager backing,
                              # 0 when mirrors sync lazily at refresh)
    wall_s: float = 0.0


@dataclass
class RefreshReport:
    generation: int           # the generation this refresh published
    n_transactions: int
    min_support: int
    frequent: int             # published frequent itemsets
    segments_refreshed: Tuple[int, ...]
    dirty_items: int          # items occurring in the fresh segments
    # border classification vs the previous generation
    stayed: int
    born: int
    died: int
    # how much re-mining the delta plan avoided
    reused: int               # candidates answered from known supports
    swept_delta: int          # candidates delta-swept (fresh segments)
    swept_full: int           # candidates fully swept (never seen)
    rows_touched: int
    bytes_swept: int
    h2d_bytes: int            # arena gauge deltas for THIS refresh
    d2d_bytes: int
    wall_s: float = 0.0
    # post-publish segment compaction (0 when the policy didn't fire)
    compacted_segments: int = 0
    compaction_bytes: int = 0
    metrics: Optional[MiningMetrics] = None


# ---------------------------------------------------------------------------
# the streaming miner
# ---------------------------------------------------------------------------

class StreamingMiner:
    """Owns one growing, segmented :class:`BitmapArena` and publishes
    mining generations over it.

    ``min_support`` is either an absolute count (held fixed as the
    database grows — supports only grow under ingest, so nothing ever
    dies) or a float fraction of the current transaction count
    (re-resolved at every refresh — it rises with the database, so
    border itemsets can die). ``mesh`` accepts the same values as
    ``fpm.mine``: None, an int (logical shards), or a jax Mesh.

    Locking: refreshes serialize on ``_refresh_lock``; quick state
    mutations (segment appends, counter/snapshot commits, compaction)
    serialize on ``_state``. An ``ingest`` therefore NEVER blocks
    behind an in-flight ``refresh`` — the refresh captures its
    generation boundary (segment count) up front, sweeps only
    boundary segments, and the mid-refresh batch simply lands in the
    next generation. Queries via :attr:`snapshot` /
    :class:`PatternServer` are lock-free. Until the first ``refresh``
    the published snapshot is the empty generation 0.

    Segment compaction (LSM-style): every publish may fold the
    refreshed (cold) segments back into one wide store —
    ``compact_segments`` is the cadence bound (more cold segments than
    this always compacts) and ``compact_ratio`` the size-ratio bound
    (a cold tail at most this fraction of the lead segment's width is
    cheap to fold, so it folds immediately). The repack bytes are
    billed in the arena's ``compaction_bytes`` gauge and reported per
    refresh. Set ``compact_ratio=0.0`` and a huge ``compact_segments``
    to disable."""

    def __init__(self, n_items: int, min_support, *,
                 initial_db: Sequence[Sequence[int]] = (),
                 policy: str = "clustered", n_workers: int = 4,
                 max_k: int = 6, granularity: str = "bucket",
                 backend: str = "auto", arena: str = "auto",
                 cache_size: int = 32, max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, mesh=None,
                 representation: str = "auto",
                 compact_segments: int = 8,
                 compact_ratio: float = 0.5):
        if n_items < 1:
            raise ValueError(f"n_items must be >= 1, got {n_items}")
        self.n_items = n_items
        self.max_k = max_k
        self._ms_spec = min_support
        self._run_kw = dict(policy=policy, n_workers=n_workers,
                            granularity=granularity, backend=backend,
                            cache_size=cache_size, max_batch=max_batch,
                            flush_us=flush_us,
                            representation=representation)
        n_shards, devices = _resolve_mesh(mesh)
        initial_db = [list(t) for t in initial_db]
        self._check_items(initial_db)
        # one packing pass yields the bitmaps AND the per-item ones
        # counts — the level-1 supports and the density-model seed,
        # with no post-hoc popcount sweep
        bitmaps, item_counts = pack_database(initial_db, n_items,
                                             return_counts=True)
        self.arena = BitmapArena.from_bitmaps(
            bitmaps, backing=arena, n_shards=n_shards, devices=devices)
        self.n_transactions = len(initial_db)
        self._seg_tx = [len(initial_db)]   # transactions per segment
        self._item_support = item_counts
        # support of every candidate ever swept (|X| >= 2; frequent AND
        # negative border), exact over the refreshed segments — the
        # reuse store that lets clean classes skip their sweeps
        self._known: Dict[Itemset, int] = {}
        self._refreshed_segments = self.arena.n_segments
        self.generation = 0
        self.compact_segments = compact_segments
        self.compact_ratio = compact_ratio
        self._state = threading.RLock()     # quick mutations + commits
        self._refresh_lock = threading.Lock()   # one refresh at a time
        self._snapshot = PatternSnapshot(
            0, self.n_transactions, self._resolve_ms(), {})

    # ------------------------------------------------------------ queries --
    @property
    def snapshot(self) -> PatternSnapshot:
        """The last published generation (atomic reference read)."""
        return self._snapshot

    @property
    def needs_refresh(self) -> bool:
        # snapshot BOTH counters under the state lock: free-running
        # reads racing a completing refresh (or a compaction) could
        # pair a fresh segment count with a stale refreshed count and
        # report negative/phantom pending segments
        with self._state:
            return self.arena.n_segments > self._refreshed_segments

    def _resolve_ms(self, n_transactions: Optional[int] = None) -> int:
        if n_transactions is None:
            n_transactions = self.n_transactions
        if isinstance(self._ms_spec, float):
            return max(1, int(self._ms_spec * n_transactions))
        return int(self._ms_spec)

    def _check_items(self, db) -> None:
        for txn in db:
            for i in txn:
                if not 0 <= i < self.n_items:
                    raise ValueError(
                        f"item id {i} outside [0, {self.n_items})")

    # ------------------------------------------------------------- ingest --
    def ingest(self, batch: Sequence[Sequence[int]]) -> IngestReport:
        """Append a batch of transactions as one fresh arena segment.
        O(batch) work and — with eager ("jax") arena backing — exactly
        the new segment's payload in device upload; the mined results
        are stale until the next :meth:`refresh` (queries keep serving
        the published generation). Never blocks behind an in-flight
        refresh: only the brief state lock is taken, and the new
        segment lands in the NEXT generation (the running refresh
        sweeps only its captured boundary segments)."""
        batch = [list(t) for t in batch]
        self._check_items(batch)
        t0 = time.time()
        seg_bm = pack_database(batch, self.n_items)   # outside any lock
        with self._state:
            h0 = self.arena.h2d_bytes
            seg = self.arena.add_segment(seg_bm)
            self._seg_tx.append(len(batch))
            self.n_transactions += len(batch)
            return IngestReport(
                segment=seg, n_transactions=len(batch),
                words=seg_bm.shape[1],
                payload_bytes=self.arena.seg_nbytes(seg),
                h2d_bytes=self.arena.h2d_bytes - h0,
                wall_s=time.time() - t0)

    # ------------------------------------------------------------ refresh --
    def refresh(self, before_publish=None) -> RefreshReport:
        """Fold every pending segment into a new published generation,
        re-mining only invalidated equivalence classes. Returns the
        refresh report; the new :class:`PatternSnapshot` is swapped in
        atomically at the end (``before_publish(snapshot)``, if given,
        runs just before the swap — tests use it to observe the
        serving layer mid-refresh).

        The generation boundary (segment count + transaction count) is
        captured up front under the state lock; every sweep names its
        segments explicitly, so batches an overlapped :meth:`ingest`
        appends mid-refresh are invisible to this generation and fold
        in on the next one."""
        with self._refresh_lock:
            t0 = time.time()
            arena = self.arena
            with self._state:
                boundary = arena.n_segments
                pending = tuple(range(self._refreshed_segments,
                                      boundary))
                boundary_tx = sum(self._seg_tx[:boundary])
            base_segments = tuple(range(boundary))
            deltas = np.zeros(self.n_items, np.int64)
            for g in pending:
                seg = arena.seg_view(g)[:self.n_items]
                deltas += tidlist.popcount32(seg).sum(axis=1)
            dirty = frozenset(int(i) for i in np.nonzero(deltas)[0])
            # all-or-nothing: mine against WORKING copies and commit
            # only at publish, so a failed refresh (task error mid-
            # mine) leaves the miner's state untouched and a retry
            # cannot double-add the pending segments' deltas. The
            # shallow _known copy is cheap next to the mining it
            # fronts.
            item_support = self._item_support + deltas
            known = dict(self._known)
            ms = self._resolve_ms(boundary_tx)
            prev = self._snapshot.supports

            def hotness(prefix: Itemset) -> float:
                """Staleness priority of a re-mine task: the stale
                prefix's popularity (its last known support), so drain
                selection serves hot prefixes first and the snapshot
                converges where queries concentrate."""
                if len(prefix) == 1:
                    return float(item_support[prefix[0]])
                return float(known.get(prefix, 0))

            plan = DeltaPlan(
                known=known,
                dirty_items=dirty,
                segments=pending,
                base_segments=base_segments,
                # an empty known store means everything is fresh — no
                # staleness to rank, and stamping priorities would only
                # buy the priority-drain scan on every task switch
                priority_of=hotness if known else None)
            singles: Dict[Itemset, int] = {
                (i,): int(s) for i, s in enumerate(item_support)
                if s >= ms}
            result = dict(singles)
            frequent = sorted(result)
            h2d0, d2d0 = arena.h2d_bytes, arena.d2d_bytes
            run = MiningRun(arena, item_counts=item_support,
                            **self._run_kw)
            run.metrics.frequent += len(frequent)
            try:
                mine_more(run, ms, self.max_k, result, frequent,
                          delta=plan)
            finally:
                run.close()
            metrics = run.finalize(t0)
            metrics.h2d_bytes = arena.h2d_bytes - h2d0
            metrics.d2d_bytes = arena.d2d_bytes - d2d0

            # exact assembly from the reuse store: skipped (clean)
            # subtrees never touched `result`, but their supports are
            # in the known store — and downward closure makes the
            # filter exact
            final = dict(singles)
            for x, s in known.items():
                if len(x) <= self.max_k and s >= ms:
                    final[x] = s

            # single-pass border classification: one membership probe
            # per published itemset (the old two-set construction was
            # a measurable slice of small-delta refresh wall time)
            stayed = born = 0
            for x in final:
                if x in prev:
                    stayed += 1
                else:
                    born += 1
            died = len(prev) - stayed
            snapshot = PatternSnapshot(self.generation + 1,
                                       boundary_tx, ms, final)
            report = RefreshReport(
                generation=snapshot.generation,
                n_transactions=boundary_tx,
                min_support=ms,
                frequent=len(final),
                segments_refreshed=pending,
                dirty_items=len(dirty),
                stayed=stayed,
                born=born,
                died=died,
                reused=plan.reused,
                swept_delta=plan.swept_delta,
                swept_full=plan.swept_full,
                rows_touched=metrics.rows_touched,
                bytes_swept=metrics.bytes_swept,
                h2d_bytes=metrics.h2d_bytes,
                d2d_bytes=metrics.d2d_bytes,
                wall_s=time.time() - t0,
                metrics=metrics)
            # the hook observes the world just before the swap and may
            # itself ingest — so it runs OUTSIDE the state lock
            if before_publish is not None:
                before_publish(snapshot)
            with self._state:
                # commit point: plain assignments, then the swap
                self._item_support = item_support
                self._known = known
                self._refreshed_segments = boundary
                self._snapshot = snapshot       # the atomic swap
                self.generation = snapshot.generation
                c0 = arena.compaction_bytes
                report.compacted_segments = self._maybe_compact()
                report.compaction_bytes = arena.compaction_bytes - c0
            report.wall_s = time.time() - t0
            return report

    # --------------------------------------------------------- compaction --
    def _maybe_compact(self) -> int:
        """Fold the refreshed segments into one when the policy fires
        (caller holds the state lock, no refresh mining in flight —
        segment ids are not referenced by any live sweep). Returns the
        number of segments removed."""
        r = self._refreshed_segments
        if r < 2:
            return 0
        lead = self.arena.seg_words(0)
        tail = sum(self.arena.seg_words(g) for g in range(1, r))
        if not (r > self.compact_segments
                or tail <= self.compact_ratio * max(lead, 1)):
            return 0
        return self._compact(r)

    def _compact(self, upto: int) -> int:
        removed = self.arena.compact(upto)
        if removed:
            self._seg_tx[:removed + 1] = [sum(self._seg_tx[:removed + 1])]
            self._refreshed_segments -= removed
        return removed

    def compact_now(self) -> int:
        """Force-fold every refreshed segment regardless of policy
        (maintenance hook; also what the cadence-equivalence tests
        drive). Returns the number of segments removed."""
        with self._refresh_lock, self._state:
            return self._compact(self._refreshed_segments)

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        with self._state:
            n_seg = self.arena.n_segments
            pending = n_seg - self._refreshed_segments
            return (f"<StreamingMiner gen={self.generation} "
                    f"tx={self.n_transactions} "
                    f"segments={n_seg} "
                    f"pending={pending} "
                    f"known={len(self._known)}>")
