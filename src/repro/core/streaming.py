"""Streaming ingestion + incremental re-mining + pattern serving.

The batch engine (``repro.core.fpm``) answers "what is frequent in this
database" once; a deployed miner faces a database that never stops
growing and queries that cannot wait for a re-mine. This module closes
that gap with four pieces on top of the existing arena/scheduler/
dispatcher stack:

``StreamingMiner.ingest(batch)``
    packs the new transactions into a FRESH arena segment
    (``BitmapArena.add_segment``): per-item word-columns for the new
    transactions only. Existing segments are never repacked, and a
    device-backed arena uploads exactly the new segment's payload
    (``seg_nbytes``) — ingest cost is proportional to the batch, not
    the database.

``StreamingMiner.refresh()``
    folds the pending segments in incrementally. Per-item support
    deltas over ONLY the fresh segments classify the *dirty items* (an
    itemset's support can change only if every one of its items occurs
    in the new batch); the border of the previous generation then
    splits into stayed-frequent / newly-frequent / died. The engine
    cores re-mine ONLY invalidated equivalence classes (``DeltaPlan``):
    clean known candidates are looked up (zero rows), dirty ones are
    delta-swept over the pending segments, and never-seen candidates
    get full sweeps. Re-mine tasks carry a *staleness priority* (the
    stale prefix's popularity) in ``Task.priority`` — the clustered /
    nearest-neighbour drain rules serve stale-HOT buckets first, so
    the published patterns converge on popular prefixes earliest:
    the paper's task-attribute machinery doing live scheduling work.

``PatternServer`` / ``QueryPlanner``
    answer ``support`` / ``top_k`` / ``frequent`` queries. Dict hits
    read the last PUBLISHED generation: every refresh builds an
    immutable ``PatternSnapshot`` (frequent supports AND the negative
    border) and swaps it in atomically, so those queries never block
    on mining and never observe a half-updated result. An itemset the
    generation never counted is no longer a ``None`` — the planner
    decomposes it into a prefix-intersection + extension-count sweep
    and enqueues it as a PRIORITY request on the same live per-shard
    dispatchers the refresh path uses, so query sweeps coalesce into
    the very flushes that carry candidate sweeps. Answered supports
    backfill the known store: a repeat of the same query is a dict
    hit. ``top_k`` ranks on a device-resident index (a jitted masked
    top-k over flat itemset encodings) once the snapshot is large
    enough to pay for it.

``TenantHub``
    multiplexes several independent streams onto ONE arena and ONE
    persistent :class:`~repro.core.fpm.EngineRuntime`. Each tenant
    owns a disjoint, tagged segment set, its own threshold/known
    store/snapshot; re-mine tasks carry the tenant tag and the drain
    rules serve the highest weight/(served+1) deficit first, so a
    heavy tenant cannot starve a light one.

Correctness anchor: after ANY ingest sequence, ``refresh()`` yields
exactly the frequent itemsets (and supports) of a from-scratch
``fpm.mine`` on the concatenated database — for every granularity,
policy, and mesh shape; and ``support_many`` answers equal brute-force
counts over the refreshed prefix of the database. ``_known`` keeps the
support of every candidate ever swept (frequent and negative border);
it grows with the pattern space, not the transaction count, and is
what makes clean subtrees skippable without a sweep.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from types import MappingProxyType
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from repro.core import tidlist
from repro.core.fpm import (DeltaPlan, EngineRuntime, MiningMetrics,
                            MiningRun, _resolve_mesh, mine_more)
from repro.core.itemsets import Itemset
from repro.core.join_backend import FLUSH_US, MAX_BATCH
from repro.core.scheduler import ClusteredPolicy
from repro.core.tidlist import BitmapArena, pack_database
from repro.obs import LatencyRecorder, MetricsRegistry
from repro.obs import schema as obs_schema


# ---------------------------------------------------------------------------
# device-resident top-k
# ---------------------------------------------------------------------------

# snapshots below this many itemsets rank faster with one numpy argsort
# than with a device round-trip; tests monkeypatch it to 0 to force the
# device path on tiny inputs
TOPK_DEVICE_MIN = 4096

_topk_fn = None


def _device_topk_fn():
    """The jitted masked top-k, built once: rows whose length exceeds
    the prefix length and whose leading positions equal the prefix
    keep their support, everything else scores -1, and
    ``lax.top_k`` ranks. Its smallest-index tie rule over
    lexicographically sorted rows reproduces the host ordering."""
    global _topk_fn
    if _topk_fn is None:
        import jax
        import jax.numpy as jnp

        def kernel(enc, sup, lens, pref, plen, k):
            pos = jnp.arange(enc.shape[1])[None, :]
            match = jnp.all((pos >= plen) | (enc == pref[None, :]),
                            axis=1)
            match = match & (lens > plen)
            return jax.lax.top_k(jnp.where(match, sup, -1), k)

        _topk_fn = jax.jit(kernel, static_argnums=(5,))
    return _topk_fn


class _SnapshotIndex:
    """Flat itemset encodings for vectorized ``top_k``: rows sorted
    lexicographically, items right-padded with -1. Stable descending-
    support orderings over this layout (numpy stable argsort, or
    ``lax.top_k``'s smallest-index tie rule) reproduce the serving
    tie-break — equal supports rank lexicographically — so the device
    and host paths are bit-identical."""

    def __init__(self, supports: Mapping[Itemset, int]):
        items = sorted(supports)
        n = len(items)
        kmax = max((len(x) for x in items), default=1)
        enc = np.full((n, kmax), -1, np.int32)
        lens = np.zeros(n, np.int32)
        sup = np.zeros(n, np.int64)
        for r, x in enumerate(items):
            enc[r, :len(x)] = x
            lens[r] = len(x)
            sup[r] = supports[x]
        self.items = items
        self.enc, self.lens, self.sup = enc, lens, sup
        self._dev = None      # padded device copies, uploaded once

    def top_k(self, prefix: Itemset, k: int
              ) -> List[Tuple[Itemset, int]]:
        plen = len(prefix)
        n = len(self.items)
        if n == 0 or k <= 0 or plen >= self.enc.shape[1]:
            return []
        order = vals = None
        if n >= TOPK_DEVICE_MIN:
            try:
                order, vals = self._device_top_k(prefix, k)
            except Exception:            # no jax → host path
                order = vals = None
        if order is None:
            mask = self.lens > plen
            if plen:
                mask &= (self.enc[:, :plen]
                         == np.asarray(prefix, np.int32)).all(axis=1)
            scored = np.where(mask, self.sup, -1)
            order = np.argsort(-scored, kind="stable")[:k]
            vals = scored[order]
        return [(self.items[int(r)], int(v))
                for r, v in zip(order, vals) if v >= 0]

    def _device_top_k(self, prefix: Itemset, k: int):
        import jax.numpy as jnp
        if self._dev is None:
            n, kmax = self.enc.shape
            npad = 1 << max(n - 1, 1).bit_length()
            enc = np.full((npad, kmax), -1, np.int32)
            enc[:n] = self.enc
            lens = np.zeros(npad, np.int32)   # len 0 never matches
            lens[:n] = self.lens
            sup = np.zeros(npad, np.int32)
            sup[:n] = self.sup
            self._dev = (jnp.asarray(enc), jnp.asarray(sup),
                         jnp.asarray(lens))
        enc_d, sup_d, lens_d = self._dev
        pref = np.full(enc_d.shape[1], -1, np.int32)
        pref[:len(prefix)] = prefix
        # k rounds up to a power of two so the jit cache holds a few
        # entries, not one per distinct k
        kk = min(1 << max(k - 1, 1).bit_length(), int(enc_d.shape[0]))
        vals, idx = _device_topk_fn()(
            enc_d, sup_d, lens_d, jnp.asarray(pref),
            np.int32(len(prefix)), kk)
        return np.asarray(idx)[:k], np.asarray(vals)[:k]


# ---------------------------------------------------------------------------
# snapshots + serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PatternSnapshot:
    """One published generation of mining results — immutable, so a
    reader holding it can answer any number of queries consistently
    while newer generations are mined and swapped in behind it.

    ``supports`` maps every frequent itemset (singletons included) to
    its exact support over the ``n_transactions`` the generation
    covers; ``border`` maps the NEGATIVE border — candidates the
    engines counted whose support landed below ``min_support`` — to
    those exact sub-threshold supports (:meth:`lookup` flags them
    infrequent). The ranking index for ``top_k`` is built lazily on
    the first ranked query — publishing a generation costs one dict
    copy, not an index build inside the refresh wall (a racing build
    is benign: both threads produce the identical index and the
    reference store is atomic)."""
    generation: int
    n_transactions: int
    min_support: int
    supports: Mapping[Itemset, int]
    border: Mapping[Itemset, int] = field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "supports",
                           MappingProxyType(dict(self.supports)))
        object.__setattr__(self, "border",
                           MappingProxyType(dict(self.border)))
        object.__setattr__(self, "_index_cache", None)

    @property
    def _index(self) -> _SnapshotIndex:
        idx = self._index_cache
        if idx is None:
            idx = _SnapshotIndex(self.supports)
            object.__setattr__(self, "_index_cache", idx)
        return idx

    def support(self, itemset: Sequence[int],
                include_infrequent: bool = False) -> Optional[int]:
        """Exact support of a FREQUENT itemset; None if it was not
        frequent at this generation. With ``include_infrequent`` the
        negative border answers too (exact sub-threshold supports);
        None then means the itemset was never counted."""
        x = tuple(sorted(itemset))
        s = self.supports.get(x)
        if s is None and include_infrequent:
            s = self.border.get(x)
        return s

    def lookup(self, itemset: Sequence[int]
               ) -> Optional[Tuple[int, bool]]:
        """``(support, infrequent)`` for anything this generation
        counted — frequent or negative border — else None."""
        x = tuple(sorted(itemset))
        s = self.supports.get(x)
        if s is not None:
            return s, False
        s = self.border.get(x)
        if s is not None:
            return s, True
        return None

    def top_k(self, prefix: Sequence[int] = (), k: int = 10
              ) -> List[Tuple[Itemset, int]]:
        """The k highest-support frequent itemsets strictly extending
        ``prefix`` (itemsets whose leading items equal it), best
        first; ties rank lexicographically. ``prefix=()`` ranks
        everything. Large snapshots rank device-resident (see
        ``TOPK_DEVICE_MIN``)."""
        return self._index.top_k(tuple(sorted(prefix)), k)

    def frequent(self, min_support: Optional[int] = None
                 ) -> Dict[Itemset, int]:
        """All frequent itemsets, optionally re-thresholded UPWARD
        (supports below this generation's mining threshold were never
        published, so a lower one cannot be answered)."""
        if min_support is None or min_support <= self.min_support:
            return dict(self.supports)
        return {x: s for x, s in self.supports.items()
                if s >= min_support}


class QueryPlanner:
    """Decomposes a batch of support queries against ONE captured
    generation — snapshot, known store, singleton supports, and the
    segment set they cover, all read under the owner's state lock, so
    every answer in the batch is consistent with that generation.

    The empty itemset is the transaction count, singletons read the
    item-support vector, and any |X| >= 2 itemset already counted
    (published, negative border, or an earlier query's backfill)
    answers from the known store. The rest become prefix-intersection
    + extension-count sweeps ``(x[:-1], (x[-1],))`` — the dispatcher
    AND-reduces the k-1 prefix rows per segment and popcounts the
    intersection with the last item's row: exactly a candidate
    sweep's shape, so query and candidate requests coalesce into the
    same flushes."""

    def __init__(self, snapshot: PatternSnapshot,
                 known: Dict[Itemset, int],
                 item_support: np.ndarray,
                 segments: Sequence[int]):
        self.snapshot = snapshot
        self.known = known
        self.item_support = item_support
        self.segments = tuple(segments)

    def plan(self, itemsets: Sequence[Itemset]):
        """``(answers, sweeps, slots)``: ``answers[i]`` is a
        ``(support, swept)`` pair for dict-answerable queries and a
        None placeholder otherwise; ``sweeps[j]`` is the
        ``(prefix, exts)`` request spec answering
        ``itemsets[slots[j]]``."""
        answers: List[Optional[Tuple[int, bool]]] = [None] * len(itemsets)
        sweeps: List[Tuple[Any, Tuple[int, ...]]] = []
        slots: List[int] = []
        for j, x in enumerate(itemsets):
            if not x:
                answers[j] = (int(self.snapshot.n_transactions), False)
            elif len(x) == 1:
                answers[j] = (int(self.item_support[x[0]]), False)
            else:
                s = self.known.get(x)
                if s is not None:
                    answers[j] = (int(s), False)
                else:
                    sweeps.append((x[0] if len(x) == 2 else x[:-1],
                                   (x[-1],)))
                    slots.append(j)
        return answers, sweeps, slots


class _QueryGate:
    """Counts in-flight query sweeps against one state lock so
    compaction — which renumbers the segment ids those sweeps hold —
    can wait for them to land. ``begin`` requires the lock held;
    ``end`` takes it itself; ``wait_idle`` (lock held) releases it
    while waiting."""

    def __init__(self, lock):
        self.cv = threading.Condition(lock)
        self.inflight = 0

    def begin(self) -> None:
        self.inflight += 1

    def end(self) -> None:
        with self.cv:
            self.inflight -= 1
            if not self.inflight:
                self.cv.notify_all()

    def wait_idle(self, timeout: float) -> bool:
        deadline = time.monotonic() + timeout
        while self.inflight:
            left = deadline - time.monotonic()
            if left <= 0:
                return False
            self.cv.wait(left)
        return True


def _serve_queries(owner, itemsets: Sequence[Sequence[int]]
                   ) -> List[Tuple[int, bool]]:
    """The shared serving path (StreamingMiner and Tenant): plan under
    the state lock, sweep the misses as one priority burst on a
    round-robin dispatcher, backfill the known store, and return
    ``(support, swept)`` per itemset."""
    t_q = time.perf_counter()
    xs: List[Itemset] = []
    for raw in itemsets:
        x = tuple(sorted({int(i) for i in raw}))
        for i in x:
            if not 0 <= i < owner.n_items:
                raise ValueError(
                    f"item id {i} outside [0, {owner.n_items})")
        xs.append(x)
    with owner._state:
        planner = owner._query_view()
        answers, sweeps, slots = planner.plan(xs)
        if slots:
            runtime = owner._ensure_runtime()
            known_ref = planner.known
            owner._gate.begin()
    if not slots:
        # pure snapshot hits: per-query share of the batched call
        owner.latency.record(
            "hit", (time.perf_counter() - t_q) / max(len(xs), 1),
            n=len(xs))
        return answers
    try:
        disp = runtime.dispatchers[
            next(owner._q_rr) % len(runtime.dispatchers)]
        futs = disp.submit_many(sweeps, segments=planner.segments,
                                priority=True)
        counts = [int(f.result()[0]) for f in futs]
    finally:
        owner._gate.end()
    seg_words = sum(owner.arena.seg_words(g) for g in planner.segments)
    nbytes = sum((len(p) if isinstance(p, tuple) else 1) + 1
                 for p, _ in sweeps) * seg_words * 4
    updates: Dict[Itemset, int] = {}
    for j, c in zip(slots, counts):
        answers[j] = (c, True)
        updates[xs[j]] = c
    owner._commit_answers(known_ref, updates)
    owner._bill_query(len(slots), nbytes)
    owner.latency.record(
        "sweep", (time.perf_counter() - t_q) / max(len(xs), 1),
        n=len(xs))
    return answers


def _count_value(counter) -> int:
    """Current value of an ``itertools.count`` used as a counter:
    ``next()`` is one C call, so concurrent servers never lose
    increments the way ``self.n += 1`` (a read-modify-write of three
    bytecodes) does."""
    return counter.__reduce__()[1][0]


class PatternServer:
    """Query layer over anything that publishes a ``snapshot`` and
    answers ``query_supports`` — a :class:`StreamingMiner` or a
    :class:`Tenant`.

    ``support`` is TOTAL and exact: itemsets the published generation
    counted (frequent or negative border) are dict hits on the
    snapshot's backing store; anything never counted is answered by a
    priority sweep through the live dispatchers and backfilled, so a
    repeat of the same query is a dict hit. ``support_many`` amortizes
    planning and coalesces every miss into one flush-bound burst.
    Per-kind served counters (``hit`` / ``sweep`` / ``top_k``) are
    lock-free ``itertools.count`` instances merged on read."""

    def __init__(self, miner):
        self._miner = miner
        self._n_hit = itertools.count()
        self._n_sweep = itertools.count()
        self._n_top_k = itertools.count()

    @property
    def snapshot(self) -> PatternSnapshot:
        return self._miner.snapshot

    def support(self, itemset: Sequence[int]) -> int:
        """Exact support of ANY itemset over the refreshed database
        (no longer Optional: unknown itemsets sweep)."""
        return self.support_many([itemset])[0]

    def support_many(self, itemsets: Sequence[Sequence[int]]
                     ) -> List[int]:
        answers = self._miner.query_supports(itemsets)
        for _, swept in answers:
            next(self._n_sweep if swept else self._n_hit)
        return [s for s, _ in answers]

    def top_k(self, prefix: Sequence[int] = (), k: int = 10
              ) -> List[Tuple[Itemset, int]]:
        next(self._n_top_k)
        t0 = time.perf_counter()
        out = self.snapshot.top_k(prefix, k)
        rec = getattr(self._miner, "latency", None)
        if rec is not None:
            rec.record("top_k", time.perf_counter() - t0)
        return out

    def frequent(self, min_support: Optional[int] = None
                 ) -> Dict[Itemset, int]:
        next(self._n_hit)
        return self.snapshot.frequent(min_support)

    @property
    def queries(self) -> int:
        """Total served queries (sum of the per-kind counters)."""
        return (_count_value(self._n_hit)
                + _count_value(self._n_sweep)
                + _count_value(self._n_top_k))

    def merged_stats(self) -> Dict[str, int]:
        """Per-kind query counters on the ``repro.obs.schema`` query
        schema (all ints; ``queries`` is the derived sum)."""
        return obs_schema.query_stats(
            {"hit": _count_value(self._n_hit),
             "sweep": _count_value(self._n_sweep),
             "top_k": _count_value(self._n_top_k)})

    def latency_percentiles(self) -> Dict[str, Dict[str, float]]:
        """Exact per-kind p50/p95/p99 from the miner's
        :class:`repro.obs.LatencyRecorder` (empty if absent)."""
        rec = getattr(self._miner, "latency", None)
        return rec.percentiles() if rec is not None else {}


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

@dataclass
class IngestReport:
    segment: int              # arena segment id the batch landed in
    n_transactions: int       # transactions in the batch
    words: int                # packed words per item row (W_seg)
    payload_bytes: int        # the segment's base-bitmap payload
    h2d_bytes: int            # device upload billed by the ingest
                              # (== payload_bytes with eager backing,
                              # 0 when mirrors sync lazily at refresh)
    wall_s: float = 0.0


@dataclass
class RefreshReport:
    generation: int           # the generation this refresh published
    n_transactions: int
    min_support: int
    frequent: int             # published frequent itemsets
    segments_refreshed: Tuple[int, ...]
    dirty_items: int          # items occurring in the fresh segments
    # border classification vs the previous generation
    stayed: int
    born: int
    died: int
    # how much re-mining the delta plan avoided
    reused: int               # candidates answered from known supports
    swept_delta: int          # candidates delta-swept (fresh segments)
    swept_full: int           # candidates fully swept (never seen)
    rows_touched: int
    bytes_swept: int
    h2d_bytes: int            # arena gauge deltas for THIS refresh
    d2d_bytes: int
    wall_s: float = 0.0
    # post-publish segment compaction (0 when the policy didn't fire)
    compacted_segments: int = 0
    compaction_bytes: int = 0
    metrics: Optional[MiningMetrics] = None


def _check_items(db, n_items: int) -> None:
    for txn in db:
        for i in txn:
            if not 0 <= i < n_items:
                raise ValueError(
                    f"item id {i} outside [0, {n_items})")


# ---------------------------------------------------------------------------
# the streaming miner
# ---------------------------------------------------------------------------

class StreamingMiner:
    """Owns one growing, segmented :class:`BitmapArena` and publishes
    mining generations over it.

    ``min_support`` is either an absolute count (held fixed as the
    database grows — supports only grow under ingest, so nothing ever
    dies) or a float fraction of the current transaction count
    (re-resolved at every refresh — it rises with the database, so
    border itemsets can die). ``mesh`` accepts the same values as
    ``fpm.mine``: None, an int (logical shards), or a jax Mesh.

    Engine substrate: ONE persistent :class:`EngineRuntime`
    (scheduler workers + per-shard sweep dispatchers), created lazily
    on the first refresh or query sweep and lent to every refresh's
    :class:`MiningRun` — so query sweeps submitted between (and
    during) refreshes coalesce into the same dispatcher flushes as
    candidate sweeps. Idle cost is zero (untimed parking); ``close``
    (or garbage collection) tears it down.

    Locking: refreshes serialize on ``_refresh_lock``; quick state
    mutations (segment appends, counter/snapshot commits, compaction)
    serialize on ``_state``. An ``ingest`` therefore NEVER blocks
    behind an in-flight ``refresh`` — the refresh captures its
    generation boundary (segment count) up front, sweeps only
    boundary segments, and the mid-refresh batch simply lands in the
    next generation. Snapshot queries are lock-free; query SWEEPS
    register with a gate so compaction (which renumbers segments)
    waits for them. Until the first ``refresh`` the published
    snapshot is the empty generation 0.

    Segment compaction (LSM-style): every publish may fold the
    refreshed (cold) segments back into one wide store —
    ``compact_segments`` is the cadence bound (more cold segments than
    this always compacts) and ``compact_ratio`` the size-ratio bound
    (a cold tail at most this fraction of the lead segment's width is
    cheap to fold, so it folds immediately). The repack bytes are
    billed in the arena's ``compaction_bytes`` gauge and reported per
    refresh. Set ``compact_ratio=0.0`` and a huge ``compact_segments``
    to disable.

    Multi-host (``hosts > 1``, loopback): the initial database is
    word-partitioned into one local arena per logical host; each
    ``ingest`` routes its whole segment to the least-loaded owner host
    and appends ZERO-WIDTH twins on the peers, so segment ids stay
    globally aligned and refresh deltas are host-local by construction.
    A refresh drives one engine per host over ONE shared
    :class:`DeltaPlan` (two-phase per-flush reduction keeps supports
    global; idle hosts steal whole buckets from busy peers, billed to
    ``steal_net``); queries serve through host 0's runtime, whose
    dispatcher reduction covers the peers. Compaction is disabled —
    it would have to renumber every host's segment table in lockstep.
    Mutually exclusive with ``mesh``."""

    def __init__(self, n_items: int, min_support, *,
                 initial_db: Sequence[Sequence[int]] = (),
                 policy: str = "clustered", n_workers: int = 4,
                 max_k: int = 6, granularity: str = "bucket",
                 backend: str = "auto", arena: str = "auto",
                 cache_size: int = 32, max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, mesh=None,
                 representation: str = "auto",
                 compact_segments: int = 8,
                 compact_ratio: float = 0.5,
                 hosts: int = 1, tracer=None):
        if n_items < 1:
            raise ValueError(f"n_items must be >= 1, got {n_items}")
        if hosts > 1:
            if mesh is not None:
                raise ValueError("hosts= and mesh= are mutually "
                                 "exclusive")
            if representation not in ("auto", "bitmap"):
                raise ValueError(
                    "hosts > 1 requires representation='bitmap' "
                    "(sparse payloads are positional in one host's "
                    "slice)")
            representation = "bitmap"
        self.n_items = n_items
        self.max_k = max_k
        self._ms_spec = min_support
        self._hosts = max(1, int(hosts))
        # observability: optional tracer threaded into the runtime(s);
        # the latency recorder is always on (its cost is one lock +
        # append per query batch — noise next to a snapshot hit)
        self.tracer = tracer
        self.latency = LatencyRecorder()
        # perf_counter of each pending (un-refreshed) segment's
        # ingest, FIFO — refresh_lag reads the head
        self._pending_since: List[float] = []
        self._run_kw = dict(policy=policy, n_workers=n_workers,
                            granularity=granularity, backend=backend,
                            cache_size=cache_size, max_batch=max_batch,
                            flush_us=flush_us,
                            representation=representation)
        n_shards, devices = _resolve_mesh(mesh)
        initial_db = [list(t) for t in initial_db]
        _check_items(initial_db, n_items)
        # one packing pass yields the bitmaps AND the per-item ones
        # counts — the level-1 supports and the density-model seed,
        # with no post-hoc popcount sweep
        bitmaps, item_counts = pack_database(initial_db, n_items,
                                             return_counts=True)
        if self._hosts > 1:
            from repro.core import cluster as _cluster
            ranges = tidlist.partition_words(bitmaps.shape[1],
                                             self._hosts)
            self._harenas = [BitmapArena.from_bitmaps(
                np.ascontiguousarray(bitmaps[:, a:b]), backing=arena)
                for a, b in ranges]
            self.arena = self._harenas[0]
            self._bus = _cluster._LoopbackBus(self._hosts,
                                              self._harenas)
            self._hctxs = [_cluster.LoopbackContext(self._bus, h)
                           for h in range(self._hosts)]
        else:
            self._harenas = None
            self.arena = BitmapArena.from_bitmaps(
                bitmaps, backing=arena, n_shards=n_shards,
                devices=devices)
        self.n_transactions = len(initial_db)
        self._seg_tx = [len(initial_db)]   # transactions per segment
        self._item_support = item_counts
        # support of every candidate ever swept (|X| >= 2; frequent AND
        # negative border), exact over the refreshed segments — the
        # reuse store that lets clean classes skip their sweeps
        self._known: Dict[Itemset, int] = {}
        # known entries written by query backfills (not by mining):
        # the delta plan only revisits the candidate frontier, so at
        # refresh the dirty ones among these are dropped rather than
        # left to go stale
        self._query_known: Set[Itemset] = set()
        self._refreshed_segments = self.arena.n_segments
        self.generation = 0
        self.compact_segments = compact_segments
        self.compact_ratio = compact_ratio
        self._state = threading.RLock()     # quick mutations + commits
        self._refresh_lock = threading.Lock()   # one refresh at a time
        self._gate = _QueryGate(self._state)
        self._q_rr = itertools.count()      # dispatcher round-robin
        self._runtime: Optional[EngineRuntime] = None
        self._hruntimes: Optional[List[EngineRuntime]] = None
        self.query_sweeps = 0
        self.query_sweep_bytes = 0
        self._snapshot = PatternSnapshot(
            0, self.n_transactions, self._resolve_ms(), {})

    # ------------------------------------------------------------ runtime --
    def _ensure_runtime(self) -> EngineRuntime:
        """The persistent engine substrate, created on first use so
        snapshot-only readers never pay for worker threads."""
        with self._state:
            if self._runtime is None:
                kw = self._run_kw
                if self._hosts > 1:
                    self._hruntimes = [EngineRuntime(
                        self._harenas[h], policy=kw["policy"],
                        n_workers=kw["n_workers"],
                        granularity=kw["granularity"],
                        backend=kw["backend"],
                        max_batch=kw["max_batch"],
                        flush_us=kw["flush_us"],
                        cluster=self._hctxs[h],
                        tracer=self.tracer)
                        for h in range(self._hosts)]
                    self._bus.scheds = [rt.sched
                                        for rt in self._hruntimes]
                    self._bus.install_steal()
                    self._runtime = self._hruntimes[0]
                else:
                    self._runtime = EngineRuntime(
                        self.arena, policy=kw["policy"],
                        n_workers=kw["n_workers"],
                        granularity=kw["granularity"],
                        backend=kw["backend"],
                        max_batch=kw["max_batch"],
                        flush_us=kw["flush_us"],
                        tracer=self.tracer)
            return self._runtime

    @property
    def runtime(self) -> EngineRuntime:
        """The persistent engine substrate (created on first read if
        needed) — benchmarks read its dispatcher gauges."""
        return self._ensure_runtime()

    def close(self) -> None:
        """Shut down the persistent runtime (scheduler workers + sweep
        dispatchers). Snapshot reads keep working; refreshes or query
        sweeps afterwards spin up a fresh runtime."""
        with self._state:
            runtime, self._runtime = self._runtime, None
            hrts = getattr(self, "_hruntimes", None)
            self._hruntimes = None
        if hrts is not None:
            for rt in hrts:
                rt.shutdown()
        elif runtime is not None:
            runtime.shutdown()

    def __enter__(self) -> "StreamingMiner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):   # pragma: no cover - gc-timing dependent
        try:
            self.close()
        except Exception:
            pass

    # ------------------------------------------------------------ queries --
    @property
    def snapshot(self) -> PatternSnapshot:
        """The last published generation (atomic reference read)."""
        return self._snapshot

    @property
    def needs_refresh(self) -> bool:
        # snapshot BOTH counters under the state lock: free-running
        # reads racing a completing refresh (or a compaction) could
        # pair a fresh segment count with a stale refreshed count and
        # report negative/phantom pending segments
        with self._state:
            return self.arena.n_segments > self._refreshed_segments

    def _resolve_ms(self, n_transactions: Optional[int] = None) -> int:
        if n_transactions is None:
            n_transactions = self.n_transactions
        if isinstance(self._ms_spec, float):
            return max(1, int(self._ms_spec * n_transactions))
        return int(self._ms_spec)

    def _query_view(self) -> QueryPlanner:
        # caller holds _state: snapshot, known store, item supports and
        # the refreshed-segment set are one consistent generation
        return QueryPlanner(self._snapshot, self._known,
                            self._item_support,
                            range(self._refreshed_segments))

    def _commit_answers(self, known_ref: Dict[Itemset, int],
                        updates: Dict[Itemset, int]) -> None:
        with self._state:
            # a refresh may have published a NEW known store while the
            # sweep was in flight — the answers were exact for the
            # generation they were planned against, so they are
            # returned to the caller either way, but backfilling them
            # into the wrong generation's store would corrupt it
            if self._known is known_ref:
                known_ref.update(updates)
                self._query_known.update(updates)

    def _bill_query(self, n_sweeps: int, nbytes: int) -> None:
        with self._state:
            self.query_sweeps += n_sweeps
            self.query_sweep_bytes += nbytes

    def query_supports(self, itemsets: Sequence[Sequence[int]]
                       ) -> List[Tuple[int, bool]]:
        """Exact ``(support, swept)`` for ARBITRARY itemsets over the
        refreshed database — dict hits where the published generation
        already counted, one coalesced priority sweep burst for the
        rest (see :class:`QueryPlanner`)."""
        return _serve_queries(self, itemsets)

    def support_many(self, itemsets: Sequence[Sequence[int]]
                     ) -> List[int]:
        """Batched exact supports (``query_supports`` minus the swept
        flags)."""
        return [s for s, _ in self.query_supports(itemsets)]

    # ------------------------------------------------------------- ingest --
    def ingest(self, batch: Sequence[Sequence[int]]) -> IngestReport:
        """Append a batch of transactions as one fresh arena segment.
        O(batch) work and — with eager ("jax") arena backing — exactly
        the new segment's payload in device upload; the mined results
        are stale until the next :meth:`refresh` (queries keep serving
        the published generation). Never blocks behind an in-flight
        refresh: only the brief state lock is taken, and the new
        segment lands in the NEXT generation (the running refresh
        sweeps only its captured boundary segments)."""
        batch = [list(t) for t in batch]
        _check_items(batch, self.n_items)
        t0 = time.perf_counter()
        seg_bm = pack_database(batch, self.n_items)   # outside any lock
        with self._state:
            if self._hosts > 1:
                # whole-segment ownership: the least-loaded host gets
                # the payload, every peer a zero-width twin — segment
                # ids stay aligned across all host arenas, and this
                # segment's refresh delta is host-local by construction
                owner = min(range(self._hosts),
                            key=lambda h: (self._harenas[h].n_words, h))
                h0 = sum(ar.h2d_bytes for ar in self._harenas)
                empty = np.zeros((seg_bm.shape[0], 0), np.uint32)
                for h, ar in enumerate(self._harenas):
                    seg = ar.add_segment(
                        seg_bm if h == owner else empty)
                self._seg_tx.append(len(batch))
                self.n_transactions += len(batch)
                self._pending_since.append(t0)
                return self._ingest_done(IngestReport(
                    segment=seg, n_transactions=len(batch),
                    words=seg_bm.shape[1],
                    payload_bytes=self._harenas[owner].seg_nbytes(seg),
                    h2d_bytes=sum(ar.h2d_bytes
                                  for ar in self._harenas) - h0,
                    wall_s=time.perf_counter() - t0), t0)
            h0 = self.arena.h2d_bytes
            seg = self.arena.add_segment(seg_bm)
            self._seg_tx.append(len(batch))
            self.n_transactions += len(batch)
            self._pending_since.append(t0)
            return self._ingest_done(IngestReport(
                segment=seg, n_transactions=len(batch),
                words=seg_bm.shape[1],
                payload_bytes=self.arena.seg_nbytes(seg),
                h2d_bytes=self.arena.h2d_bytes - h0,
                wall_s=time.perf_counter() - t0), t0)

    def _ingest_done(self, rep: IngestReport, t0: float) -> IngestReport:
        tr = self.tracer
        if tr is not None:
            tr.span("ingest", t0, cat="stream",
                    args={"segment": rep.segment,
                          "tx": rep.n_transactions,
                          "bytes": rep.payload_bytes})
        return rep

    # ------------------------------------------------------------ refresh --
    def refresh(self, before_publish=None) -> RefreshReport:
        """Fold every pending segment into a new published generation,
        re-mining only invalidated equivalence classes. Returns the
        refresh report; the new :class:`PatternSnapshot` is swapped in
        atomically at the end (``before_publish(snapshot)``, if given,
        runs just before the swap — tests use it to observe the
        serving layer mid-refresh).

        The generation boundary (segment count + transaction count) is
        captured up front under the state lock; every sweep names its
        segments explicitly, so batches an overlapped :meth:`ingest`
        appends mid-refresh are invisible to this generation and fold
        in on the next one."""
        with self._refresh_lock:
            t0 = time.perf_counter()
            arena = self.arena
            with self._state:
                boundary = arena.n_segments
                pending = tuple(range(self._refreshed_segments,
                                      boundary))
                boundary_tx = sum(self._seg_tx[:boundary])
                # all-or-nothing: mine against WORKING copies and
                # commit only at publish, so a failed refresh (task
                # error mid-mine) leaves the miner's state untouched
                # and a retry cannot double-add the pending segments'
                # deltas. The shallow _known copy is cheap next to the
                # mining it fronts.
                known = dict(self._known)
                qk = set(self._query_known)
            base_segments = tuple(range(boundary))
            deltas = np.zeros(self.n_items, np.int64)
            arenas = self._harenas if self._hosts > 1 else (arena,)
            for g in pending:
                # a pending segment lives whole on its owner host; the
                # peers' zero-width twins contribute nothing
                for ar in arenas:
                    seg = ar.seg_view(g)[:self.n_items]
                    if seg.shape[1]:
                        deltas += tidlist.popcount32(seg).sum(axis=1)
            dirty = frozenset(int(i) for i in np.nonzero(deltas)[0])
            # query backfills live outside the candidate frontier, so
            # the delta plan is not guaranteed to revisit them — drop
            # the ones whose support may have changed (every item
            # dirty) rather than let them serve stale counts; they
            # re-sweep on the next miss
            for x in [x for x in qk
                      if x and all(i in dirty for i in x)]:
                known.pop(x, None)
                qk.discard(x)
            item_support = self._item_support + deltas
            ms = self._resolve_ms(boundary_tx)
            prev = self._snapshot.supports

            def hotness(prefix: Itemset) -> float:
                """Staleness priority of a re-mine task: the stale
                prefix's popularity (its last known support), so drain
                selection serves hot prefixes first and the snapshot
                converges where queries concentrate."""
                if len(prefix) == 1:
                    return float(item_support[prefix[0]])
                return float(known.get(prefix, 0))

            plan = DeltaPlan(
                known=known,
                dirty_items=dirty,
                segments=pending,
                base_segments=base_segments,
                # an empty known store means everything is fresh — no
                # staleness to rank, and stamping priorities would only
                # buy the priority-drain scan on every task switch
                priority_of=hotness if known else None)
            singles: Dict[Itemset, int] = {
                (i,): int(s) for i, s in enumerate(item_support)
                if s >= ms}
            result = dict(singles)
            frequent = sorted(result)
            if self._hosts > 1:
                metrics = self._refresh_cluster(plan, item_support,
                                                ms, singles, t0)
            else:
                h2d0, d2d0 = arena.h2d_bytes, arena.d2d_bytes
                run = MiningRun(arena, item_counts=item_support,
                                runtime=self._ensure_runtime(),
                                **self._run_kw)
                run.metrics.frequent += len(frequent)
                try:
                    mine_more(run, ms, self.max_k, result, frequent,
                              delta=plan)
                finally:
                    run.close()
                metrics = run.finalize(t0)
                metrics.h2d_bytes = arena.h2d_bytes - h2d0
                metrics.d2d_bytes = arena.d2d_bytes - d2d0

            # exact assembly from the reuse store: skipped (clean)
            # subtrees never touched `result`, but their supports are
            # in the known store — and downward closure makes the
            # filter exact. The sub-threshold remainder IS the
            # negative border, published alongside so the serving
            # layer answers "how infrequent" without a sweep.
            final = dict(singles)
            border: Dict[Itemset, int] = {}
            for x, s in known.items():
                if len(x) <= self.max_k:
                    if s >= ms:
                        final[x] = s
                    else:
                        border[x] = s

            # single-pass border classification: one membership probe
            # per published itemset (the old two-set construction was
            # a measurable slice of small-delta refresh wall time)
            stayed = born = 0
            for x in final:
                if x in prev:
                    stayed += 1
                else:
                    born += 1
            died = len(prev) - stayed
            snapshot = PatternSnapshot(self.generation + 1,
                                       boundary_tx, ms, final,
                                       border=border)
            report = RefreshReport(
                generation=snapshot.generation,
                n_transactions=boundary_tx,
                min_support=ms,
                frequent=len(final),
                segments_refreshed=pending,
                dirty_items=len(dirty),
                stayed=stayed,
                born=born,
                died=died,
                reused=plan.reused,
                swept_delta=plan.swept_delta,
                swept_full=plan.swept_full,
                rows_touched=metrics.rows_touched,
                bytes_swept=metrics.bytes_swept,
                h2d_bytes=metrics.h2d_bytes,
                d2d_bytes=metrics.d2d_bytes,
                wall_s=time.perf_counter() - t0,
                metrics=metrics)
            # the hook observes the world just before the swap and may
            # itself ingest — so it runs OUTSIDE the state lock
            if before_publish is not None:
                before_publish(snapshot)
            tr = self.tracer
            t_pub = tr.now() if tr is not None else 0.0
            with self._state:
                # commit point: plain assignments, then the swap
                self._item_support = item_support
                self._known = known
                self._query_known = qk
                self._refreshed_segments = boundary
                self._snapshot = snapshot       # the atomic swap
                self.generation = snapshot.generation
                # this generation absorbed the pending segments — their
                # ingest times leave the lag window at the commit point
                del self._pending_since[:len(pending)]
                c0 = arena.compaction_bytes
                report.compacted_segments = self._maybe_compact()
                report.compaction_bytes = arena.compaction_bytes - c0
            report.wall_s = time.perf_counter() - t0
            if tr is not None:
                tr.span("publish", t_pub, cat="stream",
                        args={"generation": snapshot.generation})
                tr.span("refresh", t0, cat="stream",
                        args={"generation": snapshot.generation,
                              "segments": len(pending),
                              "frequent": len(final)})
                tr.counter("refresh_lag", {"s": self.refresh_lag})
            return report

    # ------------------------------------------------------- multi-host --
    def _refresh_cluster(self, plan: DeltaPlan, item_support, ms: int,
                         singles: Dict[Itemset, int],
                         t0: float) -> MiningMetrics:
        """One refresh generation over the loopback cluster: N driver
        threads, each a :class:`MiningRun` on its host's arena slice
        and persistent cluster runtime, all sharing ONE delta plan (the
        plan's known store is the working copy the caller commits).
        Cluster gauges persist for the miner's lifetime, so the merged
        metrics report THIS refresh's deltas."""
        from repro.core import cluster as _cluster
        self._ensure_runtime()
        bus = self._bus
        g = bus.gauges
        h2d0 = sum(ar.h2d_bytes for ar in self._harenas)
        d2d0 = sum(ar.d2d_bytes for ar in self._harenas)
        with g.lock:
            g0 = (g.net_bytes, g.steal_net, g.cross_steals,
                  list(g.eval_s), list(g.eval_bytes))
        n = self._hosts
        mets: List[Optional[MiningMetrics]] = [None] * n
        errs: List[Optional[BaseException]] = [None] * n

        def driver(h: int) -> None:
            try:
                result_h = dict(singles)
                frequent_h = sorted(result_h)
                run = MiningRun(self._harenas[h],
                                item_counts=item_support,
                                runtime=self._hruntimes[h],
                                **self._run_kw)
                # level-1 frequent is global — bill it once (host 0)
                if h == 0:
                    run.metrics.frequent += len(frequent_h)
                try:
                    mine_more(run, ms, self.max_k, result_h,
                              frequent_h, delta=plan)
                finally:
                    run.close()
                mets[h] = run.finalize(t0)
            except BaseException as e:  # noqa: BLE001 - unblock peers
                errs[h] = e
                bus.abort()

        threads = [threading.Thread(target=driver, args=(h,),
                                    name=f"stream-host-{h}")
                   for h in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if any(e is not None for e in errs):
            bus.barrier.reset()      # un-break it for the next refresh
            for e in errs:
                if e is not None and not isinstance(e, RuntimeError):
                    raise e
            for e in errs:
                if e is not None:
                    raise e
        m = _cluster.merge_metrics(mets, g,
                                   self._run_kw["granularity"])
        m.net_bytes -= g0[0]
        m.steal_net -= g0[1]
        m.cross_steals -= g0[2]
        for row in m.per_host:
            row["eval_s"] -= g0[3][row["host"]]
            row["eval_bytes"] -= g0[4][row["host"]]
        m.h2d_bytes = sum(ar.h2d_bytes for ar in self._harenas) - h2d0
        m.d2d_bytes = sum(ar.d2d_bytes for ar in self._harenas) - d2d0
        return m

    @property
    def cluster_gauges(self) -> Optional[Dict[str, int]]:
        """Lifetime interconnect billing (``net_bytes`` /
        ``steal_net`` / ``cross_steals`` / ``reduced_flushes``) — None
        unless ``hosts > 1``."""
        if self._hosts < 2:
            return None
        return self._bus.gauges.snapshot()

    # ------------------------------------------------------ observability --
    @property
    def refresh_lag(self) -> float:
        """Seconds the oldest not-yet-published ingest has waited
        (0.0 when every ingested segment is covered by the current
        generation). The staleness gauge a streaming deployment
        alarms on: it grows while deltas queue and snaps back to
        zero at each publish."""
        with self._state:
            if not self._pending_since:
                return 0.0
            return time.perf_counter() - self._pending_since[0]

    def metrics_registry(self) -> "MetricsRegistry":
        """Pull-based metrics for this miner: a fresh
        :class:`repro.obs.MetricsRegistry` whose ``snapshot()``
        reads live state — stream gauges (generation, transaction
        and pending-segment counts, ``refresh_lag_s``), per-kind
        query-latency percentiles, and — once the engine runtime
        exists — the scheduler / per-device / arena sources it
        registers."""
        reg = MetricsRegistry()

        def stream() -> Dict[str, object]:
            with self._state:
                pending = self.arena.n_segments - self._refreshed_segments
                lag = (time.perf_counter() - self._pending_since[0]
                       if self._pending_since else 0.0)
                return {"generation": self.generation,
                        "n_transactions": self.n_transactions,
                        "pending_segments": pending,
                        "refresh_lag_s": lag}

        reg.register("stream", stream)
        reg.register("query_latency", self.latency.percentiles)
        rt = self._runtime
        if rt is not None:
            for name in rt.registry.names():
                reg.register(name, lambda n=name, r=rt:
                             r.registry.snapshot()[n])
        return reg

    # --------------------------------------------------------- compaction --
    def _maybe_compact(self) -> int:
        """Fold the refreshed segments into one when the policy fires
        (caller holds the state lock, no refresh mining in flight).
        In-flight query sweeps hold segment ids compaction renumbers,
        so the gate is drained first — briefly, with queries winning:
        on timeout the fold is skipped and the policy re-fires at the
        next publish. Returns the number of segments removed."""
        r = self._refreshed_segments
        if r < 2 or self._hosts > 1:
            return 0
        lead = self.arena.seg_words(0)
        tail = sum(self.arena.seg_words(g) for g in range(1, r))
        if not (r > self.compact_segments
                or tail <= self.compact_ratio * max(lead, 1)):
            return 0
        if not self._gate.wait_idle(1.0):
            return 0
        return self._compact(r)

    def _compact(self, upto: int) -> int:
        removed = self.arena.compact(upto)
        if removed:
            self._seg_tx[:removed + 1] = [sum(self._seg_tx[:removed + 1])]
            self._refreshed_segments -= removed
        return removed

    def compact_now(self) -> int:
        """Force-fold every refreshed segment regardless of policy
        (maintenance hook; also what the cadence-equivalence tests
        drive). Returns the number of segments removed — 0 if query
        sweeps stayed in flight past the drain timeout, and always 0
        when ``hosts > 1`` (compaction is single-host only)."""
        if self._hosts > 1:
            return 0
        with self._refresh_lock, self._state:
            if not self._gate.wait_idle(5.0):
                return 0
            return self._compact(self._refreshed_segments)

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        with self._state:
            n_seg = self.arena.n_segments
            pending = n_seg - self._refreshed_segments
            return (f"<StreamingMiner gen={self.generation} "
                    f"tx={self.n_transactions} "
                    f"segments={n_seg} "
                    f"pending={pending} "
                    f"known={len(self._known)}>")


# ---------------------------------------------------------------------------
# multi-tenant serving
# ---------------------------------------------------------------------------

class Tenant:
    """One stream inside a :class:`TenantHub`: the full ingest →
    refresh → snapshot/serve lifecycle scoped to the tenant's own
    tagged segment set, sharing the hub's arena and engine runtime
    with every other tenant. Create via :meth:`TenantHub.tenant`."""

    def __init__(self, hub: "TenantHub", tid, min_support,
                 weight: float = 1.0):
        self.hub = hub
        self.tid = tid
        self.weight = float(weight)
        self.n_items = hub.n_items
        self.max_k = hub.max_k
        self.arena = hub.arena
        self._ms_spec = min_support
        self.n_transactions = 0
        self.generation = 0
        self._segments: List[int] = []   # refreshed (mined) segments
        self._pending: List[int] = []    # ingested, not yet refreshed
        self._seg_tx: Dict[int, int] = {}
        self._item_support = np.zeros(hub.n_items, np.int64)
        self._known: Dict[Itemset, int] = {}
        self._query_known: Set[Itemset] = set()
        self._refresh_lock = threading.Lock()
        self._snapshot = PatternSnapshot(0, 0, self._resolve_ms(0), {})
        self._server: Optional[PatternServer] = None
        # serving plumbing shared hub-wide (one lock, one gate, one
        # dispatcher round-robin) — queries from every tenant coalesce
        self._state = hub._state
        self._gate = hub._gate
        self._q_rr = hub._q_rr
        # per-tenant meters
        self.sweep_bytes = 0             # mining sweeps (refreshes)
        self.query_sweeps = 0
        self.query_sweep_bytes = 0
        self.last_flush_occupancy = 0.0
        self.latency = LatencyRecorder()
        self._pending_since: List[float] = []

    # shared serving protocol --------------------------------------------
    def _ensure_runtime(self) -> EngineRuntime:
        return self.hub._ensure_runtime()

    def _resolve_ms(self, n_transactions: int) -> int:
        if isinstance(self._ms_spec, float):
            return max(1, int(self._ms_spec * n_transactions))
        return int(self._ms_spec)

    def _query_view(self) -> QueryPlanner:
        return QueryPlanner(self._snapshot, self._known,
                            self._item_support,
                            tuple(self._segments))

    def _commit_answers(self, known_ref, updates) -> None:
        with self._state:
            if self._known is known_ref:
                known_ref.update(updates)
                self._query_known.update(updates)

    def _bill_query(self, n_sweeps: int, nbytes: int) -> None:
        with self._state:
            self.query_sweeps += n_sweeps
            self.query_sweep_bytes += nbytes

    # public surface ------------------------------------------------------
    @property
    def snapshot(self) -> PatternSnapshot:
        return self._snapshot

    @property
    def needs_refresh(self) -> bool:
        with self._state:
            return bool(self._pending)

    @property
    def server(self) -> PatternServer:
        if self._server is None:
            self._server = PatternServer(self)
        return self._server

    def query_supports(self, itemsets: Sequence[Sequence[int]]
                       ) -> List[Tuple[int, bool]]:
        return _serve_queries(self, itemsets)

    def support_many(self, itemsets: Sequence[Sequence[int]]
                     ) -> List[int]:
        return [s for s, _ in self.query_supports(itemsets)]

    def ingest(self, batch: Sequence[Sequence[int]]) -> IngestReport:
        """Append a batch as one fresh segment TAGGED with this
        tenant's id — other tenants never sweep it, and arena
        compaction refuses to fold across the tag."""
        batch = [list(t) for t in batch]
        _check_items(batch, self.n_items)
        t0 = time.perf_counter()
        seg_bm = pack_database(batch, self.n_items)
        with self._state:
            h0 = self.arena.h2d_bytes
            seg = self.arena.add_segment(seg_bm, tenant=self.tid)
            self._pending.append(seg)
            self._pending_since.append(t0)
            self._seg_tx[seg] = len(batch)
            self.n_transactions += len(batch)
            return IngestReport(
                segment=seg, n_transactions=len(batch),
                words=seg_bm.shape[1],
                payload_bytes=self.arena.seg_nbytes(seg),
                h2d_bytes=self.arena.h2d_bytes - h0,
                wall_s=time.perf_counter() - t0)

    def refresh(self, before_publish=None) -> RefreshReport:
        """StreamingMiner.refresh over the tenant's segment set: the
        delta plan's base is the tenant's refreshed+pending segments
        (a non-contiguous subset of the shared arena), and every
        spawned task carries the tenant tag so the weighted-fair drain
        rule arbitrates between concurrently refreshing tenants."""
        with self._refresh_lock:
            t0 = time.perf_counter()
            hub, arena = self.hub, self.arena
            runtime = self._ensure_runtime()
            with self._state:
                pending = tuple(self._pending)
                base_segments = tuple(self._segments) + pending
                boundary_tx = sum(self._seg_tx[g]
                                  for g in base_segments)
                known = dict(self._known)
                qk = set(self._query_known)
            deltas = np.zeros(self.n_items, np.int64)
            for g in pending:
                seg = arena.seg_view(g)[:self.n_items]
                deltas += tidlist.popcount32(seg).sum(axis=1)
            dirty = frozenset(int(i) for i in np.nonzero(deltas)[0])
            for x in [x for x in qk
                      if x and all(i in dirty for i in x)]:
                known.pop(x, None)
                qk.discard(x)
            item_support = self._item_support + deltas
            ms = self._resolve_ms(boundary_tx)
            prev = self._snapshot.supports

            def hotness(prefix: Itemset) -> float:
                if len(prefix) == 1:
                    return float(item_support[prefix[0]])
                return float(known.get(prefix, 0))

            plan = DeltaPlan(
                known=known,
                dirty_items=dirty,
                segments=pending,
                base_segments=base_segments,
                priority_of=hotness if known else None,
                tenant=self.tid)
            singles: Dict[Itemset, int] = {
                (i,): int(s) for i, s in enumerate(item_support)
                if s >= ms}
            result = dict(singles)
            frequent = sorted(result)
            h2d0, d2d0 = arena.h2d_bytes, arena.d2d_bytes
            run = MiningRun(arena, item_counts=item_support,
                            runtime=runtime, **hub._run_kw)
            run.metrics.frequent += len(frequent)
            try:
                mine_more(run, ms, self.max_k, result, frequent,
                          delta=plan)
            finally:
                run.close()
            metrics = run.finalize(t0)
            metrics.h2d_bytes = arena.h2d_bytes - h2d0
            metrics.d2d_bytes = arena.d2d_bytes - d2d0
            final = dict(singles)
            border: Dict[Itemset, int] = {}
            for x, s in known.items():
                if len(x) <= self.max_k:
                    if s >= ms:
                        final[x] = s
                    else:
                        border[x] = s
            stayed = sum(1 for x in final if x in prev)
            born = len(final) - stayed
            died = len(prev) - stayed
            snapshot = PatternSnapshot(self.generation + 1,
                                       boundary_tx, ms, final,
                                       border=border)
            report = RefreshReport(
                generation=snapshot.generation,
                n_transactions=boundary_tx,
                min_support=ms,
                frequent=len(final),
                segments_refreshed=pending,
                dirty_items=len(dirty),
                stayed=stayed, born=born, died=died,
                reused=plan.reused,
                swept_delta=plan.swept_delta,
                swept_full=plan.swept_full,
                rows_touched=metrics.rows_touched,
                bytes_swept=metrics.bytes_swept,
                h2d_bytes=metrics.h2d_bytes,
                d2d_bytes=metrics.d2d_bytes,
                wall_s=time.perf_counter() - t0,
                metrics=metrics)
            if before_publish is not None:
                before_publish(snapshot)
            with self._state:
                self._item_support = item_support
                self._known = known
                self._query_known = qk
                self._segments = list(base_segments)
                landed = set(pending)
                self._pending = [g for g in self._pending
                                 if g not in landed]
                del self._pending_since[:len(pending)]
                self._snapshot = snapshot
                self.generation = snapshot.generation
                self.sweep_bytes += metrics.bytes_swept
                self.last_flush_occupancy = metrics.batch_occupancy
            report.wall_s = time.perf_counter() - t0
            return report

    @property
    def refresh_lag(self) -> float:
        """Seconds this tenant's oldest unpublished ingest has waited
        (see :attr:`StreamingMiner.refresh_lag`)."""
        with self._state:
            if not self._pending_since:
                return 0.0
            return time.perf_counter() - self._pending_since[0]

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        with self._state:
            return (f"<Tenant {self.tid!r} gen={self.generation} "
                    f"tx={self.n_transactions} "
                    f"segments={len(self._segments)} "
                    f"pending={len(self._pending)}>")


class TenantHub:
    """Multi-tenant serving: several independent transaction streams
    multiplexed onto ONE :class:`BitmapArena` and ONE persistent
    :class:`EngineRuntime`.

    Each :class:`Tenant` owns a disjoint set of arena segments
    (tagged at ingest, so compaction never folds across tenants), its
    own min-support spec, known store, and published snapshot;
    refreshes and query sweeps from every tenant share the scheduler
    workers and per-shard dispatchers, which is exactly what makes
    cross-tenant coalescing (and the fairness problem) real. Fairness:
    re-mine tasks carry the tenant tag, and the clustered drain rule
    serves the worker-local tenant with the highest
    ``weight / (served + 1)`` deficit first — a heavy tenant gets
    proportionally more engine turns but can never starve a light
    one. Per-tenant meters (queries by kind, sweep bytes, flush
    occupancy, tasks served) surface through :meth:`tenant_stats`."""

    def __init__(self, n_items: int, *, policy: str = "clustered",
                 n_workers: int = 4, max_k: int = 6,
                 granularity: str = "bucket", backend: str = "auto",
                 arena: str = "auto", cache_size: int = 32,
                 max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, mesh=None,
                 representation: str = "auto"):
        if n_items < 1:
            raise ValueError(f"n_items must be >= 1, got {n_items}")
        self.n_items = n_items
        self.max_k = max_k
        self._run_kw = dict(policy=policy, n_workers=n_workers,
                            granularity=granularity, backend=backend,
                            cache_size=cache_size, max_batch=max_batch,
                            flush_us=flush_us,
                            representation=representation)
        n_shards, devices = _resolve_mesh(mesh)
        # the arena starts with one empty (zero-width) segment; every
        # real segment arrives tagged via Tenant.ingest
        self.arena = BitmapArena.from_bitmaps(
            pack_database([], n_items), backing=arena,
            n_shards=n_shards, devices=devices)
        self._state = threading.RLock()
        self._gate = _QueryGate(self._state)
        self._q_rr = itertools.count()
        self._runtime: Optional[EngineRuntime] = None
        self._tenants: Dict[Any, Tenant] = {}

    def _ensure_runtime(self) -> EngineRuntime:
        with self._state:
            if self._runtime is None:
                kw = self._run_kw
                self._runtime = EngineRuntime(
                    self.arena, policy=kw["policy"],
                    n_workers=kw["n_workers"],
                    granularity=kw["granularity"],
                    backend=kw["backend"], max_batch=kw["max_batch"],
                    flush_us=kw["flush_us"])
                self._push_weights()
            return self._runtime

    def _push_weights(self) -> None:
        # caller holds _state
        runtime = self._runtime
        if runtime is None:
            return      # pushed when the runtime is first built
        policy = runtime.sched.policy
        if isinstance(policy, ClusteredPolicy):
            policy.set_weights(
                {tid: t.weight for tid, t in self._tenants.items()}
                or None)

    def tenant(self, tid, min_support=None, *,
               weight: float = 1.0) -> Tenant:
        """Register a new tenant stream (``min_support`` required) or
        fetch an existing one by id."""
        with self._state:
            t = self._tenants.get(tid)
            if t is None:
                if min_support is None:
                    raise ValueError(
                        "min_support is required when registering a "
                        "new tenant")
                t = Tenant(self, tid, min_support, weight)
                self._tenants[tid] = t
                self._push_weights()
            return t

    @property
    def tenants(self) -> Tuple[Tenant, ...]:
        with self._state:
            return tuple(self._tenants.values())

    def refresh_all(self) -> Dict[Any, RefreshReport]:
        """Refresh every tenant with pending segments (sequentially —
        callers wanting overlap run per-tenant ``refresh`` from their
        own threads; the shared runtime arbitrates)."""
        out = {}
        for t in self.tenants:
            if t.needs_refresh or t.generation == 0:
                out[t.tid] = t.refresh()
        return out

    def tenant_stats(self) -> Dict[Any, Dict[str, Any]]:
        """Per-tenant serving/mining meters: generation, stream size,
        queries served by kind, sweep bytes (mining + query), last
        refresh's flush occupancy, scheduler tasks served under the
        fairness rule, and the configured weight."""
        with self._state:
            served: Dict[Any, int] = {}
            if self._runtime is not None and isinstance(
                    self._runtime.sched.policy, ClusteredPolicy):
                served = self._runtime.sched.policy.tenant_served()
            out: Dict[Any, Dict[str, Any]] = {}
            for tid, t in self._tenants.items():
                q = (t._server.merged_stats()
                     if t._server is not None else
                     obs_schema.query_stats({}))
                out[tid] = {
                    "generation": t.generation,
                    "transactions": t.n_transactions,
                    "segments": len(t._segments) + len(t._pending),
                    "frequent": len(t._snapshot.supports),
                    "weight": t.weight,
                    "tasks_served": int(served.get(tid, 0)),
                    "sweep_bytes": t.sweep_bytes,
                    "query_sweeps": t.query_sweeps,
                    "query_sweep_bytes": t.query_sweep_bytes,
                    "flush_occupancy": t.last_flush_occupancy,
                    "queries": q,
                }
            return out

    def close(self) -> None:
        """Shut down the shared runtime; snapshots keep serving."""
        with self._state:
            runtime, self._runtime = self._runtime, None
        if runtime is not None:
            runtime.shutdown()

    def __enter__(self) -> "TenantHub":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):   # pragma: no cover - gc-timing dependent
        try:
            self.close()
        except Exception:
            pass

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        with self._state:
            return (f"<TenantHub items={self.n_items} "
                    f"tenants={len(self._tenants)} "
                    f"segments={self.arena.n_segments}>")
