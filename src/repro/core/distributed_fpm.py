"""Compatibility wrapper: multi-device mining IS the task engine now.

The bespoke level-synchronous ``shard_map`` driver that used to live
here (per-device planners, ``_kernel_clustered`` / ``_kernel_round_robin``
bodies, per-level jit rebuilds) bypassed the scheduler, the
``BitmapArena``, and the ``SweepDispatcher`` entirely — none of the
engine's wins (barrier-free depth-first, handle-based batched sweeps,
device-resident bitmaps) existed beyond one device, and every level
re-wrapped the kernel in a fresh ``functools.partial``/``jax.jit``,
defeating the jit cache.

All of that is deleted. ``repro.core.fpm.mine(mesh=...)`` runs every
granularity distributed: the arena shards one mirror per mesh device
(pinned item rows replicated, materialized rows owned by the creating
shard, cross-shard fetches in ``d2d_bytes``), one ``SweepDispatcher``
per device flushes ``bitmap_join_many`` on its own shard, and the
scheduler's clustered placement is device placement (cross-device
bucket steals migrate the bucket's retained bitmaps explicitly).
Kernel compilation is cached at module level (``repro.kernels``), so
there is nothing per-level left to rebuild.

``mine_distributed`` survives as a thin shim mapping the old two-policy
API onto the unified engine:

  clustered    → clustered placement at bucket granularity (the prefix
                 join computed once per bucket, extensions swept
                 batched — the owner-computes locality path).
  round_robin  → scattered FIFO placement at candidate granularity
                 with the prefix cache disabled (every candidate pays
                 its full k-way join — the no-locality baseline).

Both return identical supports; the locality difference shows up in the
measured rows-touched counters (shared cost model in
``repro.core.buckets``).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.fpm import mine
from repro.core.itemsets import Itemset

#                  fpm policy, granularity, cache_size
_POLICY_MAP = {
    "clustered":   ("clustered", "bucket", 32),
    "round_robin": ("fifo", "candidate", 0),
}


def mine_distributed(bitmaps: np.ndarray, min_support: int, mesh,
                     *, policy: str = "clustered", max_k: int = 6,
                     axis_name: str | None = None, n_workers: int = 8,
                     backend: str = "auto",
                     ) -> Tuple[Dict[Itemset, int], Dict[str, int]]:
    """Level-synchronous distributed Apriori (compat shim over
    ``fpm.mine(mesh=...)``). Returns (supports, stats) with the
    historical stats keys plus the mesh gauges (``d2d_bytes``,
    ``migrations``, ``n_devices``, ``per_device``)."""
    if policy not in _POLICY_MAP:
        raise ValueError(policy)
    axes = getattr(mesh, "axis_names", ())
    if len(axes) > 1:
        # the old driver sharded over ONE axis of a possibly-wider
        # mesh; the unified engine shards over every mesh device.
        # Refuse rather than silently change the caller's placement.
        raise ValueError(
            f"mine_distributed shards over all devices of a 1-axis "
            f"mesh; got axes {tuple(axes)} — pass a sub-mesh of the "
            f"axis to shard over (was: axis_name={axis_name!r})")
    fpm_policy, granularity, cache_size = _POLICY_MAP[policy]
    result, met = mine(bitmaps, min_support, mesh=mesh,
                       policy=fpm_policy, granularity=granularity,
                       cache_size=cache_size, max_k=max_k,
                       n_workers=n_workers, backend=backend)
    stats = {
        "levels": met.levels,
        "candidates": met.candidates,
        "rows_touched": met.rows_touched,
        "bytes_swept": met.bytes_swept,
        "n_devices": met.n_devices,
        "d2d_bytes": met.d2d_bytes,
        "migrations": met.migrations,
        "per_device": met.per_device,
    }
    return result, stats
