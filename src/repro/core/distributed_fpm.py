"""Distributed (multi-device) Apriori under shard_map — the paper's
clustered scheduling transposed to a TPU mesh (DESIGN.md §3, layer 2).

Level-synchronous mining. Item TID-bitmaps are sharded over devices
(owner = item % n_devices). Candidates for level k are partitioned into
per-device work lists under one of two assignment policies:

  clustered    whole prefix-buckets are placed together (owner = the
               bucket's first item's owner, with cluster-granularity
               rebalancing — the paper's bucket steal). The device
               computes each bucket's (k-1)-prefix intersection ONCE and
               sweeps the bucket's extensions against it while the prefix
               stays register/VMEM-resident (the bitmap_join kernel's
               tiling on TPU). Per-candidate HBM traffic: ~1 bitmap row.
  round_robin  the Cilk-style analogue: candidates scattered with no
               locality; every candidate performs its full k-way join
               (prefix recomputed per task). Per-candidate HBM traffic:
               ~k bitmap rows + no reuse across neighbours.

Both policies return identical supports. The locality difference shows up
in (a) rows-touched stats here, (b) HLO FLOPs/bytes of the per-level
kernel in the dry-run (benchmarks/fpm_distributed.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import tidlist
from repro.core.buckets import (bucket_rows_touched, candidate_rows_touched,
                                group_by_prefix, rows_to_bytes)
from repro.core.itemsets import Itemset, gen_candidates


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusteredPlan:
    prefixes: np.ndarray     # [n_dev, max_b, k-1] int32, -1 padded
    exts: np.ndarray         # [n_dev, max_b, max_e] int32, -1 padded
    order: List[List[Itemset]]   # per-device candidate order (b-major)
    rows_touched: int = 0


@dataclasses.dataclass
class RoundRobinPlan:
    cand_items: np.ndarray   # [n_dev, max_c, k] int32, -1 padded
    order: List[List[Itemset]]
    rows_touched: int = 0


def plan_clustered(cands: Sequence[Itemset], n_dev: int,
                   items_per_dev: int = 0) -> ClusteredPlan:
    """Place whole prefix-buckets on devices (bucket grouping shared
    with the shared-memory engine via repro.core.buckets)."""
    buckets = group_by_prefix(cands)
    loads = np.zeros(n_dev, np.int64)
    per_dev: List[List[Tuple[Itemset, Tuple[int, ...]]]] = [
        [] for _ in range(n_dev)]
    for b in sorted(buckets, key=lambda b: (-len(b), b.key)):
        pref, ext = b.prefix, b.exts
        owner = (min(pref[0] // items_per_dev, n_dev - 1)
                 if items_per_dev else pref[0] % n_dev)
        tgt = int(np.argmin(loads))
        if loads[owner] > 2 * loads[tgt] + len(ext):
            owner = tgt                       # steal the whole bucket
        per_dev[owner].append((pref, ext))
        loads[owner] += len(ext)
    k = len(cands[0])
    max_b = max(1, max(len(v) for v in per_dev))
    max_e = max(1, max((len(e) for v in per_dev for _, e in v), default=1))
    prefixes = np.full((n_dev, max_b, k - 1), -1, np.int32)
    exts = np.full((n_dev, max_b, max_e), -1, np.int32)
    order: List[List[Itemset]] = [[] for _ in range(n_dev)]
    rows = 0
    for d, lst in enumerate(per_dev):
        for b, (pref, ext) in enumerate(lst):
            prefixes[d, b] = pref
            exts[d, b, :len(ext)] = ext
            order[d].extend(pref + (e,) for e in ext)
            rows += bucket_rows_touched(k - 1, len(ext))
    return ClusteredPlan(prefixes, exts, order, rows)


def plan_round_robin(cands: Sequence[Itemset], n_dev: int) -> RoundRobinPlan:
    per_dev: List[List[Itemset]] = [[] for _ in range(n_dev)]
    for i, c in enumerate(cands):
        per_dev[i % n_dev].append(c)
    k = len(cands[0])
    max_c = max(1, max(len(v) for v in per_dev))
    arr = np.full((n_dev, max_c, k), -1, np.int32)
    for d, lst in enumerate(per_dev):
        for j, c in enumerate(lst):
            arr[d, j] = c
    rows = sum(candidate_rows_touched(k, len(lst)) for lst in per_dev)
    return RoundRobinPlan(arr, per_dev, rows)


# ---------------------------------------------------------------------------
# Per-device kernels (shard_map bodies)
# ---------------------------------------------------------------------------


def _kernel_clustered(bitmaps_local, prefixes, exts, axis_name: str,
                      k: int):
    """prefixes: [max_b, k-1]; exts: [max_b, max_e] -> counts [max_b*max_e].

    One prefix join per bucket; extensions swept against the resident
    prefix (vmapped bitmap_join shape)."""
    full = jax.lax.all_gather(bitmaps_local, axis_name, axis=0, tiled=True)

    def bucket(pref, ext):
        rows = full[jnp.maximum(pref, 0)]          # [k-1, W]
        pbm = rows[0]
        for j in range(1, k - 1):
            pbm = jnp.bitwise_and(pbm, rows[j])    # prefix AND — once
        erows = full[jnp.maximum(ext, 0)]          # [max_e, W]
        joined = jnp.bitwise_and(erows, pbm[None, :])
        cnt = jax.lax.population_count(joined).astype(jnp.int32).sum(-1)
        return jnp.where((ext >= 0) & (pref[0] >= 0), cnt, -1)

    counts = jax.vmap(bucket)(prefixes, exts)      # [max_b, max_e]
    return counts.reshape(-1)


def _kernel_round_robin(bitmaps_local, cand_items, axis_name: str, k: int):
    """cand_items: [max_c, k] -> counts [max_c]; full k-way join each."""
    full = jax.lax.all_gather(bitmaps_local, axis_name, axis=0, tiled=True)
    rows = full[jnp.maximum(cand_items, 0)]        # [max_c, k, W]
    joined = rows[:, 0]
    for j in range(1, k):
        joined = jnp.bitwise_and(joined, rows[:, j])
    counts = jax.lax.population_count(joined).astype(jnp.int32).sum(-1)
    return jnp.where(cand_items[:, 0] >= 0, counts, -1)


def shard_bitmaps(bitmaps: np.ndarray, n_dev: int) -> np.ndarray:
    """Contiguous-block owner layout: item i lives on device
    i // items_per_dev, so a tiled all_gather restores item order."""
    n_items, w = bitmaps.shape
    pad = (-n_items) % n_dev
    return np.pad(bitmaps, ((0, pad), (0, 0)))   # [I_padded, W]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def mine_distributed(bitmaps: np.ndarray, min_support: int, mesh: Mesh,
                     *, policy: str = "clustered", max_k: int = 6,
                     axis_name: Optional[str] = None
                     ) -> Tuple[Dict[Itemset, int], Dict[str, int]]:
    """Level-synchronous distributed Apriori. Returns (supports, stats)."""
    axis_name = axis_name or mesh.axis_names[0]
    n_dev = mesh.shape[axis_name]
    n_items = bitmaps.shape[0]
    sharded = shard_bitmaps(bitmaps, n_dev)      # [I_padded, W]
    items_per_dev = sharded.shape[0] // n_dev
    bm_dev = jax.device_put(jnp.asarray(sharded),
                            NamedSharding(mesh, P(axis_name)))

    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(n_items)
        if supports[i] >= min_support}
    frequent = sorted(result)
    stats = {"levels": 0, "candidates": 0, "rows_touched": 0,
             "bytes_swept": 0}

    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        if not cands:
            break
        stats["levels"] += 1
        stats["candidates"] += len(cands)

        if policy == "clustered":
            plan = plan_clustered(cands, n_dev, items_per_dev)
            fn = shard_map(
                functools.partial(_kernel_clustered, axis_name=axis_name,
                                  k=k),
                mesh=mesh,
                in_specs=(P(axis_name), P(axis_name), P(axis_name)),
                out_specs=P(axis_name))
            counts = np.asarray(jax.jit(fn)(
                bm_dev,
                jax.device_put(jnp.asarray(plan.prefixes.reshape(
                    -1, plan.prefixes.shape[2])),
                    NamedSharding(mesh, P(axis_name))),
                jax.device_put(jnp.asarray(plan.exts.reshape(
                    -1, plan.exts.shape[2])),
                    NamedSharding(mesh, P(axis_name)))))
            counts = counts.reshape(n_dev, -1)
        elif policy == "round_robin":
            plan = plan_round_robin(cands, n_dev)
            fn = shard_map(
                functools.partial(_kernel_round_robin,
                                  axis_name=axis_name, k=k),
                mesh=mesh,
                in_specs=(P(axis_name), P(axis_name)),
                out_specs=P(axis_name))
            counts = np.asarray(jax.jit(fn)(
                bm_dev,
                jax.device_put(jnp.asarray(plan.cand_items.reshape(
                    -1, plan.cand_items.shape[2])),
                    NamedSharding(mesh, P(axis_name)))))
            counts = counts.reshape(n_dev, -1)
        else:
            raise ValueError(policy)
        stats["rows_touched"] += plan.rows_touched
        stats["bytes_swept"] += rows_to_bytes(plan.rows_touched,
                                              bitmaps.shape[1])

        frequent = []
        for d in range(n_dev):
            dev_counts = counts[d]
            if policy == "clustered":
                # counts are bucket-major with -1 padding; valid entries
                # appear in exactly the order the planner emitted order[d]
                it = iter(plan.order[d])
                for v in dev_counts:
                    if v < 0:
                        continue
                    c = next(it)
                    if v >= min_support:
                        result[c] = int(v)
                        frequent.append(c)
            else:
                for j, c in enumerate(plan.order[d]):
                    v = int(dev_counts[j])
                    if v >= min_support:
                        result[c] = v
                        frequent.append(c)
        frequent.sort()
        k += 1
    return result, stats
