"""Batched join backends + the sweep dispatcher.

A *bucket sweep* is the paper's per-task TID join restructured at bucket
granularity: one (k-1)-prefix bitmap against the bucket's E extension
bitmaps, producing E support counts in one vectorized call. The old
design gave every scheduler worker its own single-prefix ``sweep`` call
and serialized all JAX dispatch behind a module-global lock, so the
"TPU fast path" was transfer-bound (every sweep re-uploaded its
extension bitmaps host→device) and single-dispatch.

This layer inverts that around two pieces:

  ``BitmapArena`` (repro.core.tidlist)  every bitmap lives in one
      refcounted, append-only row store with integer handles; the
      device mirror is synced incrementally, so repeated sweeps cost
      ~one initial upload instead of one upload per sweep.
  ``SweepDispatcher``  workers enqueue handle-based ``SweepRequest``s
      and block on a future; one dedicated dispatcher thread coalesces
      pending requests into a padded batch and launches ONE
      multi-prefix ``bitmap_join_many`` kernel for all of them. Only
      the dispatcher thread ever touches JAX — no lock exists at all.

Backends implement the same batched API:

  numpy             per-request ``tidlist.support_counts`` over
                    zero-copy arena row views — GIL-released ufunc
                    passes, the CPU tier-1 path. It runs through the
                    identical dispatcher/batching code as the kernels.
  pallas-interpret  ``bitmap_join_many`` under the Pallas interpreter —
                    bit-exact with the TPU kernel, runnable anywhere.
  pallas-jit        the compiled kernel — TPU only; each request's
                    prefix tile stays VMEM-resident across its
                    extension sweep while B requests share the launch.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.tidlist import BitmapArena

# Dispatcher defaults: how many requests one kernel launch may carry,
# and how long (µs) the dispatcher waits for stragglers to coalesce
# before flushing a partial batch.
MAX_BATCH = 32
FLUSH_US = 200.0


@dataclass
class SweepRequest:
    """One bucket sweep, by handle: counts[i] = |row(prefix) ∧ row(ext_i)|.

    ``shard`` is the device shard the request executes on — stamped by
    the (per-device) dispatcher that accepted it, so backends know
    which arena mirror to gather from. ``segments`` restricts the join
    to a subset of the arena's transaction segments (None = all): the
    streaming engine's support-delta sweeps read ONLY the freshly
    ingested segments, so a small ingest costs a small sweep."""
    prefix_handle: int
    ext_handles: Tuple[int, ...]
    shard: int = 0
    segments: Optional[Tuple[int, ...]] = None
    future: Future = field(default_factory=Future)

    def segment_ids(self, arena: BitmapArena) -> Tuple[int, ...]:
        if self.segments is not None:
            return self.segments
        return tuple(range(arena.n_segments))


class JoinBackend:
    """Batched executor: ``sweep_many(arena, requests)`` returns one
    int64 counts array per request (ragged — each sized to the
    request's own extension count)."""

    name: str = "base"

    def sweep_many(self, arena: BitmapArena,
                   requests: Sequence[SweepRequest]) -> List[np.ndarray]:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<JoinBackend {self.name}>"


class NumpyBackend(JoinBackend):
    """Zero-copy arena row views into the fused AND+popcount ufunc
    pass. Runs per-request (no padding copies), but through the same
    dispatcher path as the kernels so CPU tier-1 tests exercise the
    identical request/batch/flush machinery. In sharded mode the
    batch's row accesses are booked against the requests' shard first
    (cross-shard reads land in the arena's ``d2d_bytes`` gauge)."""

    name = "numpy"

    def sweep_many(self, arena, requests):
        if arena.n_shards > 1:
            # booked per request: batches are shard-homogeneous today
            # (each dispatcher stamps its own shard), but a mixed batch
            # must not misattribute traffic to requests[0]'s shard —
            # and a delta sweep bills only the segments it reads
            for r in requests:
                arena.note_access(r.shard,
                                  (r.prefix_handle, *r.ext_handles),
                                  segments=r.segments)
        out = []
        for r in requests:
            total = None
            for g in r.segment_ids(arena):
                if not arena.seg_words(g):
                    continue          # zero-width segment (empty batch)
                rows = arena.seg_view(g)
                c = tidlist.support_counts(rows[r.prefix_handle],
                                           arena.seg_gather(
                                               g, r.ext_handles))
                total = c if total is None else total + c
            if total is None:
                total = np.zeros(len(r.ext_handles), np.int64)
            out.append(total)
        return out


def _pow2(n: int, lo: int = 1) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


# E-padding floor = the batched kernel's E tile (kernel.EB_TILE, not
# imported to keep jax out of this module's import path): any narrower
# pad would be re-padded to one tile inside the kernel anyway, so
# distinct sub-tile shapes would only multiply jit compilations.
E_PAD_FLOOR = 64


class _PallasBackend(JoinBackend):
    """Shared plumbing for the kernel modes: pad the ragged batch to
    [B', E', W], gather rows (on device when the arena has a mirror,
    host-side otherwise), launch one ``bitmap_join_many`` per
    transaction segment the batch touches, slice each request's counts
    back out and sum them across segments. B and E pad to powers of
    two so the jit cache stays bounded (~log × log shapes per run);
    single-segment arenas (every non-streaming run) keep the one-launch
    behaviour."""

    mode = "pallas-interpret"

    def sweep_many(self, arena, requests):
        totals = [np.zeros(len(r.ext_handles), np.int64)
                  for r in requests]
        # sub-batch per segment: full sweeps touch every segment, delta
        # sweeps only the fresh ones — a mixed batch still coalesces
        # per segment
        by_seg: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            for g in r.segment_ids(arena):
                if arena.seg_words(g):
                    by_seg.setdefault(g, []).append(i)
        for g, idxs in sorted(by_seg.items()):
            counts = self._sweep_segment(arena, g,
                                         [requests[i] for i in idxs])
            for j, i in enumerate(idxs):
                totals[i] += counts[j, :len(requests[i].ext_handles)
                                    ].astype(np.int64)
        return totals

    def _sweep_segment(self, arena, seg, requests):
        import jax.numpy as jnp

        from repro.kernels.bitmap_join.ops import bitmap_join_many
        b = len(requests)
        emax = max(len(r.ext_handles) for r in requests)
        bp = _pow2(b)
        ep = _pow2(emax, lo=E_PAD_FLOOR)
        w = arena.seg_words(seg)
        pidx = np.zeros(bp, np.int32)
        eidx = np.zeros((bp, ep), np.int32)
        mask = np.zeros((bp, ep), bool)
        for i, r in enumerate(requests):
            pidx[i] = r.prefix_handle
            n = len(r.ext_handles)
            eidx[i, :n] = r.ext_handles
            mask[i, :n] = True
        shard = requests[0].shard if requests else 0
        needed = None
        if arena.n_shards > 1:
            needed = [h for r in requests
                      for h in (r.prefix_handle, *r.ext_handles)]
        dev = arena.device_rows(shard, needed=needed, segment=seg)
        if dev is not None:
            # arena-gather path: bitmaps are already device-resident,
            # only the (tiny) index arrays cross host→device
            prefixes = dev[jnp.asarray(pidx)]
            exts = dev[jnp.asarray(eidx.reshape(-1))].reshape(bp, ep, w)
        else:
            # host-gather baseline (arena backing "numpy"): the old
            # transfer-bound behaviour — every batch re-uploads its
            # bitmap payload, and the gauge records it
            rows = arena.seg_view(seg)
            ph = rows[pidx]
            eh = rows[eidx.reshape(-1)].reshape(bp, ep, w)
            arena.count_h2d(ph.nbytes + eh.nbytes)
            prefixes = jnp.asarray(ph)
            exts = jnp.asarray(eh)
        return np.asarray(bitmap_join_many(prefixes, exts,
                                           jnp.asarray(mask),
                                           mode=self.mode))


class PallasInterpretBackend(_PallasBackend):
    name = "pallas-interpret"
    mode = "pallas-interpret"


class PallasJitBackend(_PallasBackend):
    name = "pallas-jit"
    mode = "pallas-jit"


_REGISTRY: Dict[str, Callable[[], JoinBackend]] = {
    "numpy": NumpyBackend,
    "pallas-interpret": PallasInterpretBackend,
    "pallas-jit": PallasJitBackend,
}
_instances: Dict[str, JoinBackend] = {}


def get_backend(name: str) -> JoinBackend:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown join backend {name!r}; known: {sorted(_REGISTRY)}")
    b = _instances.get(name)
    if b is None:
        b = _instances[name] = _REGISTRY[name]()
    return b


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present here
        return False


def available_backends() -> List[str]:
    """Backends that can execute on this host. The compiled Pallas
    kernel only lowers on TPU; the interpreter runs anywhere."""
    names = ["numpy", "pallas-interpret"]
    if _on_tpu():
        names.append("pallas-jit")
    return names


def resolve_backend(spec: str = "auto") -> JoinBackend:
    """One backend per run (batching replaced the per-bucket choice:
    narrow buckets now amortize a launch by sharing it, so there is no
    tiny-bucket penalty to route around). "auto" is the compiled
    kernel on TPU and numpy on CPU — the interpreter is a correctness
    tool, not a fast path."""
    if spec == "auto":
        return get_backend("pallas-jit" if _on_tpu() else "numpy")
    avail = available_backends()
    if spec not in avail:
        # fail fast: an unavailable backend must error here, not
        # inside a scheduler worker thread mid-mine
        get_backend(spec)                     # unknown name -> ValueError
        raise ValueError(
            f"join backend {spec!r} is not available on this host "
            f"(available: {avail})")
    return get_backend(spec)


class SweepDispatcher:
    """Coalesces many workers' sweep requests into batched launches.

    In mesh runs there is ONE dispatcher per device shard: workers
    submit to the dispatcher matching their device affinity, requests
    are stamped with that shard, and each dispatcher flushes
    ``bitmap_join_many`` against its own arena mirror — per-device
    batching, per-device occupancy gauges.

    Workers call :meth:`sweep` (or :meth:`submit` + ``future.result()``)
    and block; the dedicated dispatcher thread gathers pending requests
    and flushes a batch when either

      * ``min(max_batch, n_clients)`` requests are pending — since
        ``sweep`` blocks its caller, pending requests count currently
        blocked clients, so once every client is waiting no further
        request can arrive and waiting longer is pure latency; or
      * ``flush_us`` elapsed since the flush started forming — bounding
        the latency a lone straggler pays when other workers are busy
        with non-sweep work.

    Errors from the backend resolve every future in the flight batch,
    so task bodies re-raise through the scheduler's normal task-error
    machinery. ``batch_occupancy`` (requests per flush) is the gauge
    that shows whether batching actually happened — the granularity
    benchmark asserts it stays above 1 so the dispatcher cannot
    silently degrade to one-bucket launches.
    """

    def __init__(self, arena: BitmapArena, backend: JoinBackend,
                 n_clients: int, max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, shard: int = 0):
        self.arena = arena
        self.backend = backend
        self.n_clients = max(1, n_clients)
        self.max_batch = max(1, max_batch)
        self.flush_s = max(0.0, flush_us) * 1e-6
        self.shard = shard
        self._pending: List[SweepRequest] = []
        self._cv = threading.Condition()
        self._stop = False
        self.flushes = 0
        self.requests = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"sweep-dispatcher-{shard}")
        self._thread.start()

    # ------------------------------------------------------------ client --
    def submit(self, prefix_handle: int,
               ext_handles: Sequence[int],
               segments: Optional[Sequence[int]] = None) -> Future:
        req = SweepRequest(int(prefix_handle), tuple(ext_handles),
                           shard=self.shard,
                           segments=(tuple(segments)
                                     if segments is not None else None))
        with self._cv:
            if self._stop:
                raise RuntimeError("dispatcher is stopped")
            self._pending.append(req)
            self._cv.notify_all()
        return req.future

    def sweep(self, prefix_handle: int,
              ext_handles: Sequence[int],
              segments: Optional[Sequence[int]] = None) -> np.ndarray:
        """Blocking convenience: enqueue and wait for the counts.
        ``segments`` restricts the join to a segment subset (a
        streaming delta sweep)."""
        return self.submit(prefix_handle, ext_handles,
                           segments=segments).result()

    @property
    def batch_occupancy(self) -> float:
        return self.requests / self.flushes if self.flushes else 0.0

    def stats(self) -> Dict[str, float]:
        """This dispatcher's gauges — the per-device rows of
        ``MiningMetrics.per_device`` (arena-global h2d/d2d gauges live
        on the arena, not here)."""
        return {"device": self.shard, "flushes": self.flushes,
                "sweep_requests": self.requests,
                "batch_occupancy": self.batch_occupancy}

    # -------------------------------------------------------------- loop --
    def _loop(self):
        full = min(self.max_batch, self.n_clients)
        while True:
            with self._cv:
                while not self._pending and not self._stop:
                    self._cv.wait()
                if not self._pending and self._stop:
                    return
                if len(self._pending) < full and not self._stop:
                    deadline = time.monotonic() + self.flush_s
                    while len(self._pending) < full and not self._stop:
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._cv.wait(timeout=left)
                batch = self._pending[:self.max_batch]
                del self._pending[:self.max_batch]
            self.flushes += 1
            self.requests += len(batch)
            try:
                results = self.backend.sweep_many(self.arena, batch)
            except BaseException as e:  # noqa: BLE001 - resolve futures:
                for r in batch:         # a swallowed error would deadlock
                    r.future.set_exception(e)   # every blocked worker
            else:
                for r, counts in zip(batch, results):
                    r.future.set_result(counts)

    def stop(self):
        """Drain pending requests, then join the dispatcher thread."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=10)
        with self._cv:                  # only non-empty if the thread died
            leftover, self._pending = self._pending, []
        for r in leftover:              # pragma: no cover - crash path
            r.future.set_exception(RuntimeError("dispatcher stopped"))
