"""Batched join backends + the sweep dispatcher.

A *bucket sweep* is the paper's per-task TID join restructured at bucket
granularity: one (k-1)-prefix bitmap against the bucket's E extension
bitmaps, producing E support counts in one vectorized call. The old
design gave every scheduler worker its own single-prefix ``sweep`` call
and serialized all JAX dispatch behind a module-global lock, so the
"TPU fast path" was transfer-bound (every sweep re-uploaded its
extension bitmaps host→device) and single-dispatch.

This layer inverts that around two pieces:

  ``BitmapArena`` (repro.core.tidlist)  every bitmap lives in one
      refcounted, append-only row store with integer handles; the
      device mirror is synced incrementally, so repeated sweeps cost
      ~one initial upload instead of one upload per sweep.
  ``SweepDispatcher``  workers enqueue handle-based ``SweepRequest``s
      and block on a future; one dedicated dispatcher thread coalesces
      pending requests into a padded batch and launches ONE
      multi-prefix ``bitmap_join_many`` kernel for all of them. Only
      the dispatcher thread ever touches JAX — no lock exists at all.

Backends implement the same batched API:

  numpy             per-request ``tidlist.support_counts`` over
                    zero-copy arena row views — GIL-released ufunc
                    passes, the CPU tier-1 path. It runs through the
                    identical dispatcher/batching code as the kernels.
  pallas-interpret  ``bitmap_join_many`` under the Pallas interpreter —
                    bit-exact with the TPU kernel, runnable anywhere.
  pallas-jit        the compiled kernel — TPU only; each request's
                    prefix tile stays VMEM-resident across its
                    extension sweep while B requests share the launch.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.tidlist import BitmapArena
from repro.obs import schema as obs_schema

# Dispatcher defaults: how many requests one kernel launch may carry,
# and how long (µs) the dispatcher waits for stragglers to coalesce
# before flushing a partial batch.
MAX_BATCH = 32
FLUSH_US = 200.0
# Straggler cap once a QUERY-class (priority) request is pending: a
# serving query still coalesces into whatever flush is forming, but
# it will not sit out the full mining straggler window — the p99 a
# lone query pays is bounded by this, not FLUSH_US.
QUERY_FLUSH_US = 50.0


@dataclass
class SweepRequest:
    """One bucket sweep, by handle: counts[i] = |row(prefix) ∧ row(ext_i)|.

    ``prefix_handle`` is either one arena handle (a cached/materialized
    prefix bitmap) or a TUPLE of handles whose rows are AND-reduced per
    segment inside the backend — the streaming delta path sweeps
    base-item tuples this way, so a 2-word delta sweep never pays a
    full-width prefix intersection build just to read 2 words of it.

    ``shard`` is the device shard the request executes on — stamped by
    the (per-device) dispatcher that accepted it, so backends know
    which arena mirror to gather from. ``segments`` restricts the join
    to a subset of the arena's transaction segments (None = all): the
    streaming engine's support-delta sweeps read ONLY the freshly
    ingested segments, so a small ingest costs a small sweep.

    Hybrid representation: when ``prefix_handle`` is a SPARSE arena row
    (tid-list or diffset), the backend runs the gather-intersect path
    instead of AND+popcount and the counts are ``|payload ∩ ext_i|``
    over the raw sparse payload — for a tid-list that IS the support,
    for a diffset it is the subtrahend the engine turns into
    ``parent_support - count``. One flush may mix representations; the
    backend partitions per launch. Tuple prefixes are always dense
    (streaming sweeps AND base item rows).

    ``priority`` marks a QUERY-class request (the serving layer's
    unknown-itemset sweeps): it jumps to the front of the pending
    queue (guaranteed into the next flush) and caps the dispatcher's
    straggler wait at ``QUERY_FLUSH_US`` — queries coalesce with
    candidate sweeps but never wait out the full mining window.

    ``desc`` is the request's portable descriptor for multi-host runs:
    the prefix as base ITEM ids, meaningful on any host's arena slice.
    Arena handles are host-local (a cached prefix row exists only on
    the host that built it), so cluster mode's cross-host reduction
    re-evaluates the flush from descriptors — call sites sweeping a
    derived handle must pass the prefix itemset here. Tuple prefixes
    and base-row handles self-describe; single-host runs ignore it."""
    prefix_handle: "int | Tuple[int, ...]"
    ext_handles: Tuple[int, ...]
    shard: int = 0
    segments: Optional[Tuple[int, ...]] = None
    priority: bool = False
    desc: Optional[Tuple[int, ...]] = None
    future: Future = field(default_factory=Future)

    @property
    def prefix_handles(self) -> Tuple[int, ...]:
        p = self.prefix_handle
        return p if isinstance(p, tuple) else (p,)

    def segment_ids(self, arena: BitmapArena) -> Tuple[int, ...]:
        if self.segments is not None:
            return self.segments
        return tuple(range(arena.n_segments))

    def is_sparse(self, arena: BitmapArena) -> bool:
        """True when the prefix row is a tid-list/diffset (gather-
        intersect path); tuple prefixes AND base rows, always dense."""
        p = self.prefix_handle
        return (not isinstance(p, tuple)
                and arena.rep_of(p) != tidlist.REP_BITMAP)


class JoinBackend:
    """Batched executor: ``sweep_many(arena, requests)`` returns one
    int64 counts array per request (ragged — each sized to the
    request's own extension count)."""

    name: str = "base"
    # True when ``sweep_many`` is safe to call from ANY thread (pure
    # host compute against the arena's locked bookkeeping). Kernel
    # backends stay False: only the dispatcher thread may touch JAX.
    host_parallel: bool = False

    def sweep_many(self, arena: BitmapArena,
                   requests: Sequence[SweepRequest]) -> List[np.ndarray]:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<JoinBackend {self.name}>"


class NumpyBackend(JoinBackend):
    """Zero-copy arena row views into the fused AND+popcount ufunc
    pass, batched: a flush's requests are grouped per segment and
    binned by padded shape, then each bin executes as a handful of
    wide numpy passes (index gather → AND-reduce → fused popcount)
    instead of ~10 tiny numpy calls per request. On the streaming
    delta path the per-request work is a 2-word AND — Python call
    overhead dwarfed the arithmetic until the batch was vectorized.
    Runs through the same dispatcher path as the kernels so CPU
    tier-1 tests exercise the identical request/batch/flush
    machinery. In sharded mode the batch's row accesses are booked
    against the requests' shard first (cross-shard reads land in the
    arena's ``d2d_bytes`` gauge)."""

    name = "numpy"
    host_parallel = True
    # bound on a bin pass's [B, E, W] AND temporary (slices B)
    PASS_BYTES = 4 << 20

    def sweep_many(self, arena, requests):
        if arena.n_shards > 1:
            # booked per request: batches are shard-homogeneous today
            # (each dispatcher stamps its own shard), but a mixed batch
            # must not misattribute traffic to requests[0]'s shard —
            # and a delta sweep bills only the segments it reads
            for r in requests:
                arena.note_access(r.shard,
                                  (*r.prefix_handles, *r.ext_handles),
                                  segments=r.segments)
        totals: List[Optional[np.ndarray]] = [None] * len(requests)
        by_seg: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            if r.is_sparse(arena):
                # gather-intersect path: O(S) per ext, never W — the
                # request loops its own segments internally.  Kept
                # scalar deliberately: a flat cross-request gather
                # (repeat/tile + reduceat) was measured ~2x SLOWER than
                # per-request np.ix_ outer indexing at class shapes.
                totals[i] = self._sweep_sparse(arena, r)
                continue
            for g in r.segment_ids(arena):
                if arena.seg_words(g):   # skip zero-width (empty batch)
                    by_seg.setdefault(g, []).append(i)
        for g, idxs in sorted(by_seg.items()):
            rows = arena.seg_view(g)
            if len(idxs) == 1:
                i = idxs[0]
                c = self._sweep_one(rows, requests[i])
                totals[i] = c if totals[i] is None else totals[i] + c
                continue
            # bin by padded (L, E) so one fancy-index gather serves the
            # whole bin without per-request ragged handling
            bins: Dict[Tuple[int, int], List[int]] = {}
            for i in idxs:
                r = requests[i]
                key = (_pow2(len(r.prefix_handles)),
                       _pow2(len(r.ext_handles)))
                bins.setdefault(key, []).append(i)
            for (lp, ep), bi in sorted(bins.items()):
                counts = self._sweep_bin(
                    rows, [requests[i] for i in bi], lp, ep)
                for j, i in enumerate(bi):
                    c = counts[j, :len(requests[i].ext_handles)]
                    totals[i] = (c if totals[i] is None
                                 else totals[i] + c)
        return [t if t is not None
                else np.zeros(len(r.ext_handles), np.int64)
                for t, r in zip(totals, requests)]

    @staticmethod
    def sweep_sparse_bits(arena, r):
        """Sparse sweep that also returns the gathered bit matrix.

        A depth-first class task needs |payload ∩ e| to COUNT and
        payload ∩ e to CARVE child rows — both fall out of one [E, S]
        gather. The host-parallel path returns ``(counts, bits)`` so
        the engine never re-gathers what the count pass already read
        (the device kernel returns counts only; the engine falls back
        to a batched carve gather there). ``bits`` columns align with
        the request's sorted payload; full sweeps only."""
        tids = arena.tids_of(r.prefix_handle)
        n_ext, n_tid = len(r.ext_handles), len(tids)
        bits = np.zeros((n_ext, n_tid), bool)
        if not n_ext or not n_tid:
            return np.zeros(n_ext, np.int64), bits
        eh = list(r.ext_handles)
        for g in r.segment_ids(arena):
            if not arena.seg_words(g):
                continue
            lo, hi = arena.seg_tid_range(g)
            i0, i1 = np.searchsorted(tids, [lo, hi])
            if i0 == i1:
                continue
            t = tids[i0:i1].astype(np.int64) - lo
            w = arena.seg_view(g)[np.ix_(eh, t >> 5)]
            bits[:, i0:i1] = (w >> (t & 31).astype(np.uint32)[None, :]
                              ) & np.uint32(1)
        return bits.sum(axis=1, dtype=np.int64), bits

    @staticmethod
    def _sweep_sparse(arena, r):
        """Sparse-prefix sweep: for each extension, gather the ext word
        at every prefix tid and test one bit — ``np.ix_`` outer-indexes
        the segment store directly into an [E, S] word block, so no
        [E, W] dense gather copy is ever built. Segment-restricted
        (delta) sweeps searchsorted the sorted tid payload down to the
        swept segments' global tid windows."""
        out = np.zeros(len(r.ext_handles), np.int64)
        tids = arena.tids_of(r.prefix_handle)
        if not len(tids) or not len(r.ext_handles):
            return out
        eh = list(r.ext_handles)
        for g in r.segment_ids(arena):
            if not arena.seg_words(g):
                continue
            lo, hi = arena.seg_tid_range(g)
            i0, i1 = np.searchsorted(tids, [lo, hi])
            if i0 == i1:
                continue
            t = (tids[i0:i1].astype(np.int64) - lo)
            wi = t >> 5
            bp = (t & 31).astype(np.uint32)
            words = arena.seg_view(g)[np.ix_(eh, wi)]       # [E, S]
            out += ((words >> bp[None, :]) & np.uint32(1)
                    ).sum(axis=1, dtype=np.int64)
        return out

    @staticmethod
    def _sweep_one(rows, r):
        """Single-request path: no padding copies, and
        ``support_counts`` chunks its own [E, W] temporary — the right
        shape for one wide full sweep."""
        ph = r.prefix_handles
        prefix = rows[ph[0]]
        for h in ph[1:]:              # tuple prefix: AND per segment
            prefix = prefix & rows[h]
        return tidlist.support_counts(
            prefix, rows[list(r.ext_handles)])

    def _sweep_bin(self, rows, reqs, lp, ep):
        """[B, E]-batched sweep over one segment: prefix tuples pad by
        repeating their first handle (AND-idempotent), extension pads
        gather row 0 and are sliced off by the caller."""
        b = len(reqs)
        w = rows.shape[1]
        pidx = np.zeros((b, lp), np.int64)
        eidx = np.zeros((b, ep), np.int64)
        for i, r in enumerate(reqs):
            ph = r.prefix_handles
            pidx[i] = ph + (ph[0],) * (lp - len(ph))
            eidx[i, :len(r.ext_handles)] = r.ext_handles
        pr = rows[pidx.ravel()].reshape(b, lp, w)
        prefix = pr[:, 0]
        for j in range(1, lp):
            prefix = prefix & pr[:, j]
        out = np.empty((b, ep), np.int64)
        step = max(1, self.PASS_BYTES // max(ep * w * 4, 1))
        for lo in range(0, b, step):
            hi = min(lo + step, b)
            ex = rows[eidx[lo:hi].ravel()].reshape(hi - lo, ep, w)
            out[lo:hi] = tidlist.popcount32(
                ex & prefix[lo:hi, None, :]).sum(axis=2)
        return out


def _pow2(n: int, lo: int = 1) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


# E-padding floor = the batched kernel's E tile (kernel.EB_TILE, not
# imported to keep jax out of this module's import path): any narrower
# pad would be re-padded to one tile inside the kernel anyway, so
# distinct sub-tile shapes would only multiply jit compilations.
E_PAD_FLOOR = 64


class _PallasBackend(JoinBackend):
    """Shared plumbing for the kernel modes: pad the ragged batch to
    [B', E', W], gather rows (on device when the arena has a mirror,
    host-side otherwise), launch one ``bitmap_join_many`` per
    transaction segment the batch touches, slice each request's counts
    back out and sum them across segments. B and E pad to powers of
    two so the jit cache stays bounded (~log × log shapes per run);
    single-segment arenas (every non-streaming run) keep the one-launch
    behaviour."""

    mode = "pallas-interpret"

    def sweep_many(self, arena, requests):
        totals = [np.zeros(len(r.ext_handles), np.int64)
                  for r in requests]
        # sub-batch per segment: full sweeps touch every segment, delta
        # sweeps only the fresh ones — a mixed batch still coalesces
        # per segment
        by_seg: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            for g in r.segment_ids(arena):
                if arena.seg_words(g):
                    by_seg.setdefault(g, []).append(i)
        for g, idxs in sorted(by_seg.items()):
            # one flush may mix representations: dense requests go to
            # bitmap_join_many, sparse ones to gather_intersect_many —
            # two launches per (segment, mixed batch) at most
            dense = [i for i in idxs if not requests[i].is_sparse(arena)]
            sparse = [i for i in idxs if requests[i].is_sparse(arena)]
            for part, fn in ((dense, self._sweep_segment),
                             (sparse, self._sweep_segment_sparse)):
                if not part:
                    continue
                counts = fn(arena, g, [requests[i] for i in part])
                for j, i in enumerate(part):
                    totals[i] += counts[j, :len(requests[i].ext_handles)
                                        ].astype(np.int64)
        return totals

    def _sweep_segment(self, arena, seg, requests):
        import jax.numpy as jnp

        from repro.kernels.bitmap_join.ops import bitmap_join_many
        b = len(requests)
        emax = max(len(r.ext_handles) for r in requests)
        lmax = max(len(r.prefix_handles) for r in requests)
        bp = _pow2(b)
        ep = _pow2(emax, lo=E_PAD_FLOOR)
        lp = _pow2(lmax)
        w = arena.seg_words(seg)
        # pad W to a pow2 too: delta sweeps see one fresh W per ingest,
        # and without the pad every (segment width, shape) pair mints a
        # new jit cache entry — recompile stalls that grow with ingest
        # count. Zero pad words AND to zero and add no popcount.
        wp = _pow2(w)
        pidx = np.zeros((bp, lp), np.int32)
        eidx = np.zeros((bp, ep), np.int32)
        mask = np.zeros((bp, ep), bool)
        for i, r in enumerate(requests):
            ph = r.prefix_handles
            # pad the prefix tuple by repeating its first handle —
            # AND-idempotent, so no mask dimension is needed
            pidx[i] = (ph + (ph[0],) * (lp - len(ph)))
            n = len(r.ext_handles)
            eidx[i, :n] = r.ext_handles
            mask[i, :n] = True
        shard = requests[0].shard if requests else 0
        needed = None
        if arena.n_shards > 1:
            needed = [h for r in requests
                      for h in (*r.prefix_handles, *r.ext_handles)]
        dev = arena.device_rows(shard, needed=needed, segment=seg)
        if dev is not None:
            # arena-gather path: bitmaps are already device-resident,
            # only the (tiny) index arrays cross host→device
            if wp != w:
                dev = jnp.pad(dev, ((0, 0), (0, wp - w)))
            pr = dev[jnp.asarray(pidx.reshape(-1))].reshape(bp, lp, wp)
            exts = dev[jnp.asarray(eidx.reshape(-1))].reshape(bp, ep, wp)
        else:
            # host-gather baseline (arena backing "numpy"): the old
            # transfer-bound behaviour — every batch re-uploads its
            # bitmap payload, and the gauge records it (pad words are
            # synthetic zeros, not billed)
            rows = arena.seg_view(seg)
            ph = rows[pidx.reshape(-1)].reshape(bp, lp, w)
            eh = rows[eidx.reshape(-1)].reshape(bp, ep, w)
            arena.count_h2d(ph[:, 0].nbytes + eh.nbytes)
            if wp != w:
                ph = np.pad(ph, ((0, 0), (0, 0), (0, wp - w)))
                eh = np.pad(eh, ((0, 0), (0, 0), (0, wp - w)))
            pr = jnp.asarray(ph)
            exts = jnp.asarray(eh)
        prefixes = pr[:, 0, :]
        for j in range(1, lp):        # tuple prefix: AND-reduce on device
            prefixes = prefixes & pr[:, j, :]
        return np.asarray(bitmap_join_many(prefixes, exts,
                                           jnp.asarray(mask),
                                           mode=self.mode))

    def _sweep_segment_sparse(self, arena, seg, requests):
        """Sparse sub-batch: prefixes are tid/diffset payloads, shipped
        host→device per launch (billed at actual nbytes — sparse rows
        have no resident mirror payload); extension word-columns gather
        from the mirror exactly like the dense path. Tids are
        searchsorted down to this segment's global tid window and
        rebased, then padded to a pow2 S with the -1 sentinel so the
        jit cache stays bounded."""
        import jax.numpy as jnp

        from repro.kernels.gather_intersect.ops import (
            gather_intersect_many)
        b = len(requests)
        emax = max(len(r.ext_handles) for r in requests)
        bp = _pow2(b)
        ep = _pow2(emax, lo=E_PAD_FLOOR)
        w = arena.seg_words(seg)
        wp = _pow2(w)
        lo, hi = arena.seg_tid_range(seg)
        local: List[np.ndarray] = []
        smax = 1
        for r in requests:
            tids = arena.tids_of(r.prefix_handle)
            i0, i1 = np.searchsorted(tids, [lo, hi])
            t = (tids[i0:i1].astype(np.int64) - lo).astype(np.int32)
            local.append(t)
            smax = max(smax, len(t))
        sp = _pow2(smax, lo=E_PAD_FLOOR)
        tmat = np.full((bp, sp), -1, np.int32)
        for i, t in enumerate(local):
            tmat[i, :len(t)] = t
        eidx = np.zeros((bp, ep), np.int32)
        mask = np.zeros((bp, ep), bool)
        for i, r in enumerate(requests):
            n = len(r.ext_handles)
            eidx[i, :n] = r.ext_handles
            mask[i, :n] = True
        shard = requests[0].shard if requests else 0
        needed = None
        if arena.n_shards > 1:
            needed = [h for r in requests
                      for h in (*r.prefix_handles, *r.ext_handles)]
        dev = arena.device_rows(shard, needed=needed, segment=seg)
        if dev is not None:
            if wp != w:
                dev = jnp.pad(dev, ((0, 0), (0, wp - w)))
            exts = dev[jnp.asarray(eidx.reshape(-1))].reshape(bp, ep, wp)
            arena.count_h2d(tmat.nbytes)      # tid payload, per launch
        else:
            rows = arena.seg_view(seg)
            eh = rows[eidx.reshape(-1)].reshape(bp, ep, w)
            arena.count_h2d(eh.nbytes + tmat.nbytes)
            if wp != w:
                eh = np.pad(eh, ((0, 0), (0, 0), (0, wp - w)))
            exts = jnp.asarray(eh)
        return np.asarray(gather_intersect_many(jnp.asarray(tmat), exts,
                                                jnp.asarray(mask),
                                                mode=self.mode))


class PallasInterpretBackend(_PallasBackend):
    name = "pallas-interpret"
    mode = "pallas-interpret"


class PallasJitBackend(_PallasBackend):
    name = "pallas-jit"
    mode = "pallas-jit"


_REGISTRY: Dict[str, Callable[[], JoinBackend]] = {
    "numpy": NumpyBackend,
    "pallas-interpret": PallasInterpretBackend,
    "pallas-jit": PallasJitBackend,
}
_instances: Dict[str, JoinBackend] = {}


def get_backend(name: str) -> JoinBackend:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown join backend {name!r}; known: {sorted(_REGISTRY)}")
    b = _instances.get(name)
    if b is None:
        b = _instances[name] = _REGISTRY[name]()
    return b


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present here
        return False


def available_backends() -> List[str]:
    """Backends that can execute on this host. The compiled Pallas
    kernel only lowers on TPU; the interpreter runs anywhere."""
    names = ["numpy", "pallas-interpret"]
    if _on_tpu():
        names.append("pallas-jit")
    return names


def resolve_backend(spec: str = "auto") -> JoinBackend:
    """One backend per run (batching replaced the per-bucket choice:
    narrow buckets now amortize a launch by sharing it, so there is no
    tiny-bucket penalty to route around). "auto" is the compiled
    kernel on TPU and numpy on CPU — the interpreter is a correctness
    tool, not a fast path."""
    if spec == "auto":
        return get_backend("pallas-jit" if _on_tpu() else "numpy")
    avail = available_backends()
    if spec not in avail:
        # fail fast: an unavailable backend must error here, not
        # inside a scheduler worker thread mid-mine
        get_backend(spec)                     # unknown name -> ValueError
        raise ValueError(
            f"join backend {spec!r} is not available on this host "
            f"(available: {avail})")
    return get_backend(spec)


class SweepDispatcher:
    """Coalesces many workers' sweep requests into batched launches.

    In mesh runs there is ONE dispatcher per device shard: workers
    submit to the dispatcher matching their device affinity, requests
    are stamped with that shard, and each dispatcher flushes
    ``bitmap_join_many`` against its own arena mirror — per-device
    batching, per-device occupancy gauges.

    Workers call :meth:`sweep` (or :meth:`submit` + ``future.result()``)
    and block; the dedicated dispatcher thread gathers pending requests
    and flushes a batch when either

      * ``min(max_batch, n_clients)`` requests are pending — since
        ``sweep`` blocks its caller, pending requests count currently
        blocked clients, so once every client is waiting no further
        request can arrive and waiting longer is pure latency; or
      * ``flush_us`` elapsed since the flush started forming — bounding
        the latency a lone straggler pays when other workers are busy
        with non-sweep work.

    Errors from the backend resolve every future in the flight batch,
    so task bodies re-raise through the scheduler's normal task-error
    machinery. ``batch_occupancy`` (requests per flush) is the gauge
    that shows whether batching actually happened — the granularity
    benchmark asserts it stays above 1 so the dispatcher cannot
    silently degrade to one-bucket launches.
    """

    def __init__(self, arena: BitmapArena, backend: JoinBackend,
                 n_clients: int, max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, shard: int = 0,
                 query_flush_us: float = QUERY_FLUSH_US, cluster=None,
                 tracer=None, trace_pid: int = 0):
        self.arena = arena
        self.backend = backend
        # observability: None = off; spans record flush formation on
        # the dispatcher lane and blocking sweeps on the caller's lane
        self.tracer = tracer
        self.trace_pid = trace_pid
        self.n_clients = max(1, n_clients)
        self.max_batch = max(1, max_batch)
        self.flush_s = max(0.0, flush_us) * 1e-6
        self.query_flush_s = max(0.0, query_flush_us) * 1e-6
        self.shard = shard
        # multi-host context: when set, every flush is two-phase —
        # local partial counts over this arena's owned words, then
        # cluster.reduce_flush sums the peers' partials for the same
        # descriptors. One reduction per flush, so the collective
        # amortizes exactly like the dispatcher amortizes launches.
        self.cluster = cluster
        self.sweep_s = 0.0            # local backend busy time (s)
        self._pending: List[SweepRequest] = []
        self._n_priority = 0          # priority requests in _pending
        self._cv = threading.Condition()
        self._stop = False
        self.flushes = 0
        self.requests = 0
        self.query_requests = 0       # priority (serving) requests seen
        # dispatcher-THREAD flushes only (excludes sweep_local /
        # sweep_bits inline bursts, which bill themselves as flushes):
        # the coalescing gauge the query-storm benchmark compares,
        # since inline bursts never mix with anything by construction
        self.queue_flushes = 0
        self.queue_requests = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"sweep-dispatcher-{shard}")
        self._thread.start()

    # ------------------------------------------------------------ client --
    def submit(self, prefix_handle: int,
               ext_handles: Sequence[int],
               segments: Optional[Sequence[int]] = None,
               priority: bool = False,
               desc: Optional[Tuple[int, ...]] = None) -> Future:
        p = (tuple(int(h) for h in prefix_handle)
             if isinstance(prefix_handle, tuple) else int(prefix_handle))
        req = SweepRequest(p, tuple(ext_handles),
                           shard=self.shard,
                           segments=(tuple(segments)
                                     if segments is not None else None),
                           priority=priority, desc=desc)
        with self._cv:
            if self._stop:
                raise RuntimeError("dispatcher is stopped")
            if priority:
                self._pending.insert(0, req)
                self._n_priority += 1
                self.query_requests += 1
            else:
                self._pending.append(req)
            self._cv.notify_all()
        return req.future

    def _make_requests(self, sweeps: Sequence[Tuple],
                       segments: Optional[Sequence[int]],
                       priority: bool = False
                       ) -> List[SweepRequest]:
        segs = tuple(segments) if segments is not None else None
        return [SweepRequest(
                    (tuple(int(h) for h in p) if isinstance(p, tuple)
                     else int(p)),
                    tuple(e), shard=self.shard, segments=segs,
                    priority=priority)
                for p, e in sweeps]

    def submit_many(self, sweeps: Sequence[Tuple],
                    segments: Optional[Sequence[int]] = None,
                    priority: bool = False) -> List[Future]:
        """Enqueue a burst of ``(prefix, ext_handles)`` sweeps under one
        lock acquisition / one wakeup — the streaming delta path's
        coalescing entry point (per-candidate ``submit`` calls would
        trickle in and flush at occupancy ~1). ``prefix`` may be a
        handle or a tuple of handles (AND-reduced in the backend).
        ``priority=True`` marks the burst as query-class: it goes to
        the FRONT of the pending queue (order preserved within the
        burst) and shortens the straggler wait to ``query_flush_us``."""
        reqs = self._make_requests(sweeps, segments, priority)
        with self._cv:
            if self._stop:
                raise RuntimeError("dispatcher is stopped")
            if priority:
                self._pending[:0] = reqs
                self._n_priority += len(reqs)
                self.query_requests += len(reqs)
            else:
                self._pending.extend(reqs)
            self._cv.notify_all()
        return [r.future for r in reqs]

    def sweep_local(self, sweeps: Sequence[Tuple],
                    segments: Optional[Sequence[int]] = None
                    ) -> List[np.ndarray]:
        """Execute a burst of ``(prefix, ext_handles)`` sweeps and
        return counts arrays aligned with ``sweeps``.

        When the backend is ``host_parallel`` (numpy) the burst runs
        synchronously on the CALLING thread — its ufunc passes release
        the GIL, so N worker threads executing their own bursts truly
        parallelize instead of serializing behind the one dispatcher
        thread (the delta path's wall-clock regression in a nutshell).
        Kernel backends fall back to ``submit_many`` so only the
        dispatcher thread ever touches JAX, and the burst still
        coalesces into wide launches there. Either way the burst bills
        the occupancy gauges as one flush of ``len(sweeps)`` requests.
        """
        if not sweeps:
            return []
        if not self.backend.host_parallel:
            return [f.result()
                    for f in self.submit_many(sweeps, segments=segments)]
        reqs = self._make_requests(sweeps, segments)
        with self._cv:
            if self._stop:
                raise RuntimeError("dispatcher is stopped")
            self.flushes += 1
            self.requests += len(reqs)
        t0 = time.perf_counter()
        results = self.backend.sweep_many(self.arena, reqs)
        with self._cv:
            self.sweep_s += time.perf_counter() - t0
        if self.cluster is not None:
            results = self.cluster.reduce_flush(reqs, results)
        tr = self.tracer
        if tr is not None:
            # inline burst: the flush span lands on the CALLING
            # worker's lane (that is where the time went)
            tr.span("flush", t0, cat="flush",
                    args=self._flush_args(reqs, inline=True))
        return results

    def sweep(self, prefix_handle: int,
              ext_handles: Sequence[int],
              segments: Optional[Sequence[int]] = None,
              desc: Optional[Tuple[int, ...]] = None) -> np.ndarray:
        """Blocking convenience: enqueue and wait for the counts.
        ``segments`` restricts the join to a segment subset (a
        streaming delta sweep)."""
        tr = self.tracer
        if tr is None:
            return self.submit(prefix_handle, ext_handles,
                               segments=segments, desc=desc).result()
        t0 = tr.now()
        counts = self.submit(prefix_handle, ext_handles,
                             segments=segments, desc=desc).result()
        # caller-side wait: nests inside the worker's task span
        tr.span("sweep", t0, cat="sweep",
                args={"ext": len(ext_handles)})
        return counts

    def sweep_bits(self, prefix_handle: int, ext_handles: Sequence[int],
                   desc: Optional[Tuple[int, ...]] = None
                   ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Depth-first class sweep: ``(counts, bits)`` where ``bits``
        is the [E, S] payload∩ext matrix of the SAME gather the counts
        came from (sparse prefixes on host-parallel backends; None
        otherwise).

        Host-parallel backends run inline on the CALLING thread — the
        ``sweep_local`` rationale applied to class tasks: a class
        sweep is one vectorized pass, so the enqueue → dispatcher
        wakeup → future round-trip costs more than the sweep itself
        (two context switches per class on a busy machine), and for a
        sparse prefix returning the bit matrix lets the class task
        carve children without re-gathering. Kernel backends keep the
        batched queue (only the dispatcher thread touches JAX) and
        return no bits. Billed as a 1-request flush so
        ``flushes × occupancy == requests`` stays exact."""
        if not self.backend.host_parallel:
            return self.sweep(prefix_handle, ext_handles,
                              desc=desc), None
        req = self._make_requests(
            [(prefix_handle, tuple(ext_handles))], None)[0]
        req.desc = desc
        with self._cv:
            if self._stop:
                raise RuntimeError("dispatcher is stopped")
            self.flushes += 1
            self.requests += 1
        tr = self.tracer
        if req.is_sparse(self.arena) and getattr(
                self.backend, "sweep_sparse_bits", None) is not None:
            if self.arena.n_shards > 1:
                self.arena.note_access(req.shard, (*req.prefix_handles,
                                                   *req.ext_handles))
            t0 = time.perf_counter()
            out = self.backend.sweep_sparse_bits(self.arena, req)
            if tr is not None:
                tr.span("sweep", t0, cat="sweep",
                        args={"ext": len(req.ext_handles),
                              "sparse": True})
            return out
        t0 = time.perf_counter()
        counts = self.backend.sweep_many(self.arena, [req])[0]
        with self._cv:
            self.sweep_s += time.perf_counter() - t0
        if self.cluster is not None:
            counts = self.cluster.reduce_flush([req], [counts])[0]
        if tr is not None:
            tr.span("sweep", t0, cat="sweep",
                    args={"ext": len(req.ext_handles), "sparse": False})
        return counts, None

    @property
    def batch_occupancy(self) -> float:
        return self.requests / self.flushes if self.flushes else 0.0

    def stats(self) -> Dict[str, float]:
        """This dispatcher's gauges — the per-device rows of
        ``MiningMetrics.per_device``, on the ``repro.obs.schema``
        device schema (arena-global h2d/d2d gauges live on the arena,
        not here)."""
        return obs_schema.device_stats(
            {"device": self.shard, "flushes": self.flushes,
             "sweep_requests": self.requests,
             "query_requests": self.query_requests,
             "queue_flushes": self.queue_flushes,
             "queue_requests": self.queue_requests,
             "sweep_s": self.sweep_s})

    def _flush_args(self, batch: Sequence[SweepRequest],
                    inline: bool = False) -> Dict[str, float]:
        """Span payload for one flush: occupancy, an upper-bound byte
        figure (rows × full arena width — segment-restricted sweeps
        read less), and the dense/sparse representation split. Only
        runs when a tracer is attached."""
        arena = self.arena
        rows = sum(len(r.prefix_handles) + len(r.ext_handles)
                   for r in batch)
        sparse = sum(1 for r in batch if r.is_sparse(arena))
        return {"requests": len(batch), "occupancy": len(batch),
                "rows": rows, "batch_bytes": rows * arena.n_words * 4,
                "sparse": sparse, "dense": len(batch) - sparse,
                "queries": sum(1 for r in batch if r.priority),
                "inline": inline}

    # -------------------------------------------------------------- loop --
    def _loop(self):
        tr = self.tracer
        if tr is not None:
            tr.set_lane(f"dispatcher-{self.shard}",
                        sort_index=1000 + self.shard,
                        pid=self.trace_pid)
        full = min(self.max_batch, self.n_clients)
        while True:
            with self._cv:
                while not self._pending and not self._stop:
                    self._cv.wait()
                if not self._pending and self._stop:
                    return
                if len(self._pending) < full and not self._stop:
                    deadline = time.monotonic() + self.flush_s
                    while len(self._pending) < full and not self._stop:
                        # a pending query caps the straggler wait: the
                        # cap re-applies on every pass so a query that
                        # ARRIVES mid-wait also shortens the window
                        if self._n_priority:
                            deadline = min(
                                deadline,
                                time.monotonic() + self.query_flush_s)
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._cv.wait(timeout=left)
                batch = self._pending[:self.max_batch]
                del self._pending[:self.max_batch]
                self._n_priority -= sum(1 for r in batch if r.priority)
                self.flushes += 1       # gauges share the cv lock with
                self.requests += len(batch)   # sweep_local's local bursts
                self.queue_flushes += 1
                self.queue_requests += len(batch)
            try:
                t0 = time.perf_counter()
                results = self.backend.sweep_many(self.arena, batch)
                t1 = time.perf_counter()
                with self._cv:
                    self.sweep_s += t1 - t0
                if self.cluster is not None:
                    results = self.cluster.reduce_flush(batch, results)
                    if tr is not None:
                        # the cross-host reduction tail of this flush
                        tr.span("net-flush", t1, cat="net",
                                args={"requests": len(batch)})
                if tr is not None:
                    tr.span("flush", t0, cat="flush",
                            args=self._flush_args(batch))
            except BaseException as e:  # noqa: BLE001 - resolve futures:
                for r in batch:         # a swallowed error would deadlock
                    r.future.set_exception(e)   # every blocked worker
            else:
                for r, counts in zip(batch, results):
                    r.future.set_result(counts)

    def stop(self):
        """Drain pending requests, then join the dispatcher thread."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=10)
        with self._cv:                  # only non-empty if the thread died
            leftover, self._pending = self._pending, []
        for r in leftover:              # pragma: no cover - crash path
            r.future.set_exception(RuntimeError("dispatcher stopped"))
