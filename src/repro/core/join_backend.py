"""Pluggable join backends for the bucket-sweep mining engine.

A *bucket sweep* is the paper's per-task TID join restructured at bucket
granularity: given one (k-1)-prefix bitmap and the bucket's E extension
bitmaps, produce the E support counts in one vectorized call. Three
interchangeable executors:

  numpy             ``tidlist.support_counts`` — one fused AND+popcount
                    ufunc pass, GIL-released, the right choice for the
                    threaded shared-memory scheduler on CPU.
  pallas-interpret  the Pallas ``bitmap_join`` kernel under the Pallas
                    interpreter — bit-exact with the TPU kernel,
                    runnable anywhere (parity tests, debugging).
  pallas-jit        the compiled Pallas kernel — TPU only; keeps the
                    prefix tile VMEM-resident across the extension
                    sweep (the clustered policy's reuse, structural).

``make_selector`` returns the per-bucket choice function the engine
uses: backends are picked by extension count, so tiny buckets skip
kernel-launch overhead while large buckets get the tiled sweep.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import tidlist

# Buckets at least this wide amortize a Pallas kernel launch (one E-tile
# of the kernel's grid); narrower buckets stay on the numpy path.
PALLAS_MIN_EXTS = 256

_jax_lock = threading.Lock()


class JoinBackend:
    """sweep(prefix, exts) -> counts. prefix: [W] uint32; exts: [E, W]
    uint32; counts: [E] int64."""

    name: str = "base"

    def sweep(self, prefix: np.ndarray, exts: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def materialize(self, prefix: np.ndarray, ext: np.ndarray
                    ) -> np.ndarray:
        """prefix ∧ ext as a fresh owned array — the parent→child bitmap
        handoff of the depth-first engine. Computed exactly once per
        frequent child; the child never recomputes or cache-probes its
        prefix intersection. One ufunc pass on every backend (the
        Pallas backends sweep counts on device but materialize child
        bitmaps host-side, where the scheduler hands them off)."""
        return prefix & ext

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<JoinBackend {self.name}>"


class NumpyBackend(JoinBackend):
    name = "numpy"

    def sweep(self, prefix, exts):
        return tidlist.support_counts(prefix, exts)


class _PallasBackend(JoinBackend):
    """Shared plumbing: numpy in, numpy out, jax under a lock (jax
    dispatch is not re-entrant across scheduler worker threads)."""

    mode = "pallas-interpret"

    def sweep(self, prefix, exts):
        import jax.numpy as jnp

        from repro.kernels.bitmap_join.ops import bitmap_join
        with _jax_lock:
            out = bitmap_join(jnp.asarray(prefix), jnp.asarray(exts),
                              mode=self.mode)
            return np.asarray(out).astype(np.int64)


class PallasInterpretBackend(_PallasBackend):
    name = "pallas-interpret"
    mode = "pallas-interpret"


class PallasJitBackend(_PallasBackend):
    name = "pallas-jit"
    mode = "pallas-jit"


_REGISTRY: Dict[str, Callable[[], JoinBackend]] = {
    "numpy": NumpyBackend,
    "pallas-interpret": PallasInterpretBackend,
    "pallas-jit": PallasJitBackend,
}
_instances: Dict[str, JoinBackend] = {}


def get_backend(name: str) -> JoinBackend:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown join backend {name!r}; known: {sorted(_REGISTRY)}")
    b = _instances.get(name)
    if b is None:
        b = _instances[name] = _REGISTRY[name]()
    return b


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present here
        return False


def available_backends() -> List[str]:
    """Backends that can execute on this host. The compiled Pallas
    kernel only lowers on TPU; the interpreter runs anywhere."""
    names = ["numpy", "pallas-interpret"]
    if _on_tpu():
        names.append("pallas-jit")
    return names


Selector = Callable[[int], JoinBackend]


def make_selector(spec: str = "auto",
                  min_pallas_exts: int = PALLAS_MIN_EXTS) -> Selector:
    """Per-bucket backend choice, keyed by extension count.

    ``spec`` is either a backend name (constant choice) or "auto":
    numpy for narrow buckets, the Pallas kernel (compiled on TPU) for
    buckets wide enough to fill a kernel E-tile. On CPU "auto" is
    always numpy — the interpreter is a correctness tool, not a fast
    path.
    """
    if spec != "auto":
        avail = available_backends()
        if spec not in avail:
            # fail fast: an unavailable backend must error here, not
            # inside a scheduler worker thread mid-mine
            get_backend(spec)                 # unknown name -> ValueError
            raise ValueError(
                f"join backend {spec!r} is not available on this host "
                f"(available: {avail})")
        backend = get_backend(spec)
        return lambda n_exts: backend
    small = get_backend("numpy")
    if not _on_tpu():
        return lambda n_exts: small
    big = get_backend("pallas-jit")
    return lambda n_exts: big if n_exts >= min_pallas_exts else small
