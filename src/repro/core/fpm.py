"""Apriori-based FPM on the task scheduler — the paper's application.

Two task granularities (the paper's key knob, cf. "Redesigning pattern
mining algorithms for supercomputers"):

  granularity="candidate"  one task per candidate k-itemset (paper §2).
      The per-task join reuses a per-worker-thread LRU cache of *prefix
      intersections*: tasks that share a (k-1)-prefix hit the cache iff
      they run back-to-back on the same worker — exactly the locality
      the clustered policy creates and the Cilk-style policy destroys.
  granularity="bucket"     one task per (k-1)-prefix bucket (default).
      The task computes the prefix intersection ONCE and sweeps all of
      the bucket's extensions with one vectorized call through a
      pluggable join backend (numpy ufuncs or the Pallas bitmap_join
      kernel — repro.core.join_backend). This turns the clustered
      policy's incidental cache locality into structure: the prefix
      bitmap stays register/VMEM-resident across the whole sweep.

Both granularities return identical supports under every policy. The
cache hit-rate (candidate) and rows-touched/bytes-swept counters (both,
shared with repro.core.distributed_fpm) are this reproduction's
analogue of the paper's dTLB/IPC counters.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.buckets import Bucket, group_by_prefix, rows_to_bytes
from repro.core.itemsets import (Itemset, gen_candidates, prefix_hash)
from repro.core.join_backend import make_selector
from repro.core.scheduler import TaskScheduler, make_policy

GRANULARITIES = ("bucket", "candidate")


@dataclass
class MiningMetrics:
    wall_s: float = 0.0
    levels: int = 0
    candidates: int = 0
    buckets: int = 0
    frequent: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_partial_hits: int = 0
    rows_touched: int = 0        # bitmap rows actually read (measured)
    bytes_swept: int = 0         # rows_touched * W * 4
    scheduler: Dict[str, float] = field(default_factory=dict)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0


class _PrefixCache:
    """LRU of prefix -> intersected bitmap (one instance per worker).

    *Hierarchical*: a miss on ABC first checks AB — if present, only one
    extra AND is needed. With the nearest-neighbour policy (the paper's
    §6 future work) neighbouring buckets share sub-prefixes, so partial
    reuse crosses bucket boundaries.

    ``get`` also returns the number of bitmap rows it read to build the
    intersection (0 on a full hit) — the measured locality traffic."""

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        self.d: "collections.OrderedDict[Itemset, np.ndarray]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.partial_hits = 0

    def _put(self, prefix: Itemset, bm: np.ndarray):
        self.d[prefix] = bm
        if len(self.d) > self.maxsize:
            self.d.popitem(last=False)

    def get(self, prefix: Itemset, bitmaps: np.ndarray
            ) -> Tuple[np.ndarray, int]:
        d = self.d
        if prefix in d:
            d.move_to_end(prefix)
            self.hits += 1
            return d[prefix], 0
        self.misses += 1
        # hierarchical fallback: longest cached ancestor prefix
        for cut in range(len(prefix) - 1, 1, -1):
            parent = prefix[:cut]
            if parent in d:
                d.move_to_end(parent)
                self.partial_hits += 1
                bm = d[parent]
                for item in prefix[cut:]:
                    bm = bm & bitmaps[item]
                self._put(prefix, bm)
                return bm, len(prefix) - cut
        bm = tidlist.intersect(bitmaps[list(prefix)])
        self._put(prefix, bm)
        return bm, len(prefix)


def _raise_task_errors(tasks) -> None:
    """Surface the first task-body exception on the driver thread (the
    scheduler records it instead of letting the worker die, which would
    deadlock wait_all)."""
    for t in tasks:
        if t.error is not None:
            raise t.error


def mine(bitmaps: np.ndarray, min_support: int, *,
         policy: str = "clustered", n_workers: int = 8,
         max_k: int = 8, cache_size: int = 32,
         granularity: str = "bucket", backend: str = "auto",
         ) -> Tuple[Dict[Itemset, int], MiningMetrics]:
    """bitmaps: [n_items, W] uint32 packed TID bitmaps.

    ``granularity`` selects the unit of scheduler task: "bucket" (one
    task per (k-1)-prefix, vectorized extension sweep) or "candidate"
    (one scalar join per candidate — kept for A/B benchmarking).
    ``backend`` names the bucket-sweep executor ("auto", "numpy",
    "pallas-interpret", "pallas-jit"; see repro.core.join_backend).
    """
    if granularity not in GRANULARITIES:
        raise ValueError(
            f"granularity must be one of {GRANULARITIES}, "
            f"got {granularity!r}")
    n_items, n_w = bitmaps.shape
    select = make_selector(backend)
    metrics = MiningMetrics()
    t0 = time.time()

    # level 1: dense count (no tasks — same in both policies)
    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(n_items)
        if supports[i] >= min_support}
    frequent: List[Itemset] = sorted(result)
    metrics.frequent += len(frequent)

    caches: Dict[int, _PrefixCache] = {}        # thread ident -> cache
    lock = threading.Lock()

    def _thread_cache() -> _PrefixCache:
        tid = threading.get_ident()
        c = caches.get(tid)
        if c is None:
            with lock:
                c = caches.setdefault(tid, _PrefixCache(cache_size))
        return c

    def _prefix_bitmap(cache: _PrefixCache, prefix: Itemset
                       ) -> Tuple[np.ndarray, int]:
        if len(prefix) == 1:
            return bitmaps[prefix[0]], 1        # no reuse term at k=2
        return cache.get(prefix, bitmaps)

    def _account(rows: int) -> None:
        st = sched.worker_stats()
        st.rows_touched += rows
        st.bytes_swept += rows_to_bytes(rows, n_w)

    def count_task(cand: Itemset) -> int:
        cache = _thread_cache()
        pbm, prows = _prefix_bitmap(cache, cand[:-1])
        _account(prows + 1)
        return int(tidlist.popcount32(pbm & bitmaps[cand[-1]]).sum())

    def sweep_task(bucket: Bucket) -> np.ndarray:
        """Bucket-granularity body: prefix intersection once, then one
        vectorized sweep over all extensions. Returns [E] counts."""
        cache = _thread_cache()
        pbm, prows = _prefix_bitmap(cache, bucket.prefix)
        _account(prows + len(bucket.exts))
        exts = bitmaps[list(bucket.exts)]
        return select(len(bucket.exts)).sweep(pbm, exts)

    # task attr = (bucket_key, itemset-or-prefix): the key is the
    # paper's XOR'd prefix hash, precomputed once so queue ops stay
    # O(1). The nearest-neighbour policy keys buckets by the prefix
    # tuple itself (it needs item overlap between bucket keys).
    if granularity == "bucket":
        cluster_of = ((lambda a: a[1]) if policy == "nn"
                      else (lambda a: a[0]))
    else:
        cluster_of = ((lambda a: a[1][:-1]) if policy == "nn"
                      else (lambda a: a[0]))
    sched = TaskScheduler(n_workers,
                          make_policy(policy, n_workers, cluster_of))
    try:
        k = 2
        while frequent and k <= max_k:
            cands = gen_candidates(frequent)
            if not cands:
                break
            metrics.levels += 1
            metrics.candidates += len(cands)
            frequent = []
            if granularity == "bucket":
                plan = group_by_prefix(cands)
                metrics.buckets += len(plan)
                tasks = [sched.spawn(sweep_task, b,
                                     attr=(b.key, b.prefix))
                         for b in plan]
                sched.wait_all()
                _raise_task_errors(tasks)
                for b, t in zip(plan, tasks):
                    counts = t.result
                    for e, s in zip(b.exts, counts):
                        if s >= min_support:
                            c = b.prefix + (e,)
                            result[c] = int(s)
                            frequent.append(c)
            else:
                tasks = [sched.spawn(count_task, c,
                                     attr=(prefix_hash(c), c))
                         for c in cands]
                sched.wait_all()
                _raise_task_errors(tasks)
                for c, t in zip(cands, tasks):
                    if t.result >= min_support:
                        result[c] = t.result
                        frequent.append(c)
            frequent.sort()
            metrics.frequent += len(frequent)
            k += 1
    finally:
        sched.shutdown()

    metrics.wall_s = time.time() - t0
    metrics.scheduler = sched.merged_stats()
    metrics.rows_touched = int(metrics.scheduler["rows_touched"])
    metrics.bytes_swept = int(metrics.scheduler["bytes_swept"])
    metrics.cache_hits = sum(c.hits for c in caches.values())
    metrics.cache_misses = sum(c.misses for c in caches.values())
    metrics.cache_partial_hits = sum(c.partial_hits
                                     for c in caches.values())
    return result, metrics


def mine_serial(bitmaps: np.ndarray, min_support: int, max_k: int = 8
                ) -> Dict[Itemset, int]:
    """Single-threaded reference (no scheduler)."""
    n_items = bitmaps.shape[0]
    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(n_items)
        if supports[i] >= min_support}
    frequent = sorted(result)
    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        frequent = []
        for c in cands:
            s = tidlist.support_of(bitmaps[list(c)])
            if s >= min_support:
                result[c] = s
                frequent.append(c)
        frequent.sort()
        k += 1
    return result
