"""Apriori/Eclat FPM on the task scheduler — the paper's application.

Three task granularities (the paper's key knob, cf. "Redesigning pattern
mining algorithms for supercomputers"):

  granularity="candidate"    one task per candidate k-itemset (paper §2).
      The per-task join reuses a per-worker-thread LRU cache of *prefix
      intersections*: tasks that share a (k-1)-prefix hit the cache iff
      they run back-to-back on the same worker — exactly the locality
      the clustered policy creates and the Cilk-style policy destroys.
  granularity="bucket"       one task per (k-1)-prefix bucket (default).
      The task computes the prefix intersection ONCE and sweeps all of
      the bucket's extensions with one vectorized call through a
      pluggable join backend (numpy ufuncs or the Pallas bitmap_join
      kernel — repro.core.join_backend). Level-synchronous: a driver
      barrier separates level k from level k+1.
  granularity="depth-first"  barrier-free equivalence-class recursion.
      Each task owns one class (prefix P, sibling extensions E): it
      sweeps E through the join backend, records the frequent
      extensions, forms the child classes P+(e,) × {siblings > e}
      Eclat-style (no global candidate generation), materializes each
      child's ``prefix ∧ ext`` bitmap exactly once and *hands it to the
      child task* — so no child ever recomputes or cache-probes a
      prefix intersection. Children spawn onto the spawning worker's
      queue (steals move whole subtrees); the deepest class drains
      first, bounding retained handoff bitmaps; one terminal
      ``wait_all`` replaces every inter-level barrier.

All granularities return identical supports under every policy. The
cache hit-rate (candidate), rows-touched/bytes-swept counters (all,
shared with repro.core.distributed_fpm) and peak-retained-bitmap gauge
(depth-first) are this reproduction's analogue of the paper's dTLB/IPC
counters.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.buckets import (Bucket, class_rows_touched, group_by_prefix,
                                rows_to_bytes)
from repro.core.itemsets import (Itemset, gen_candidates, itemset_hash,
                                 prefix_hash)
from repro.core.join_backend import make_selector
from repro.core.scheduler import TaskScheduler, make_policy

GRANULARITIES = ("bucket", "candidate", "depth-first")


@dataclass
class MiningMetrics:
    wall_s: float = 0.0
    levels: int = 0
    candidates: int = 0
    buckets: int = 0
    frequent: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_partial_hits: int = 0
    rows_touched: int = 0        # bitmap rows actually read (measured)
    bytes_swept: int = 0         # rows_touched * W * 4
    # depth-first handoff gauges: how many materialized child bitmaps
    # (and their bytes) were alive at once — the engine's memory bound
    peak_retained_bitmaps: int = 0
    peak_bytes_retained: int = 0
    scheduler: Dict[str, float] = field(default_factory=dict)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0


class _PrefixCache:
    """LRU of prefix -> intersected bitmap (one instance per worker).

    *Hierarchical*: a miss on ABC first checks AB — if present, only one
    extra AND is needed. With the nearest-neighbour policy (the paper's
    §6 future work) neighbouring buckets share sub-prefixes, so partial
    reuse crosses bucket boundaries.

    ``get`` also returns the number of bitmap rows it read to build the
    intersection (0 on a full hit) — the measured locality traffic.

    The depth-first engine never touches this cache: the parent→child
    bitmap handoff makes it vestigial on that path (cache_misses == 0
    structurally)."""

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        self.d: "collections.OrderedDict[Itemset, np.ndarray]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.partial_hits = 0

    def _put(self, prefix: Itemset, bm: np.ndarray):
        self.d[prefix] = bm
        if len(self.d) > self.maxsize:
            self.d.popitem(last=False)

    def get(self, prefix: Itemset, bitmaps: np.ndarray
            ) -> Tuple[np.ndarray, int]:
        d = self.d
        if prefix in d:
            d.move_to_end(prefix)
            self.hits += 1
            return d[prefix], 0
        self.misses += 1
        # hierarchical fallback: longest cached ancestor prefix
        for cut in range(len(prefix) - 1, 1, -1):
            parent = prefix[:cut]
            if parent in d:
                d.move_to_end(parent)
                self.partial_hits += 1
                bm = d[parent]
                for item in prefix[cut:]:
                    bm = bm & bitmaps[item]
                self._put(prefix, bm)
                return bm, len(prefix) - cut
        bm = tidlist.intersect(bitmaps[list(prefix)])
        self._put(prefix, bm)
        return bm, len(prefix)


def _raise_task_errors(tasks) -> None:
    """Surface the first task-body exception on the driver thread (the
    scheduler records it instead of letting the worker die, which would
    deadlock wait_all)."""
    for t in tasks:
        if t.error is not None:
            raise t.error


def _level1(bitmaps: np.ndarray, min_support: int
            ) -> Tuple[Dict[Itemset, int], List[Itemset]]:
    """Level 1, shared by every engine: dense popcount, no tasks."""
    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(bitmaps.shape[0])
        if supports[i] >= min_support}
    return result, sorted(result)


def _cluster_fn(granularity: str, policy: str):
    """Task attr -> queue-bucket key. attr = (prefix_hash, itemset-or-
    prefix): the hash is the paper's XOR'd prefix hash, precomputed once
    so queue ops stay O(1). The nearest-neighbour policy keys buckets by
    the prefix tuple itself (it needs item overlap between bucket keys).
    """
    if granularity == "candidate":
        return ((lambda a: a[1][:-1]) if policy == "nn"
                else (lambda a: a[0]))
    return ((lambda a: a[1]) if policy == "nn"
            else (lambda a: a[0]))


def mine(bitmaps: np.ndarray, min_support: int, *,
         policy: str = "clustered", n_workers: int = 8,
         max_k: int = 8, cache_size: int = 32,
         granularity: str = "bucket", backend: str = "auto",
         ) -> Tuple[Dict[Itemset, int], MiningMetrics]:
    """bitmaps: [n_items, W] uint32 packed TID bitmaps.

    ``granularity`` selects the unit of scheduler task: "bucket" (one
    task per (k-1)-prefix, vectorized extension sweep), "candidate"
    (one scalar join per candidate — kept for A/B benchmarking), or
    "depth-first" (barrier-free equivalence-class recursion with
    parent→child bitmap handoff).
    ``backend`` names the bucket-sweep executor ("auto", "numpy",
    "pallas-interpret", "pallas-jit"; see repro.core.join_backend).
    """
    if granularity not in GRANULARITIES:
        raise ValueError(
            f"granularity must be one of {GRANULARITIES}, "
            f"got {granularity!r}")
    select = make_selector(backend)
    metrics = MiningMetrics()
    t0 = time.time()

    result, frequent = _level1(bitmaps, min_support)
    metrics.frequent += len(frequent)

    sched = TaskScheduler(n_workers,
                          make_policy(policy, n_workers,
                                      _cluster_fn(granularity, policy)))
    caches: Dict[int, _PrefixCache] = {}        # thread ident -> cache
    try:
        if granularity == "depth-first":
            _mine_depth_first(bitmaps, min_support, max_k, select, sched,
                              metrics, result, frequent)
        else:
            _mine_levelwise(bitmaps, min_support, max_k, select, sched,
                            metrics, result, frequent, granularity,
                            cache_size, caches)
    finally:
        sched.shutdown()

    metrics.wall_s = time.time() - t0
    metrics.scheduler = sched.merged_stats()
    metrics.rows_touched = int(metrics.scheduler["rows_touched"])
    metrics.bytes_swept = int(metrics.scheduler["bytes_swept"])
    metrics.cache_hits = sum(c.hits for c in caches.values())
    metrics.cache_misses = sum(c.misses for c in caches.values())
    metrics.cache_partial_hits = sum(c.partial_hits
                                     for c in caches.values())
    return result, metrics


def _mine_levelwise(bitmaps, min_support, max_k, select, sched, metrics,
                    result, frequent, granularity, cache_size, caches):
    """Level-synchronous engines: plan level k, spawn, barrier, plan
    level k+1 (the paper's §2 shape, at candidate or bucket grain)."""
    n_w = bitmaps.shape[1]
    lock = threading.Lock()

    def _thread_cache() -> _PrefixCache:
        tid = threading.get_ident()
        c = caches.get(tid)
        if c is None:
            with lock:
                c = caches.setdefault(tid, _PrefixCache(cache_size))
        return c

    def _prefix_bitmap(cache: _PrefixCache, prefix: Itemset
                       ) -> Tuple[np.ndarray, int]:
        if len(prefix) == 1:
            return bitmaps[prefix[0]], 1        # no reuse term at k=2
        return cache.get(prefix, bitmaps)

    def _account(rows: int) -> None:
        st = sched.worker_stats()
        st.rows_touched += rows
        st.bytes_swept += rows_to_bytes(rows, n_w)

    def count_task(cand: Itemset) -> int:
        cache = _thread_cache()
        pbm, prows = _prefix_bitmap(cache, cand[:-1])
        _account(prows + 1)
        return int(tidlist.popcount32(pbm & bitmaps[cand[-1]]).sum())

    def sweep_task(bucket: Bucket) -> np.ndarray:
        """Bucket-granularity body: prefix intersection once, then one
        vectorized sweep over all extensions. Returns [E] counts."""
        cache = _thread_cache()
        pbm, prows = _prefix_bitmap(cache, bucket.prefix)
        _account(prows + len(bucket.exts))
        exts = bitmaps[list(bucket.exts)]
        return select(len(bucket.exts)).sweep(pbm, exts)

    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        if not cands:
            break
        metrics.levels += 1
        metrics.candidates += len(cands)
        frequent = []
        if granularity == "bucket":
            plan = group_by_prefix(cands)
            metrics.buckets += len(plan)
            tasks = [sched.spawn(sweep_task, b,
                                 attr=(b.key, b.prefix))
                     for b in plan]
            sched.wait_all()
            _raise_task_errors(tasks)
            for b, t in zip(plan, tasks):
                counts = t.result
                for e, s in zip(b.exts, counts):
                    if s >= min_support:
                        c = b.prefix + (e,)
                        result[c] = int(s)
                        frequent.append(c)
        else:
            tasks = [sched.spawn(count_task, c,
                                 attr=(prefix_hash(c), c))
                     for c in cands]
            sched.wait_all()
            _raise_task_errors(tasks)
            for c, t in zip(cands, tasks):
                if t.result >= min_support:
                    result[c] = t.result
                    frequent.append(c)
        frequent.sort()
        metrics.frequent += len(frequent)
        k += 1


def _mine_depth_first(bitmaps, min_support, max_k, select, sched,
                      metrics, result, frequent):
    """Barrier-free engine: tasks spawn child equivalence classes.

    A task = one equivalence class (P, E): sweep the |E| extensions
    against the parent-handed prefix bitmap, record frequent
    extensions, then for each frequent sibling e (except the last)
    materialize ``pbm ∧ bitmaps[e]`` ONCE and spawn the child class
    (P+(e,), {frequent siblings > e}) with that bitmap. The child
    never recomputes a prefix intersection — the handoff replaces the
    LRU cache entirely. Eclat shape: no global candidate generation,
    no Apriori cross-class prune (supports are identical; a few extra
    infrequent candidates get swept).

    Memory bound: a handed bitmap is retained from spawn until its
    task finishes. With depth-first drain order (scheduler) and
    spawn-onto-own-worker placement, each worker holds O(depth ×
    branching) live bitmaps instead of a whole level's worth; the
    peak is measured in ``metrics.peak_retained_bitmaps`` /
    ``peak_bytes_retained``.
    """
    n_w = bitmaps.shape[1]
    lock = threading.Lock()
    all_tasks: List = []
    retained_n = retained_bytes = 0

    def _retain(nbytes: int) -> None:
        nonlocal retained_n, retained_bytes
        retained_n += 1
        retained_bytes += nbytes
        metrics.peak_retained_bitmaps = max(metrics.peak_retained_bitmaps,
                                            retained_n)
        metrics.peak_bytes_retained = max(metrics.peak_bytes_retained,
                                          retained_bytes)

    def _release(nbytes: int) -> None:
        nonlocal retained_n, retained_bytes
        retained_n -= 1
        retained_bytes -= nbytes

    def class_task(prefix: Itemset, pbm: np.ndarray,
                   exts: Tuple[int, ...], owned: bool) -> None:
        try:
            k = len(prefix) + 1                 # size of swept itemsets
            backend = select(len(exts))
            counts = backend.sweep(pbm, bitmaps[list(exts)])
            freq = [(e, int(s)) for e, s in zip(exts, counts)
                    if s >= min_support]
            sibs = [e for e, _ in freq]         # ascending (exts sorted)
            children: List[Tuple[Itemset, np.ndarray, Tuple[int, ...]]] \
                = []
            if k < max_k and len(freq) > 1:
                children = [(prefix + (e,),
                             backend.materialize(pbm, bitmaps[e]),
                             tuple(sibs[i + 1:]))
                            for i, e in enumerate(sibs[:-1])]
            rows = class_rows_touched(len(exts), len(children))
            st = sched.worker_stats()
            st.rows_touched += rows
            st.bytes_swept += rows_to_bytes(rows, n_w)
            # ONE lock round-trip per class for metrics + retains (the
            # retain must precede the spawn: a fast child could finish
            # and _release before a late _retain, skewing the gauge)
            with lock:
                metrics.buckets += 1
                metrics.candidates += len(exts)
                metrics.levels = max(metrics.levels, k - 1)
                metrics.frequent += len(freq)
                for e, s in freq:
                    result[prefix + (e,)] = s
                for _, cbm, _ in children:
                    _retain(cbm.nbytes)
            if not children:
                return
            spawned = [sched.spawn(class_task, cprefix, cbm, csibs, True,
                                   attr=(itemset_hash(cprefix), cprefix),
                                   depth=len(cprefix))
                       for cprefix, cbm, csibs in children]
            with lock:
                all_tasks.extend(spawned)
        finally:
            if owned:
                with lock:
                    _release(pbm.nbytes)

    if max_k >= 2 and len(frequent) > 1:
        items = [p[0] for p in frequent]        # sorted singleton items
        for i, it in enumerate(items[:-1]):
            # root classes hand the base bitmap row itself (a view —
            # nothing materialized, nothing retained)
            t = sched.spawn(class_task, (it,), bitmaps[it],
                            tuple(items[i + 1:]), False,
                            attr=(itemset_hash((it,)), (it,)),
                            depth=1)
            with lock:    # already-running roots append concurrently
                all_tasks.append(t)
    sched.wait_all()                            # the ONLY wait
    with lock:
        tasks = list(all_tasks)
    _raise_task_errors(tasks)


def mine_serial(bitmaps: np.ndarray, min_support: int, max_k: int = 8
                ) -> Dict[Itemset, int]:
    """Single-threaded reference (no scheduler)."""
    result, frequent = _level1(bitmaps, min_support)
    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        frequent = []
        for c in cands:
            s = tidlist.support_of(bitmaps[list(c)])
            if s >= min_support:
                result[c] = s
                frequent.append(c)
        frequent.sort()
        k += 1
    return result
