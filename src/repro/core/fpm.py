"""Apriori-based FPM on the task scheduler — the paper's application.

One task per candidate k-itemset (paper §2). The per-task join reuses a
per-worker-thread LRU cache of *prefix intersections*: tasks that share a
(k-1)-prefix hit the cache iff they run back-to-back on the same worker —
exactly the locality the clustered policy creates and the Cilk-style
policy destroys. The cache hit-rate is this reproduction's analogue of
the paper's dTLB/IPC counters (measured, reported in benchmarks).
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.itemsets import (Itemset, gen_candidates, prefix_hash)
from repro.core.scheduler import TaskScheduler, make_policy


@dataclass
class MiningMetrics:
    wall_s: float = 0.0
    levels: int = 0
    candidates: int = 0
    frequent: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_partial_hits: int = 0
    scheduler: Dict[str, float] = field(default_factory=dict)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0


class _PrefixCache:
    """LRU of prefix -> intersected bitmap (one instance per worker).

    *Hierarchical*: a miss on ABC first checks AB — if present, only one
    extra AND is needed. With the nearest-neighbour policy (the paper's
    §6 future work) neighbouring buckets share sub-prefixes, so partial
    reuse crosses bucket boundaries."""

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        self.d: "collections.OrderedDict[Itemset, np.ndarray]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.partial_hits = 0

    def _put(self, prefix: Itemset, bm: np.ndarray):
        self.d[prefix] = bm
        if len(self.d) > self.maxsize:
            self.d.popitem(last=False)

    def get(self, prefix: Itemset, bitmaps: np.ndarray
            ) -> np.ndarray:
        d = self.d
        if prefix in d:
            d.move_to_end(prefix)
            self.hits += 1
            return d[prefix]
        self.misses += 1
        # hierarchical fallback: longest cached ancestor prefix
        for cut in range(len(prefix) - 1, 1, -1):
            parent = prefix[:cut]
            if parent in d:
                d.move_to_end(parent)
                self.partial_hits += 1
                bm = d[parent]
                for item in prefix[cut:]:
                    bm = bm & bitmaps[item]
                self._put(prefix, bm)
                return bm
        bm = tidlist.intersect(bitmaps[list(prefix)])
        self._put(prefix, bm)
        return bm


def mine(bitmaps: np.ndarray, min_support: int, *,
         policy: str = "clustered", n_workers: int = 8,
         max_k: int = 8, cache_size: int = 32,
         ) -> Tuple[Dict[Itemset, int], MiningMetrics]:
    """bitmaps: [n_items, W] uint32 packed TID bitmaps."""
    n_items = bitmaps.shape[0]
    metrics = MiningMetrics()
    t0 = time.time()

    # level 1: dense count (no tasks — same in both policies)
    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(n_items)
        if supports[i] >= min_support}
    frequent: List[Itemset] = sorted(result)
    metrics.frequent += len(frequent)

    caches: Dict[int, _PrefixCache] = {}        # thread ident -> cache
    lock = threading.Lock()

    def _thread_cache() -> _PrefixCache:
        tid = threading.get_ident()
        c = caches.get(tid)
        if c is None:
            with lock:
                c = caches.setdefault(tid, _PrefixCache(cache_size))
        return c

    def count_task(cand: Itemset) -> int:
        cache = _thread_cache()
        prefix = cand[:-1]
        if len(prefix) == 1:
            pbm = bitmaps[prefix[0]]            # 2-itemsets: no reuse term
        else:
            pbm = cache.get(prefix, bitmaps)
        return int(tidlist.popcount32(pbm & bitmaps[cand[-1]]).sum())

    # task attr = (bucket_key, itemset): the key is the paper's XOR'd
    # prefix hash, precomputed once so queue ops stay O(1). The
    # nearest-neighbour policy keys buckets by the prefix tuple itself
    # (it needs item overlap between bucket keys).
    cluster_of = ((lambda a: a[1][:-1]) if policy == "nn"
                  else (lambda a: a[0]))
    sched = TaskScheduler(n_workers,
                          make_policy(policy, n_workers, cluster_of))
    try:
        k = 2
        while frequent and k <= max_k:
            cands = gen_candidates(frequent)
            if not cands:
                break
            metrics.levels += 1
            metrics.candidates += len(cands)
            tasks = [sched.spawn(count_task, c, attr=(prefix_hash(c), c))
                     for c in cands]
            sched.wait_all()
            frequent = []
            for c, t in zip(cands, tasks):
                if t.result >= min_support:
                    result[c] = t.result
                    frequent.append(c)
            frequent.sort()
            metrics.frequent += len(frequent)
            k += 1
    finally:
        sched.shutdown()

    metrics.wall_s = time.time() - t0
    metrics.scheduler = sched.merged_stats()
    metrics.cache_hits = sum(c.hits for c in caches.values())
    metrics.cache_misses = sum(c.misses for c in caches.values())
    metrics.cache_partial_hits = sum(c.partial_hits
                                     for c in caches.values())
    return result, metrics


def mine_serial(bitmaps: np.ndarray, min_support: int, max_k: int = 8
                ) -> Dict[Itemset, int]:
    """Single-threaded reference (no scheduler)."""
    n_items = bitmaps.shape[0]
    supports = tidlist.popcount32(bitmaps).sum(axis=1)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(n_items)
        if supports[i] >= min_support}
    frequent = sorted(result)
    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        frequent = []
        for c in cands:
            s = tidlist.support_of(bitmaps[list(c)])
            if s >= min_support:
                result[c] = s
                frequent.append(c)
        frequent.sort()
        k += 1
    return result
