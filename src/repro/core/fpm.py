"""Apriori/Eclat FPM on the task scheduler — the paper's application.

Three task granularities (the paper's key knob, cf. "Redesigning pattern
mining algorithms for supercomputers"):

  granularity="candidate"    one task per candidate k-itemset (paper §2).
      The per-task join reuses a per-worker-thread LRU cache of *prefix
      intersections*: tasks that share a (k-1)-prefix hit the cache iff
      they run back-to-back on the same worker — exactly the locality
      the clustered policy creates and the Cilk-style policy destroys.
  granularity="bucket"       one task per (k-1)-prefix bucket (default).
      The task resolves its prefix intersection ONCE (to an arena
      handle) and enqueues one handle-based SweepRequest on the sweep
      dispatcher, which coalesces many workers' buckets into batched
      multi-prefix kernel launches (repro.core.join_backend).
      Level-synchronous: a driver barrier separates level k from k+1.
  granularity="depth-first"  barrier-free equivalence-class recursion.
      Each task owns one class (prefix P, sibling extensions E): it
      sweeps E through the dispatcher, records the frequent extensions,
      forms the child classes P+(e,) × {siblings > e} Eclat-style (no
      global candidate generation), materializes each child's
      ``prefix ∧ ext`` bitmap exactly once *into the arena* and hands
      the child task the handle — so no child ever recomputes or
      cache-probes a prefix intersection. Children spawn onto the
      spawning worker's queue (steals move whole subtrees); the deepest
      class drains first, bounding retained handoff bitmaps; one
      terminal ``wait_all`` replaces every inter-level barrier.

Every bitmap lives in one ``BitmapArena`` (repro.core.tidlist): item
bitmaps are loaded once (handle == item id), prefix intersections and
child handoffs are refcounted arena rows, and on the Pallas path the
arena's device mirror is synced incrementally — repeated sweeps cost
~one initial upload (``MiningMetrics.h2d_bytes``) instead of one
upload per sweep.

``mine(mesh=...)`` runs the SAME engine — every granularity, every
policy — across a device mesh: the arena shards one mirror per device
(item rows replicated, materialized rows owned by the creating shard),
one dispatcher per device flushes batched joins on its own shard,
workers carry a device affinity so clustered bucket placement is device
placement, and a cross-device bucket steal migrates the bucket's
retained handoff bitmaps explicitly. ``repro.core.distributed_fpm`` is
now only a compatibility shim over this path.

All granularities return identical supports under every policy (and
under every mesh shape). The cache hit-rate (candidate),
rows-touched/bytes-swept counters (shared cost model in
repro.core.buckets), batch-occupancy/flush gauges (per-device
dispatchers), peak-retained-bitmap gauge (arena), and cross-device
``d2d_bytes``/``migrations`` gauges are this reproduction's analogue
of the paper's dTLB/IPC counters.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import tidlist
from repro.core.buckets import (REPRESENTATIONS, Bucket, DensityModel,
                                class_rows_touched, group_by_prefix,
                                rows_to_bytes)
from repro.core.itemsets import (Itemset, gen_candidates, itemset_hash,
                                 prefix_hash)
from repro.core.join_backend import (FLUSH_US, MAX_BATCH, SweepDispatcher,
                                     resolve_backend)
from repro.core.scheduler import TaskScheduler, make_policy
from repro.core.tidlist import BitmapArena
from repro.obs import MetricsRegistry
from repro.obs import schema as obs_schema

GRANULARITIES = ("bucket", "candidate", "depth-first", "auto")


@dataclass
class MiningMetrics:
    wall_s: float = 0.0
    levels: int = 0
    candidates: int = 0
    buckets: int = 0
    frequent: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_partial_hits: int = 0
    rows_touched: int = 0        # bitmap rows actually read (measured)
    bytes_swept: int = 0         # rows_touched * W * 4
    # arena gauges: how many non-base rows (cached prefix intersections
    # + depth-first handoff bitmaps) were alive at once — the engines'
    # memory bound — and the bitmap payload uploaded host→device
    peak_retained_bitmaps: int = 0
    peak_bytes_retained: int = 0
    h2d_bytes: int = 0
    # dispatcher gauges: batched launches and their mean occupancy
    # (sweep requests per flush; >1 means coalescing actually happened)
    flushes: int = 0
    batch_occupancy: float = 0.0
    # mesh gauges: shards in the run, modeled cross-device row traffic
    # (on-demand foreign fetches + explicit steal migrations), rows
    # re-owned by migration, and one stats dict per device dispatcher
    # (flushes / batch_occupancy / sweep_requests per shard)
    n_devices: int = 1
    d2d_bytes: int = 0
    migrations: int = 0
    per_device: List[Dict[str, float]] = field(default_factory=list)
    scheduler: Dict[str, float] = field(default_factory=dict)
    # multi-host gauges (cluster runs only): hosts in the run, bytes
    # that crossed the interconnect (descriptor flushes + count
    # replies + level exchanges + steal migrations), the steal share
    # of them, cross-host bucket migrations, and one per-host row
    # (bytes_swept / sweep_s / eval_s / eval_bytes) for capacity math
    n_hosts: int = 1
    net_bytes: int = 0
    steal_net: int = 0
    cross_steals: int = 0
    per_host: List[Dict[str, float]] = field(default_factory=list)
    # hybrid-representation gauges: sweeps split by the prefix row's
    # representation, the byte share of bytes_swept that went through
    # the sparse (gather-intersect) path, sparse rows pushed, both
    # conversion directions (ops + bytes billed by the arena), and the
    # density model's per-child representation decisions
    representation: str = "bitmap"
    dense_sweeps: int = 0
    sparse_sweeps: int = 0
    sparse_bytes_swept: int = 0
    sparse_rows: int = 0
    densify_ops: int = 0
    densify_bytes: int = 0
    sparsify_ops: int = 0
    sparsify_bytes: int = 0
    rep_picks: Dict[str, int] = field(default_factory=dict)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0


class _PrefixCache:
    """LRU of prefix -> arena handle of the intersected bitmap (one
    instance per worker).

    *Hierarchical*: a miss on ABC first checks AB — if present, only one
    extra AND is needed. With the nearest-neighbour policy (the paper's
    §6 future work) neighbouring buckets share sub-prefixes, so partial
    reuse crosses bucket boundaries.

    ``get`` also returns the number of bitmap rows it read to build the
    intersection (0 on a full hit) — the measured locality traffic.

    Ownership contract: the cache owns one arena reference per entry
    (``push`` grants it; eviction releases), and ``get`` retains a
    SECOND reference on the caller's behalf before returning — the
    caller must release it when done. This keeps a handle live across
    the async dispatcher flight even if the entry is evicted meanwhile,
    and makes ``cache_size=0`` a valid "no cache" A/B knob (the entry
    is evicted immediately, but the caller's reference keeps the row
    alive until its release).

    The depth-first engine never touches this cache: the parent→child
    handle handoff makes it vestigial on that path (cache_misses == 0
    structurally)."""

    def __init__(self, arena: BitmapArena, maxsize: int = 32,
                 shard: int = 0, upto: Optional[int] = None,
                 model: Optional[DensityModel] = None):
        self.arena = arena
        self.maxsize = maxsize
        self.model = model        # density model: sparse-worthy prefix
                                  # intersections are pushed as
                                  # tid-lists instead of word-columns
        self.shard = shard        # rows this cache pushes are owned by
                                  # the caching worker's device shard
        self.upto = upto          # segment boundary: builds read (and
                                  # pushed rows cover) only the first
                                  # ``upto`` segments, so an ingest
                                  # landing mid-refresh cannot change a
                                  # row's width between two reads
        self.d: "collections.OrderedDict[Itemset, int]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.partial_hits = 0

    def _row(self, h: int) -> np.ndarray:
        if self.upto is None:
            return self.arena.row(h)
        return self.arena.row_upto(h, self.upto)

    def _put(self, prefix: Itemset, handle: int):
        self.d[prefix] = handle
        if len(self.d) > self.maxsize:
            _, old = self.d.popitem(last=False)
            self.arena.release(old)

    def get(self, prefix: Itemset) -> Tuple[int, int]:
        """(caller-retained arena handle, bitmap rows read to build
        it). The caller must ``release`` the handle when done."""
        d = self.d
        arena = self.arena
        if prefix in d:
            d.move_to_end(prefix)
            self.hits += 1
            h = d[prefix]
            arena.retain(h)
            return h, 0
        self.misses += 1
        # hierarchical fallback: longest cached ancestor prefix
        for cut in range(len(prefix) - 1, 1, -1):
            parent = prefix[:cut]
            if parent in d:
                d.move_to_end(parent)
                self.partial_hits += 1
                bm = self._row(d[parent])
                for item in prefix[cut:]:
                    bm = bm & self._row(item)
                rows_read = len(prefix) - cut
                break
        else:
            bm = self._row(prefix[0]).copy()
            for item in prefix[1:]:
                bm &= self._row(item)
            rows_read = len(prefix)
        if (self.model is not None and self.model.pick_rep(
                int(tidlist.popcount32(bm).sum())) != "bitmap"):
            h = arena.sparsify_push(bm, shard=self.shard,
                                    cover=self.upto)
        else:
            h = arena.push(bm, shard=self.shard, cover=self.upto)
        arena.retain(h)           # the caller's reference, BEFORE _put:
        self._put(prefix, h)      # maxsize=0 evicts-and-releases at once
        return h, rows_read

    def drain(self) -> None:
        """Release every cached handle. A one-shot ``mine`` discards
        the arena with the run, but a streaming arena persists across
        refreshes — rows a dead cache pins would never recycle, and
        worse, they would survive a later ``ingest`` WITHOUT the new
        segment's words, so the runtime drains caches at close."""
        while self.d:
            _, h = self.d.popitem(last=False)
            self.arena.release(h)


def _raise_task_errors(tasks) -> None:
    """Surface the first task-body exception on the driver thread (the
    scheduler records it instead of letting the worker die, which would
    deadlock wait_all)."""
    for t in tasks:
        if t.error is not None:
            raise t.error


def _level1(bitmaps: np.ndarray, min_support: int, counts=None
            ) -> Tuple[Dict[Itemset, int], List[Itemset]]:
    """Level 1, shared by every engine: dense popcount, no tasks.
    ``counts`` short-circuits the popcount with per-item ones counts a
    caller already has (``pack_database(..., return_counts=True)``
    produces them in the packing pass)."""
    supports = (np.asarray(counts) if counts is not None
                else tidlist.popcount32(bitmaps).sum(axis=1))
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(bitmaps.shape[0])
        if supports[i] >= min_support}
    return result, sorted(result)


def _cluster_fn(granularity: str, policy: str):
    """Task attr -> queue-bucket key. attr = (prefix_hash, itemset-or-
    prefix): the hash is the paper's XOR'd prefix hash, precomputed once
    so queue ops stay O(1). The nearest-neighbour policy keys buckets by
    the prefix tuple itself (it needs item overlap between bucket keys).
    """
    if granularity == "candidate":
        return ((lambda a: a[1][:-1]) if policy == "nn"
                else (lambda a: a[0]))
    return ((lambda a: a[1]) if policy == "nn"
            else (lambda a: a[0]))


def _resolve_mesh(mesh) -> Tuple[int, Optional[list]]:
    """``mesh=`` accepts None (shared-memory run), an int (N logical
    shards — ownership/affinity/d2d accounting without jax devices, so
    the CPU tier exercises the mesh path), or a ``jax.sharding.Mesh``
    (one shard per mesh device, mirrors placed on those devices).
    Returns (n_shards, devices-or-None)."""
    if mesh is None:
        return 1, None
    if isinstance(mesh, int):
        if mesh < 1:
            raise ValueError(f"mesh must be >= 1 shards, got {mesh}")
        return mesh, None
    devs = list(np.asarray(mesh.devices).reshape(-1))
    return len(devs), devs


def mesh_over_devices(n: int):
    """CLI ``--mesh N`` semantics, shared by the launcher, quickstart,
    and benchmarks: a jax ``Mesh`` over the first N devices when the
    host exposes at least N, else N logical shards (the int form of
    ``mine``'s ``mesh=``). Returns None for ``n <= 1`` — a plain
    shared-memory run."""
    if n <= 1:
        return None
    try:
        import jax
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) >= n:
            return Mesh(np.array(devs[:n]), ("data",))
    except Exception:       # pragma: no cover - jax always present here
        pass
    return n


@dataclass
class DeltaPlan:
    """Incremental re-mine instructions threaded through the engine
    cores by ``StreamingMiner.refresh`` (None on a batch ``mine``).

    ``known`` maps every candidate ever swept (frequent AND negative
    border) to its exact support over the segments refreshed so far —
    the engines update it in place (under ``lock`` on the depth-first
    path, where class tasks merge concurrently). ``dirty_items`` are
    the items occurring in the pending segments: a candidate's support
    may have changed iff EVERY item of it is dirty. ``segments`` are
    the pending segment ids a dirty candidate's delta sweep reads;
    ``base_segments`` are the segments a FULL (fresh-candidate) sweep
    reads — the refresh generation boundary, so an ingest landing
    mid-refresh never leaks into this generation's supports.
    ``priority_of(prefix)`` (optional) is the staleness-hotness carried
    on spawned tasks — the clustered policies drain stale-hot buckets
    first; None skips priority stamping entirely (an all-fresh first
    generation would otherwise pay the priority-drain scan for
    nothing). ``tenant`` tags every spawned task for the scheduler's
    weighted-fair drain (multi-tenant serving; None on single-tenant
    runs). Clean known candidates are never swept at all: that is
    the whole point."""
    known: Dict[Itemset, int]
    dirty_items: frozenset
    segments: Tuple[int, ...]
    base_segments: Tuple[int, ...]
    priority_of: Optional[Callable[[Itemset], float]] = None
    tenant: object = None
    lock: threading.Lock = field(default_factory=threading.Lock)
    # refresh-side counters (how much re-mining the plan avoided)
    swept_full: int = 0
    swept_delta: int = 0
    reused: int = 0

    def is_dirty(self, c: Itemset) -> bool:
        d = self.dirty_items
        return all(i in d for i in c)

    def classify_buckets(self, plan: List[Bucket]
                         ) -> Tuple[List[Tuple[Itemset, int]],
                                    List[Bucket], List[Itemset]]:
        """Split a level's prefix buckets into (clean ``(c, support)``
        pairs, dirty sub-buckets, fresh candidates) in one pass over
        the already-grouped plan. The prefix's dirtiness is probed
        ONCE per bucket — the per-candidate hot loop is one
        ``known.get`` plus one set probe for the extension item, and
        dirty extensions stay bucketed so the delta path never
        re-groups them."""
        known, ditems = self.known, self.dirty_items
        clean: List[Tuple[Itemset, int]] = []
        dirty: List[Bucket] = []
        fresh: List[Itemset] = []
        for b in plan:
            p = b.prefix
            p_dirty = all(i in ditems for i in p)
            d_exts: List[int] = []
            for e in b.exts:
                c = p + (e,)
                ks = known.get(c)
                if ks is None:
                    fresh.append(c)
                elif p_dirty and e in ditems:
                    d_exts.append(e)
                else:
                    clean.append((c, ks))
            if d_exts:
                dirty.append(Bucket(b.key, p, tuple(d_exts)))
        return clean, dirty, fresh


class EngineRuntime:
    """The persistent engine substrate: one scheduler with
    device-affine workers plus one sweep dispatcher per arena shard.

    Batch ``mine`` spins one up per call and tears it down with the
    run; the streaming/serving layer owns ONE across its whole life and
    lends it to every refresh's :class:`MiningRun` — so query sweeps
    submitted between (and during) refreshes land on the SAME
    dispatchers as candidate sweeps and coalesce into the same
    flushes. Idle cost is zero: dispatcher threads park untimed on
    their condition variable and so do scheduler workers once nothing
    is outstanding."""

    def __init__(self, store: BitmapArena, *, policy: str = "clustered",
                 n_workers: int = 8, granularity: str = "bucket",
                 backend: str = "auto", max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, cluster=None, tracer=None):
        backend_obj = resolve_backend(backend)
        n_shards = store.n_shards
        if n_shards > 1:
            n_workers = max(n_workers, n_shards)  # ≥1 worker per shard
        self.store = store
        self.n_workers = n_workers
        self.backend = backend_obj
        # multi-host context (repro.core.cluster): the dispatchers
        # reduce every flush across hosts through it, and the engine
        # cores partition work / exchange level results through it
        self.cluster = cluster
        # observability (repro.obs): one tracer threaded through every
        # layer this runtime owns — scheduler workers, dispatcher
        # threads and the arena all record into its per-thread rings.
        # None (the default) keeps every instrumented site on the
        # one-branch disabled fast path. In cluster mode the host rank
        # becomes the Chrome-trace pid, one lane group per host.
        self.tracer = tracer
        trace_pid = cluster.host_id if cluster is not None else 0
        self.trace_pid = trace_pid
        if tracer is not None:
            store.tracer = tracer
        self.device_of = [i % n_shards for i in range(n_workers)]
        self.dispatchers = [
            SweepDispatcher(store, backend_obj,
                            n_clients=self.device_of.count(s),
                            max_batch=max_batch, flush_us=flush_us,
                            shard=s, cluster=cluster, tracer=tracer,
                            trace_pid=trace_pid)
            for s in range(n_shards)]
        self.sched = TaskScheduler(
            n_workers,
            make_policy(policy, n_workers,
                        _cluster_fn(granularity, policy)),
            device_of=self.device_of,
            migrate_cb=lambda hs, src, dst: store.migrate(hs, dst),
            tracer=tracer, trace_pid=trace_pid)
        # pull-based snapshot API: live gauges, readable any time
        self.registry = MetricsRegistry()
        self.registry.register("scheduler", self.sched.merged_stats)
        self.registry.register(
            "per_device", lambda: [d.stats() for d in self.dispatchers])
        self.registry.register(
            "arena", lambda: {"h2d_bytes": store.h2d_bytes,
                              "d2d_bytes": store.d2d_bytes,
                              "migrations": store.migrations,
                              "compactions": store.compactions,
                              "compaction_bytes": store.compaction_bytes,
                              "live_extra": store.live_extra})

    def shutdown(self) -> None:
        self.sched.shutdown()
        for dispatcher in self.dispatchers:
            dispatcher.stop()


class MiningRun:
    """The engine runtime shared by batch ``mine`` and streaming
    ``refresh``: one scheduler with device-affine workers, one sweep
    dispatcher per arena shard, per-worker prefix caches, and the
    metrics plumbing — built around an arena the caller owns (a batch
    run discards it; a streaming run keeps it across refreshes).

    ``runtime`` lends a persistent :class:`EngineRuntime` instead of
    building one: the run then reports scheduler/dispatcher gauges as
    DELTAS against construction-time baselines (the shared runtime's
    counters accumulate across refreshes and query traffic), and
    ``close`` drains this run's caches but leaves the runtime alive."""

    def __init__(self, store: BitmapArena, *, policy: str,
                 n_workers: int, granularity: str, cache_size: int,
                 backend: str = "auto", max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US,
                 representation: str = "auto", item_counts=None,
                 runtime: Optional[EngineRuntime] = None,
                 tracer=None):
        if granularity not in GRANULARITIES:
            raise ValueError(
                f"granularity must be one of {GRANULARITIES}, "
                f"got {granularity!r}")
        if representation not in REPRESENTATIONS:
            raise ValueError(
                f"representation must be one of {REPRESENTATIONS}, "
                f"got {representation!r}")
        if runtime is None:
            runtime = EngineRuntime(
                store, policy=policy, n_workers=n_workers,
                granularity=granularity, backend=backend,
                max_batch=max_batch, flush_us=flush_us, tracer=tracer)
            self._owns_runtime = True
        else:
            if runtime.store is not store:
                raise ValueError(
                    "runtime was built over a different arena")
            self._owns_runtime = False
        self.runtime = runtime
        self.store = store
        self.granularity = granularity
        self.cache_size = cache_size
        self.representation = representation
        # "bitmap" keeps the model out entirely — the seed engine's
        # exact code paths; "auto"/"sparse" seed the density model from
        # per-item ones counts (pack_database's one-pass byproduct, or
        # the level-1 popcount the caller ran anyway)
        self.model = (None if representation == "bitmap"
                      else DensityModel.from_counts(
                          store.n_words, item_counts,
                          force=(None if representation == "auto"
                                 else "sparse")))
        self.device_of = runtime.device_of
        self.dispatchers = runtime.dispatchers
        self.sched = runtime.sched
        self.metrics = MiningMetrics(n_devices=store.n_shards)
        self.caches: Dict[int, _PrefixCache] = {}   # thread ident -> cache
        # cluster mode also forces dispatcher-routed joins: a direct
        # host join would skip the cross-host reduction
        self.sweep_joins = (store.n_shards > 1
                            or runtime.cluster is not None)
        # gauge baselines: zero for an owned runtime, the accumulated
        # counters for a borrowed one — finalize() reports deltas
        self._disp0 = [(d.flushes, d.requests, d.queue_flushes,
                        d.queue_requests, d.query_requests, d.sweep_s)
                       for d in self.dispatchers]
        self._sched0 = self.sched.merged_stats()

    def close(self) -> None:
        if self._owns_runtime:
            self.runtime.shutdown()
        for cache in self.caches.values():
            cache.drain()

    def _disp_stats(self, d, base) -> Dict[str, float]:
        f0, r0, qf0, qr0, q0, s0 = base
        return obs_schema.device_stats(
            {"device": d.shard, "flushes": d.flushes - f0,
             "sweep_requests": d.requests - r0,
             "query_requests": d.query_requests - q0,
             "queue_flushes": d.queue_flushes - qf0,
             "queue_requests": d.queue_requests - qr0,
             "sweep_s": d.sweep_s - s0})

    def finalize(self, t0: float) -> MiningMetrics:
        """Fill the metrics from scheduler/dispatcher/arena gauges.
        Scheduler and dispatcher gauges are deltas against this run's
        construction (identical to totals for an owned runtime). Arena
        gauges are cumulative over the arena's life — ``mine`` owns a
        fresh arena so they equal the run; ``refresh`` snapshots them
        before/after to report per-refresh deltas."""
        metrics, store = self.metrics, self.store
        # perf_counter epoch (matches the caller's t0): time.time() is
        # not monotonic — an NTP step mid-run corrupted wall_s
        metrics.wall_s = time.perf_counter() - t0
        # delta the COUNTERS only, then rebuild the derived ratio —
        # the obs schema is the one place the key set lives
        metrics.scheduler = obs_schema.scheduler_stats(
            obs_schema.delta_counters(self.sched.merged_stats(),
                                      self._sched0,
                                      obs_schema.SCHEDULER_COUNTERS))
        metrics.rows_touched = int(metrics.scheduler["rows_touched"])
        metrics.bytes_swept = int(metrics.scheduler["bytes_swept"])
        metrics.cache_hits = sum(c.hits for c in self.caches.values())
        metrics.cache_misses = sum(c.misses
                                   for c in self.caches.values())
        metrics.cache_partial_hits = sum(c.partial_hits
                                         for c in self.caches.values())
        metrics.per_device = [self._disp_stats(d, b)
                              for d, b in zip(self.dispatchers,
                                              self._disp0)]
        metrics.flushes = sum(int(row["flushes"])
                              for row in metrics.per_device)
        total_requests = sum(int(row["sweep_requests"])
                             for row in metrics.per_device)
        metrics.batch_occupancy = (total_requests / metrics.flushes
                                   if metrics.flushes else 0.0)
        metrics.h2d_bytes = store.h2d_bytes
        metrics.d2d_bytes = store.d2d_bytes
        metrics.migrations = store.migrations
        metrics.peak_retained_bitmaps = store.peak_live_extra
        metrics.peak_bytes_retained = store.peak_bytes_extra
        metrics.representation = self.representation
        metrics.dense_sweeps = int(metrics.scheduler["dense_sweeps"])
        metrics.sparse_sweeps = int(metrics.scheduler["sparse_sweeps"])
        metrics.sparse_bytes_swept = int(
            metrics.scheduler["sparse_bytes_swept"])
        metrics.sparse_rows = store.sparse_pushed
        metrics.densify_ops = store.densify_ops
        metrics.densify_bytes = store.densify_bytes
        metrics.sparsify_ops = store.sparsify_ops
        metrics.sparsify_bytes = store.sparsify_bytes
        if self.model is not None:
            metrics.rep_picks = {"bitmap": self.model.bitmap_picks,
                                 "tidlist": self.model.tidlist_picks,
                                 "diffset": self.model.diffset_picks}
        return metrics


def mine(bitmaps: np.ndarray, min_support: int, *,
         policy: str = "clustered", n_workers: int = 8,
         max_k: int = 8, cache_size: int = 32,
         granularity: str = "bucket", backend: str = "auto",
         arena: str = "auto", max_batch: int = MAX_BATCH,
         flush_us: float = FLUSH_US, mesh=None,
         representation: str = "auto", item_counts=None, hosts: int = 1,
         trace=None,
         ) -> Tuple[Dict[Itemset, int], MiningMetrics]:
    """bitmaps: [n_items, W] uint32 packed TID bitmaps.

    ``granularity`` selects the unit of scheduler task: "bucket" (one
    task per (k-1)-prefix, batched extension sweep), "candidate"
    (one scalar join per candidate — kept for A/B benchmarking),
    "depth-first" (barrier-free equivalence-class recursion with
    parent→child handle handoff), or "auto" (levelwise driver that
    detaches subtrees to depth-first class tasks when the density
    model predicts sparse/deep mining wins there).
    ``representation`` selects the row representation the engines hand
    around: "bitmap" (word-columns only — the pre-hybrid engine),
    "sparse" (force tid-list/diffset rows wherever structurally legal),
    or "auto" (per-subtree density-driven choice; the default).
    ``item_counts`` passes per-item ones counts a caller already has
    (``pack_database(..., return_counts=True)``) so level 1 and the
    density-model seed skip their popcount pass.
    ``backend`` names the sweep executor ("auto", "numpy",
    "pallas-interpret", "pallas-jit"; see repro.core.join_backend).
    ``arena`` picks the bitmap store's device residency ("auto": lazy
    device mirror; "jax": eager upload; "numpy": host-only — Pallas
    backends then re-upload per batch, the old transfer-bound
    behaviour). ``max_batch``/``flush_us`` tune the sweep dispatcher's
    coalescing (requests per launch / straggler wait).
    ``mesh`` makes the SAME engine multi-device: a ``jax.sharding.Mesh``
    (or an int for logical shards) shards the arena one mirror per
    device, splits the dispatcher one-per-device, and pins workers to
    shards — every granularity and policy then runs distributed through
    this one code path, with cross-shard traffic in
    ``MiningMetrics.d2d_bytes`` and per-device dispatcher gauges in
    ``MiningMetrics.per_device``.
    ``hosts`` > 1 runs the multi-HOST decomposition instead (see
    repro.core.cluster): the transaction axis word-partitions over N
    logical hosts in this process — each with its own arena slice,
    scheduler and dispatchers — with two-phase support counting and
    cross-host steal-as-migration. Bit-identical results; cluster
    traffic lands in ``MiningMetrics.net_bytes``/``steal_net``.
    ``trace`` attaches a :class:`repro.obs.Tracer`: workers,
    dispatchers and the arena record span timelines into it (export
    with ``repro.obs.write_chrome_trace``; None = tracing off).
    """
    if hosts > 1:
        if mesh is not None:
            raise ValueError("hosts= and mesh= are mutually exclusive "
                             "(a host owns its whole slice)")
        from repro.core.cluster import mine_cluster
        return mine_cluster(bitmaps, min_support, hosts=hosts,
                            policy=policy, n_workers=n_workers,
                            max_k=max_k, cache_size=cache_size,
                            granularity=granularity, backend=backend,
                            max_batch=max_batch, flush_us=flush_us,
                            item_counts=item_counts, tracer=trace)
    n_shards, devices = _resolve_mesh(mesh)
    store = BitmapArena.from_bitmaps(bitmaps, backing=arena,
                                     n_shards=n_shards, devices=devices)
    t0 = time.perf_counter()
    # level 1 before the runtime spins up worker/dispatcher threads:
    # if it raises there is nothing to tear down
    if item_counts is None:
        item_counts = tidlist.popcount32(bitmaps).sum(axis=1)
    result, frequent = _level1(bitmaps, min_support, counts=item_counts)
    run = MiningRun(store, policy=policy, n_workers=n_workers,
                    granularity=granularity, cache_size=cache_size,
                    backend=backend, max_batch=max_batch,
                    flush_us=flush_us, representation=representation,
                    item_counts=item_counts, tracer=trace)
    run.metrics.frequent += len(frequent)
    try:
        mine_more(run, min_support, max_k, result, frequent)
    finally:
        run.close()
    return result, run.finalize(t0)


def mine_more(run: MiningRun, min_support: int, max_k: int,
              result: Dict[Itemset, int], frequent: List[Itemset],
              delta: Optional[DeltaPlan] = None) -> None:
    """Mine levels ≥ 2 on an existing runtime, starting from the
    level-1 ``frequent`` itemsets — the shared entry point under
    ``mine`` (delta=None: sweep everything) and the streaming refresh
    (delta: reuse known supports, delta-sweep dirty candidates over the
    pending segments only, carry staleness priorities)."""
    cluster = run.runtime.cluster
    tr = run.sched.tracer
    if tr is not None:
        # whichever thread drives this run gets the "driver" lane (one
        # per host in cluster mode — drivers are distinct threads)
        tr.set_lane("driver", sort_index=0, pid=run.runtime.trace_pid)
    if run.granularity == "depth-first":
        _mine_depth_first(run.store, run.dispatchers, min_support,
                          max_k, run.sched, run.metrics, result,
                          frequent, delta=delta, model=run.model,
                          cluster=cluster)
    else:
        _mine_levelwise(run.store, run.dispatchers, min_support, max_k,
                        run.sched, run.metrics, result, frequent,
                        run.granularity, run.cache_size, run.caches,
                        sweep_joins=run.sweep_joins, delta=delta,
                        model=run.model, cluster=cluster)


def _mine_levelwise(store, dispatchers, min_support, max_k, sched,
                    metrics, result, frequent, granularity, cache_size,
                    caches, sweep_joins=False, delta=None, model=None,
                    cluster=None):
    """Level-synchronous engines: plan level k, spawn, barrier, plan
    level k+1 (the paper's §2 shape, at candidate or bucket grain).
    ``sweep_joins`` routes even candidate-granularity scalar joins
    through the (per-device) dispatchers — multi-shard runs need every
    row access on the owning shard's path for d2d accounting;
    single-shard runs (shared-memory or a 1-device mesh) keep the
    direct host join as the scalar baseline.

    With a ``delta`` plan the level's candidates split three ways:
    *clean known* (support unchanged — zero rows touched), *dirty
    known* (delta-swept over only the pending segments, support
    accumulated into ``delta.known``), and *fresh* (never swept —
    full sweep over the generation-boundary segments). Dirty buckets
    are CHUNKED: one scheduler task carries ~hundreds of buckets and
    submits them as a burst of tuple-prefix sweeps — the backend
    AND-reduces each prefix's base rows over only the pending
    segments, so the delta path never builds a full-width prefix
    intersection and its launches fill like the full path's. Tasks
    carry ``delta.priority_of`` (when set) so the clustered policies
    drain stale-hot prefixes first.

    ``granularity="auto"`` runs this driver with a per-bucket escape
    hatch: when the density model predicts a prefix's subtree is
    sparse (or thin enough that level barriers dominate), the whole
    bucket detaches into a depth-first class task — the subtree mines
    barrier-free in the model-picked representation and its itemsets
    never re-enter the level frontier (``gen_candidates`` gets the
    full known-frequent set so cross-prefix pruning stays exact).
    Under a delta plan auto stays level-synchronous: the classify
    clean/dirty/fresh split already skips clean work, and diffset
    handoffs are structurally disabled mid-refresh anyway."""
    n_w = store.n_words
    # cached prefix rows must COVER every segment the plan sweeps;
    # max+1 (not len) because a multi-tenant plan's segment set is a
    # non-contiguous subset of the arena's segments (identical for the
    # single-tenant prefix case, where base_segments is range(n))
    upto = ((max(delta.base_segments) + 1)
            if delta is not None and delta.base_segments else None)
    lock = threading.Lock()
    df_miner = None
    detached_tasks: List = []
    if granularity == "auto" and model is not None and delta is None:
        df_miner = _ClassMiner(store, dispatchers, min_support, max_k,
                               sched, metrics, result, model=model)

    def _thread_cache() -> _PrefixCache:
        tid = threading.get_ident()
        c = caches.get(tid)
        if c is None:
            with lock:
                c = caches.setdefault(
                    tid, _PrefixCache(store, cache_size,
                                      shard=sched.worker_device(),
                                      upto=upto, model=model))
        return c

    def _prefix_handle(cache: _PrefixCache, prefix: Itemset
                       ) -> Tuple[int, int]:
        """Caller-retained handle (release when done; a no-op for the
        pinned base rows at k=2) + rows read to build it."""
        if len(prefix) == 1:
            return prefix[0], 1                 # base row; no reuse at k=2
        return cache.get(prefix)

    def _seg_w(segments) -> int:
        """Words per row a sweep actually reads: the full width, or
        only the pending segments' words on a delta sweep."""
        if segments is None:
            return n_w
        return sum(store.seg_words(g) for g in segments)

    def _account(prows: int, erows: int, segments) -> None:
        """prows prefix-build rows are read full-width; erows extension
        rows only over the swept segments."""
        st = sched.worker_stats()
        st.rows_touched += prows + erows
        st.bytes_swept += (rows_to_bytes(prows, n_w)
                           + rows_to_bytes(erows, _seg_w(segments)))

    def count_task(cand: Itemset, segments=None) -> int:
        cache = _thread_cache()
        ph, prows = _prefix_handle(cache, cand[:-1])
        try:
            _account(prows, 1, segments)
            st = sched.worker_stats()
            sparse = store.rep_of(ph) != tidlist.REP_BITMAP
            if sparse:
                st.sparse_sweeps += 1
                st.sparse_bytes_swept += len(store.tids_of(ph)) * 4
            else:
                st.dense_sweeps += 1
            if sweep_joins or segments is not None:
                st.sweeps_submitted += 1
                disp = dispatchers[sched.worker_device()]
                return int(disp.sweep(ph, (cand[-1],),
                                      segments=segments,
                                      desc=cand[:-1])[0])
            if sparse:
                # cached sparse prefixes are tid-lists (never
                # diffsets), so the gather count IS the support
                return int(tidlist.gather_count(store.tids_of(ph),
                                                store.row(cand[-1])))
            return int(tidlist.popcount32(store.row(ph)
                                          & store.row(cand[-1])).sum())
        finally:
            store.release(ph)

    def sweep_task(bucket: Bucket, segments=None) -> np.ndarray:
        """Bucket-granularity body: resolve the prefix handle once,
        then one handle-based request on the worker's device-affine
        dispatcher (which batches it with other workers' buckets on
        the same shard). ``segments`` restricts a delta sweep to the
        pending segments. Returns [E] counts."""
        cache = _thread_cache()
        ph, prows = _prefix_handle(cache, bucket.prefix)
        try:
            _account(prows, len(bucket.exts), segments)
            st = sched.worker_stats()
            st.sweeps_submitted += 1
            if store.rep_of(ph) != tidlist.REP_BITMAP:
                st.sparse_sweeps += 1
                st.sparse_bytes_swept += (len(store.tids_of(ph)) * 4
                                          * len(bucket.exts))
            else:
                st.dense_sweeps += 1
            disp = dispatchers[sched.worker_device()]
            return disp.sweep(ph, bucket.exts, segments=segments,
                              desc=bucket.prefix)
        finally:
            store.release(ph)

    def detach_task(bucket: Bucket, own_support: int,
                    psup: Tuple[int, ...]) -> None:
        """granularity="auto" handoff: resolve the bucket's prefix
        handle like a sweep task would, then run the depth-first class
        body inline — its children spawn barrier-free class tasks, and
        this whole subtree leaves the level frontier."""
        cache = _thread_cache()
        ph, prows = _prefix_handle(cache, bucket.prefix)
        _account(prows, 0, None)
        df_miner.class_task(bucket.prefix, ph, bucket.exts, psup,
                            own_support, True)

    def _spawn_buckets(cands, segments):
        plan = group_by_prefix(cands)
        if df_miner is not None:
            keep = []
            for b in plan:
                ps = result.get(b.prefix)
                if (ps is not None
                        and model.pick_granularity(ps) == "depth-first"):
                    # the class task re-counts its own candidates
                    metrics.candidates -= len(b.exts)
                    # parent-level sibling supports (for dEclat
                    # children): support of prefix[:-1] + (e,), frequent
                    # by the Apriori prune so present in ``result``
                    psup = tuple(result[b.prefix[:-1] + (e,)]
                                 for e in b.exts)
                    detached_tasks.append(
                        sched.spawn(detach_task, b, ps, psup,
                                    attr=(b.key, b.prefix)))
                else:
                    keep.append(b)
            plan = keep
        metrics.buckets += len(plan)
        prio = delta.priority_of if delta is not None else None
        tenant = delta.tenant if delta is not None else None
        tasks = [sched.spawn(sweep_task, b, segments,
                             attr=(b.key, b.prefix),
                             priority=prio(b.prefix) if prio else 0.0,
                             tenant=tenant)
                 for b in plan]
        return plan, tasks

    def _spawn_candidates(cands, segments):
        prio = delta.priority_of if delta is not None else None
        tenant = delta.tenant if delta is not None else None
        return [sched.spawn(count_task, c, segments,
                            attr=(prefix_hash(c), c),
                            priority=prio(c[:-1]) if prio else 0.0,
                            tenant=tenant)
                for c in cands]

    def delta_chunk_task(chunk: List[Bucket]
                         ) -> List[Tuple[Itemset, int]]:
        """Coalesced dirty-candidate burst: each bucket in the chunk
        becomes ONE tuple-prefix sweep over the pending segments, and
        the whole chunk executes as a single burst — on this worker
        thread for host backends, or as one dispatcher flush for
        kernel backends. No prefix bitmap is ever built host-side."""
        st = sched.worker_stats()
        disp = dispatchers[sched.worker_device()]
        counts_per_bucket = disp.sweep_local(
            [((b.prefix if len(b.prefix) > 1 else b.prefix[0]),
              b.exts) for b in chunk],
            segments=delta.segments)
        st.sweeps_submitted += len(chunk)
        out: List[Tuple[Itemset, int]] = []
        rows = 0
        for b, counts in zip(chunk, counts_per_bucket):
            rows += len(b.prefix) + len(b.exts)
            out.extend((b.prefix + (e,), int(s))
                       for e, s in zip(b.exts, counts))
        st.rows_touched += rows
        st.bytes_swept += rows_to_bytes(rows, _seg_w(delta.segments))
        return out

    def _spawn_delta_chunks(plan: List[Bucket]) -> Callable[
            [], List[Tuple[Itemset, int]]]:
        """Spawn a handful of chunk tasks (≈4 per worker) over the
        already-classified dirty buckets instead of one task per
        bucket — per-task scheduler and future overhead is what made
        the delta path slower than the full path it was supposed to
        beat."""
        if not plan:
            return lambda: []
        metrics.buckets += len(plan)
        n_chunks = max(1, 4 * sched.n)
        size = max(1, -(-len(plan) // n_chunks))
        tasks = [sched.spawn(delta_chunk_task, plan[i:i + size],
                             attr=(plan[i].key, plan[i].prefix),
                             tenant=delta.tenant)
                 for i in range(0, len(plan), size)]

        def collect():
            _raise_task_errors(tasks)
            return [pair for t in tasks for pair in t.result]
        return collect

    def _spawn_sweeps(cands, segments) -> Callable[
            [], List[Tuple[Itemset, int]]]:
        """Spawn sweeps for ``cands`` (bucket- or candidate-grained)
        and return a collector to call AFTER ``wait_all`` — fresh and
        dirty sweep sets share one level barrier. The collected counts
        cover ``segments`` only when restricted (the caller adds them
        to the known supports)."""
        if cluster is not None:
            # task partition: every host plans the SAME global frontier
            # but sweeps only its owned prefixes; the level exchange
            # merges the counted pairs back so thresholds stay global
            cands = [c for c in cands if cluster.owns(c[:-1])]
        if not cands:
            return lambda: []
        if granularity in ("bucket", "auto"):
            plan, tasks = _spawn_buckets(cands, segments)

            def collect():
                _raise_task_errors(tasks)
                return [(b.prefix + (e,), int(s))
                        for b, t in zip(plan, tasks)
                        for e, s in zip(b.exts, t.result)]
        else:
            tasks = _spawn_candidates(cands, segments)

            def collect():
                _raise_task_errors(tasks)
                return [(c, int(t.result))
                        for c, t in zip(cands, tasks)]
        return collect

    k = 2
    tr = sched.tracer
    while frequent and k <= max_k:
        t_level = tr.now() if tr is not None else 0.0
        # detached subtrees' itemsets never rejoin ``frequent``, so the
        # Apriori prune needs the full known-frequent membership (the
        # result dict is complete here: the level barrier below also
        # waited on every detached class task)
        cands = (gen_candidates(frequent, known_frequent=result)
                 if df_miner is not None else gen_candidates(frequent))
        if not cands:
            break
        metrics.levels += 1
        metrics.candidates += len(cands)
        frequent = []
        level: List[Tuple[Itemset, int]] = []
        if delta is None:
            collect = _spawn_sweeps(cands, None)
            if cluster is None:
                sched.wait_all()
            else:
                cluster.level_wait(sched)
            if df_miner is not None:
                _raise_task_errors(detached_tasks)
                df_miner.raise_errors()
            level = collect()
            if cluster is not None:
                level = cluster.exchange(level)
        else:
            clean, dirty, fresh = delta.classify_buckets(
                group_by_prefix(cands))
            level.extend(clean)                 # clean: zero rows read
            if cluster is None or cluster.host_id == 0:
                # a loopback cluster SHARES the plan: bill its
                # avoided-work counters once, not once per host
                delta.reused += len(clean)
                delta.swept_full += len(fresh)
                delta.swept_delta += sum(len(b.exts) for b in dirty)
            if cluster is not None:
                dirty = [b for b in dirty if cluster.owns(b.prefix)]
            collect_fresh = _spawn_sweeps(fresh, delta.base_segments)
            collect_dirty = _spawn_delta_chunks(dirty)
            if cluster is None:
                sched.wait_all()
                for c, s in collect_fresh():
                    delta.known[c] = s
                    level.append((c, s))
                for c, d in collect_dirty():
                    s = delta.known[c] + d      # delta over pending segs
                    delta.known[c] = s
                    level.append((c, s))
            else:
                cluster.level_wait(sched)
                mined = ([(c, s, True) for c, s in collect_fresh()]
                         + [(c, d, False) for c, d in collect_dirty()])

                def _apply(merged):
                    # runs ONCE per known-store (host 0 under loopback,
                    # where hosts share the plan): fold fresh supports
                    # and dirty deltas into ``known``, return the
                    # globally-thresholdable (itemset, support) pairs
                    out = []
                    for c, v, is_fresh in merged:
                        s = v if is_fresh else delta.known[c] + v
                        delta.known[c] = s
                        out.append((c, s))
                    return out

                level.extend(cluster.exchange(mined, update=_apply))
        for c, s in level:
            if s >= min_support:
                result[c] = s
                frequent.append(c)
        frequent.sort()
        metrics.frequent += len(frequent)
        if tr is not None:
            # driver-lane level span: the barrier-to-barrier extent
            tr.span(f"level-{k}", t_level, cat="level",
                    args={"candidates": len(cands),
                          "frequent": len(frequent)})
        k += 1


class _ClassMiner:
    """Barrier-free equivalence-class machinery: tasks spawn child
    classes. Shared by ``granularity="depth-first"`` (every root item
    is a class) and ``granularity="auto"`` (the levelwise driver
    detaches model-chosen prefix buckets into class tasks mid-run).

    A task = one equivalence class (P, E) owning an arena handle for
    P's row: it sweeps the |E| extensions through the dispatcher,
    records frequent extensions, then for each frequent sibling e
    (except the last) materializes the child row ONCE into the arena
    and spawns the child class (P+(e,), {frequent siblings > e}) with
    the new handle. The child never recomputes a prefix intersection —
    the handoff replaces the LRU cache entirely. Eclat shape: no global
    candidate generation, no Apriori cross-class prune (supports are
    identical; a few extra infrequent candidates get swept).

    Hybrid representation (``model`` set): the handed row's
    representation is chosen per child by the density cost model —
    dense word-column (``materialize``), sorted tid-list
    (``push_tids``), or dEclat diffset anchored on P
    (``push_diffset``). A sparse P is swept by the gather-intersect
    path, which returns |payload ∩ e| — for a tid-list that IS the
    support, for a diffset the class converts it with the
    parent-sibling supports handed down at spawn
    (``support = psup[e] - |diff ∩ e|``). Sparse children of a sparse
    parent are carved out of P's explicit tid set (``resolve_tids``,
    reconstructed once per class), so no dense intermediate is built.

    On host_parallel backends sparse subtrees run PROJECTED instead:
    the class sweep's [E, S] bit matrix (``sweep_bits``) is the dEclat
    recursion state — a child class receives its sibling rows
    column-masked to its own tid positions, its supports are row sums,
    and no arena row, dispatcher hop, or gather exists anywhere in the
    subtree's interior. Kernel backends keep the arena handoff path
    (device-resident rows, diffset chains, per-class gather-intersect
    sweeps).

    Memory bound: a handed row is live from materialize until the
    child task's ``finally`` releases it (including on task error — an
    error may NOT leak the refcount, or the arena slot never recycles).
    With depth-first drain order (scheduler) and spawn-onto-own-worker
    placement, each worker holds O(depth × branching) live rows instead
    of a whole level's worth; the peak is measured by the arena and
    reported as ``metrics.peak_retained_bitmaps`` /
    ``peak_bytes_retained``.

    With a ``delta`` plan each class splits its extensions into clean
    known (support looked up, zero rows), dirty known (delta sweep over
    the pending segments only) and fresh (full sweep), and a child
    subtree is recursed into ONLY when some candidate in it is fresh or
    dirty — a clean subtree's results are already exact in
    ``delta.known``, so whole equivalence classes are skipped without
    touching a row (the invalidated-classes-only re-mine). Diffset
    children are disabled under delta (``allow_diffset=False``): a
    dirty diffset sweep would need |parent ∩ e ∩ pending|, which the
    delta path doesn't carry — tid-list children delta-sweep fine (the
    backend searchsorts the payload into the pending segments' tid
    windows)."""

    def __init__(self, store, dispatchers, min_support, max_k, sched,
                 metrics, result, delta=None, model=None, cluster=None):
        self.store = store
        self.dispatchers = dispatchers
        self.min_support = min_support
        self.max_k = max_k
        self.sched = sched
        self.metrics = metrics
        self.result = result
        self.delta = delta
        self.model = model
        self.cluster = cluster    # multi-host: root classes partition
                                  # by owner, sweeps reduce per flush
        self.n_w = store.n_words
        self.lock = threading.Lock()
        self.all_tasks: List = []
        self._obs = 0     # observe() sampling counter (racy is fine)

    def needs_visit(self, cprefix: Itemset, csibs) -> bool:
        """A class subtree can contain changed or never-swept itemsets
        only if one of ITS OWN candidates is fresh or dirty: deeper
        dirt implies a dirty candidate here (X ⊆ dirty-items ⇒ every
        sub-candidate too), and deeper freshness implies a frequency
        status change here (supports only change where dirt is)."""
        delta = self.delta
        for e in csibs:
            c = cprefix + (e,)
            if delta.known.get(c) is None or delta.is_dirty(c):
                return True
        return False

    def _make_child(self, ph, e, csup, crep, shard, ptids, bits):
        """One child handoff row in the model-picked representation.
        Returns (handle, handoff-bytes-read, is-sparse). ``ptids`` is
        P's explicit tid set and ``bits`` its membership row in ext e —
        both resolved/gathered ONCE per class by the caller (from the
        sweep's own bit matrix when the backend surfaced it); only the
        dense-parent materialize path runs without them."""
        store = self.store
        if crep == "bitmap" and store.rep_of(ph) == tidlist.REP_BITMAP:
            return (store.materialize(ph, e, shard=shard),
                    self.n_w * 4, False)
        cov = min(store.cover_of(ph), store.cover_of(e))
        read = len(ptids) * 4 * 2      # bits gather + payload carve
        if crep == "bitmap":
            # force="bitmap" never lands here; under "auto" a dense
            # child of a sparse parent can't win the cost model
            # (child support ≤ parent support), so this is the forced
            # densify corner only
            ch = store.push(tidlist.tids_to_bitmap(ptids[bits],
                                                   self.n_w),
                            shard=shard, cover=cov)
            return ch, read + self.n_w * 4, False
        if crep == "tidlist":
            ch = store.push_tids(ptids[bits], shard=shard, cover=cov)
        else:
            ch = store.push_diffset(ptids[~bits], anchor=ph,
                                    support=csup, shard=shard,
                                    cover=cov)
        return ch, read, True

    def class_task(self, prefix: Itemset, ph: int,
                   exts: Tuple[int, ...], psup: Tuple[int, ...],
                   own_support: int, owned: bool,
                   ptids_hint=None, sub=None) -> None:
        store, sched, delta = self.store, self.sched, self.delta
        min_support, model = self.min_support, self.model
        children: List[Tuple[Itemset, int, Tuple[int, ...],
                             Tuple[int, ...], int, object,
                             object]] = []
        try:
            k = len(prefix) + 1                 # size of swept itemsets
            shard = sched.worker_device()
            st = sched.worker_stats()
            disp = self.dispatchers[shard]
            # host backends mine sparse subtrees projected (see the
            # children block); a projected child is a positional tid
            # mask whose sweep reads child_support bools no matter how
            # it was notionally encoded — so diffsets' smaller size
            # buys nothing there and the model must not price them
            host = delta is None and disp.backend.host_parallel
            if sub is not None:
                # projected class: ``sub`` is the subtree root's
                # gather-intersect bit matrix, row-selected to this
                # class's extensions and column-sliced to its tid
                # positions — no arena row exists for P at all
                rep = None
                sparse = True
                is_diff = False
                payload = sub.shape[1]
            else:
                rep = store.rep_of(ph)
                sparse = rep != tidlist.REP_BITMAP
                payload = len(store.tids_of(ph)) if sparse else 0
                is_diff = rep == tidlist.REP_DIFFSET
            pbits = None      # sweep's own [E, S] payload∩ext matrix
            supports: List[Tuple[int, int]] = []     # (ext, support)
            if delta is None:
                if sub is not None:
                    # support of P+e is a masked row sum — the dEclat
                    # intersection collapsed to boolean algebra
                    counts = sub.sum(axis=1, dtype=np.int64)
                    pbits = sub
                    supports = [(e, int(s))
                                for e, s in zip(exts, counts)]
                else:
                    st.sweeps_submitted += 1
                    counts, pbits = disp.sweep_bits(ph, exts,
                                                    desc=prefix)
                    if is_diff:
                        # dEclat arithmetic: the backend counted
                        # |diff ∩ e|; the parent's sibling supports
                        # handed down at spawn turn it into support
                        supports = [(e, psup[j] - int(s)) for j, (e, s)
                                    in enumerate(zip(exts, counts))]
                    else:
                        supports = [(e, int(s))
                                    for e, s in zip(exts, counts)]
                swept = len(exts)
                fresh_e: List[int] = []
                dirty_e: List[int] = []
            else:
                fresh_e, dirty_e = [], []
                for e in exts:
                    c = prefix + (e,)
                    ks = delta.known.get(c)
                    if ks is None:
                        fresh_e.append(e)
                    elif delta.is_dirty(c):
                        dirty_e.append(e)
                    else:
                        supports.append((e, ks))    # clean: zero rows
                n_clean = len(supports)
                # both sweeps go out before either result is awaited,
                # so they share a dispatcher flush; fresh sweeps read
                # the generation-boundary segments, never ones an
                # overlapped ingest appended mid-refresh
                ffut = (disp.submit(ph, tuple(fresh_e),
                                    segments=delta.base_segments,
                                    desc=prefix)
                        if fresh_e else None)
                dfut = (disp.submit(ph, tuple(dirty_e),
                                    segments=delta.segments,
                                    desc=prefix)
                        if dirty_e else None)
                updates: Dict[Itemset, int] = {}
                if ffut is not None:
                    st.sweeps_submitted += 1
                    for e, s in zip(fresh_e, ffut.result()):
                        updates[prefix + (e,)] = int(s)
                        supports.append((e, int(s)))
                if dfut is not None:
                    st.sweeps_submitted += 1
                    for e, d in zip(dirty_e, dfut.result()):
                        c = prefix + (e,)
                        s = delta.known[c] + int(d)
                        updates[c] = s
                        supports.append((e, s))
                with delta.lock:
                    delta.known.update(updates)
                    delta.swept_full += len(fresh_e)
                    delta.swept_delta += len(dirty_e)
                    delta.reused += n_clean
                supports.sort()       # merged lists back to ext order
                swept = len(fresh_e) + len(dirty_e)
            if model is not None and supports:
                # sampled EWMA: the gauge steers granularity detach
                # decisions, not per-child picks — every 4th class is
                # plenty of signal and trims the per-class Python floor
                self._obs += 1
                if (self._obs & 3) == 0:
                    model.observe([s for _, s in supports])
            freq = [(e, s) for e, s in supports if s >= min_support]
            sibs = [e for e, _ in freq]         # ascending (exts sorted)
            child_bytes = 0
            child_sparse_bytes = 0
            if k < self.max_k and len(freq) > 1:
                # pick every child's representation first, so the carve
                # work (P's explicit tid set + its membership bits in
                # each child ext) resolves and gathers ONCE per class
                plan = []             # (sibling idx, ext, csup, crep)
                for i, (e, csup) in enumerate(freq[:-1]):
                    if delta is not None and not self.needs_visit(
                            prefix + (e,), tuple(sibs[i + 1:])):
                        continue      # clean subtree: known is exact
                    plan.append((i, e, csup,
                                 "bitmap" if model is None
                                 else model.pick_child_rep(
                                     own_support, csup,
                                     allow_diffset=delta is None
                                     and not host)))
                # host backends mine sparse subtrees PROJECTED: the
                # sweep's gather-intersect bit matrix, row-selected to
                # the frequent siblings, IS the dEclat recursion state.
                # A child class's supports are column-masked row sums
                # of its parent's matrix, so the whole subtree below
                # this class runs on boolean index algebra — no arena
                # rows, no dispatcher hops, no gathers. Kernel backends
                # keep arena handoffs (the device owns the rows;
                # projection would drag every class to the host).
                proj = host and (sparse
                                 or any(p[3] != "bitmap" for p in plan))
                fmat = None   # frequent-sibling bits over P's tid set
                ptids = None  # P's tid set, resolved at most once
                bcol: Dict[int, int] = {}   # ext -> row in bit matrix
                bmat = None
                if proj and plan:
                    if pbits is not None and not is_diff:
                        eidx = {e: j for j, e in enumerate(exts)}
                        fmat = pbits[[eidx[f] for f in sibs]]
                    else:
                        if is_diff:
                            # dEclat chain: the spawner handed P's
                            # parent tid set down, so resolution is ONE
                            # sorted difference, not a chain walk
                            diff = store.tids_of(ph)
                            ptids = (tidlist.sorted_difference(
                                         ptids_hint, diff)
                                     if ptids_hint is not None
                                     else store.resolve_tids(ph))
                        elif sparse:
                            ptids = store.tids_of(ph)
                        else:
                            ptids = store.resolve_tids(ph)  # billed
                        fmat = store.gather_bits_rows(ptids, sibs)
                        child_bytes += len(ptids) * 4
                elif plan and not host:
                    carve = [p for p in plan
                             if p[3] != "bitmap"
                             or rep != tidlist.REP_BITMAP]
                    if carve:
                        if is_diff:
                            diff = store.tids_of(ph)
                            ptids = (tidlist.sorted_difference(
                                         ptids_hint, diff)
                                     if ptids_hint is not None
                                     else store.resolve_tids(ph))
                            pbits = None  # sweep bits were over diff
                        elif sparse:
                            ptids = store.tids_of(ph)
                        else:
                            ptids = store.resolve_tids(ph)  # billed
                        if pbits is not None:
                            eidx = {e: j for j, e in enumerate(exts)}
                            bcol = {e: eidx[e] for _, e, _, _ in carve}
                            bmat = pbits
                        else:
                            ce = [e for _, e, _, _ in carve]
                            bmat = store.gather_bits_rows(ptids, ce)
                            bcol = {e: j for j, e in enumerate(ce)}
                for i, e, csup, crep in plan:
                    if proj and (crep != "bitmap" or sparse):
                        m = fmat[i]
                        csub = fmat[i + 1:len(freq)][:, m]
                        read = csub.nbytes + m.nbytes
                        child_bytes += read
                        child_sparse_bytes += read
                        children.append((prefix + (e,), -1,
                                         tuple(sibs[i + 1:]),
                                         tuple(s for _, s
                                               in freq[i + 1:]),
                                         csup, None, csub))
                        continue
                    ch, read, ch_sparse = self._make_child(
                        ph, e, csup, crep, shard, ptids,
                        bmat[bcol[e]] if e in bcol else None)
                    child_bytes += read
                    if ch_sparse:
                        child_sparse_bytes += read
                    children.append((prefix + (e,), ch,
                                     tuple(sibs[i + 1:]),
                                     tuple(s for _, s in freq[i + 1:]),
                                     csup,
                                     ptids if crep == "diffset"
                                     else None, None))
            if delta is None:
                rows = class_rows_touched(len(exts), len(children))
                st.rows_touched += rows
                if sparse:
                    # gather-intersect passes: the payload once per
                    # extension (plus once for itself), never W words —
                    # plus the measured child-handoff reads. Projected
                    # classes read exactly their bit matrix.
                    sb = (sub.nbytes if sub is not None
                          else payload * 4 * (1 + len(exts)))
                    st.bytes_swept += sb + child_bytes
                    st.sparse_bytes_swept += sb + child_sparse_bytes
                else:
                    st.bytes_swept += rows_to_bytes(rows, self.n_w)
                    st.sparse_bytes_swept += child_sparse_bytes
            else:
                # only what was actually read: the parent-handed prefix
                # row (when any sweep ran), swept extension rows (dirty
                # ones only over the pending segments' words), and
                # materialized child handoffs
                seg_w = sum(store.seg_words(g) for g in delta.segments)
                full_rows = ((1 if swept else 0) + len(fresh_e)
                             + len(children))
                st.rows_touched += full_rows + len(dirty_e)
                if sparse:
                    sb = (payload * 4 * (1 + len(fresh_e)
                                         + len(dirty_e)) + child_bytes)
                    st.bytes_swept += sb
                    st.sparse_bytes_swept += sb
                else:
                    st.bytes_swept += (rows_to_bytes(full_rows,
                                                     self.n_w)
                                       + rows_to_bytes(len(dirty_e),
                                                       seg_w))
                    st.sparse_bytes_swept += child_sparse_bytes
            if swept or delta is None:
                if sparse:
                    st.sparse_sweeps += 1
                else:
                    st.dense_sweeps += 1
            with self.lock:
                metrics = self.metrics
                metrics.buckets += 1
                metrics.candidates += len(exts)
                metrics.levels = max(metrics.levels, k - 1)
                metrics.frequent += len(freq)
                for e, s in freq:
                    self.result[prefix + (e,)] = s
            spawned = []
            while children:
                (cprefix, ch, csibs, cpsup, csup, chint,
                 csub) = children[0]
                spawned.append(self.spawn(cprefix, ch, csibs, cpsup,
                                          csup, csub is None, chint,
                                          csub))
                children.pop(0)       # ownership moved to the child task
            if spawned:
                with self.lock:
                    self.all_tasks.extend(spawned)
        except BaseException:
            # refcount hygiene on error: materialized handles whose
            # child tasks never spawned must release here or the rows
            # leak for the rest of the run (projected children own
            # nothing — their state is the sliced bit matrix)
            for _, ch, _, _, _, _, csub in children:
                if csub is None:
                    store.release(ch)
            raise
        finally:
            if owned:
                store.release(ph)

    def spawn(self, prefix: Itemset, ph: int, exts, psup,
              own_support: int, owned: bool, ptids_hint=None,
              sub=None):
        delta = self.delta
        return self.sched.spawn(
            self.class_task, prefix, ph, exts, psup, own_support, owned,
            ptids_hint, sub,
            attr=(itemset_hash(prefix), prefix), depth=len(prefix),
            priority=(delta.priority_of(prefix)
                      if delta is not None and delta.priority_of
                      else 0.0),
            tenant=delta.tenant if delta is not None else None,
            handles=(ph,) if owned else ())

    def spawn_roots(self, frequent, result) -> None:
        """One class per root item (the depth-first driver). Root
        classes hand the pinned base row's handle (== item id —
        nothing materialized, nothing retained); their sibling
        supports are the level-1 supports."""
        if self.max_k < 2 or len(frequent) < 2:
            return
        items = [p[0] for p in frequent]        # sorted singleton items
        sup = {p[0]: result[p] for p in frequent}
        for i, it in enumerate(items[:-1]):
            sibs = tuple(items[i + 1:])
            if (self.cluster is not None
                    and not self.cluster.owns((it,))):
                continue              # a peer host mines this subtree
            if self.delta is not None and not self.needs_visit((it,),
                                                               sibs):
                continue              # clean root class: skip entirely
            t = self.spawn((it,), it, sibs,
                           tuple(sup[e] for e in sibs), sup[it], False)
            with self.lock:   # already-running roots append concurrently
                self.all_tasks.append(t)

    def raise_errors(self) -> None:
        with self.lock:
            tasks = list(self.all_tasks)
        _raise_task_errors(tasks)


def _mine_depth_first(store, dispatchers, min_support, max_k, sched,
                      metrics, result, frequent, delta=None,
                      model=None, cluster=None):
    """Barrier-free engine driver: see :class:`_ClassMiner`. Under a
    cluster the root classes partition by owner host (global counts
    from the per-flush reduction make every subtree decision
    host-independent) and ONE terminal exchange replicates the mined
    itemsets — barrier-free within the whole subtree forest, exactly
    one collective at the end."""
    miner = _ClassMiner(store, dispatchers, min_support, max_k, sched,
                        metrics, result, delta=delta, model=model,
                        cluster=cluster)
    miner.spawn_roots(frequent, result)
    if cluster is None:
        sched.wait_all()                        # the ONLY wait
        miner.raise_errors()
    else:
        cluster.level_wait(sched)
        miner.raise_errors()
        mined = [(c, s) for c, s in result.items() if len(c) > 1]
        for c, s in cluster.exchange(mined):
            result[c] = s


def mine_serial(bitmaps: np.ndarray, min_support: int, max_k: int = 8
                ) -> Dict[Itemset, int]:
    """Single-threaded reference (no scheduler)."""
    result, frequent = _level1(bitmaps, min_support)
    k = 2
    while frequent and k <= max_k:
        cands = gen_candidates(frequent)
        frequent = []
        for c in cands:
            s = tidlist.support_of(bitmaps[list(c)])
            if s >= min_support:
                result[c] = s
                frequent.append(c)
        frequent.sort()
        k += 1
    return result
