"""PFunc analogue: a task-parallel runtime with *pluggable scheduling
policies* and *task attributes* (Sections 3-4 of the paper).

- ``Task`` carries an attribute (``attr``) — the paper's "task priority",
  which for FPM is a reference to the k-itemset being mined.
- A *policy* owns the per-worker queue structure and steal semantics:
    CilkPolicy      — per-worker LIFO deque, steal ONE task from the
                      opposite end of a random victim (Cilk-style work
                      stealing [Blumofe & Leiserson]).
    FifoPolicy      — per-worker FIFO deque, steal one.
    ClusteredPolicy — per-worker *hash table of buckets* keyed by the
                      task attribute's cluster hash; workers drain one
                      bucket at a time; steals take an ENTIRE bucket
                      (the paper's contribution).
- Worker threads release the GIL inside task bodies (numpy/jax compute),
  so wall-clock speedups are real on this container.

Hardware counters (PAPI in the paper) are replaced by scheduler-level
locality metrics: per-worker steal counts, tasks-per-steal, and bucket
switches; the FPM driver adds a prefix-intersection cache whose hit rate
is the direct analogue of the paper's dTLB locality (DESIGN.md §7).
"""
from __future__ import annotations

import collections
import random
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import schema as obs_schema


def stable_hash(key: Any) -> int:
    """Process-stable hash for worker placement: CRC32 of a canonical
    repr. Python's built-in ``hash`` is salted per process for str (and
    anything containing one), so ``hash(cluster_key) % n_workers``
    placed externally-spawned tasks on DIFFERENT workers from one run
    to the next — placement (and therefore device affinity, steal
    traffic, and locality metrics) was irreproducible across
    processes. ``repr`` of the int/tuple/str cluster keys used here is
    canonical, so this hash is not."""
    return zlib.crc32(repr(key).encode("utf-8"))


@dataclass
class Task:
    fn: Callable[..., Any]
    args: Tuple
    attr: Any = None          # task attribute (paper: the itemset ref)
    depth: int = 0            # prefix depth: deeper tasks drain first
    priority: float = 0.0     # staleness priority: stale-hot buckets
                              # drain first (streaming re-mine)
    tenant: Any = None        # owning tenant (multi-tenant serving):
                              # the weighted-fair drain's accounting key
    handles: Tuple[int, ...] = ()   # arena handles the task retains —
                                    # a cross-device steal migrates them
    result: Any = None
    error: Optional[BaseException] = None   # set if the body raised


@dataclass
class WorkerStats:
    tasks_run: int = 0
    steals: int = 0           # successful steal operations
    tasks_stolen: int = 0     # tasks acquired via steals
    steal_attempts: int = 0   # victim probes (incl. empty)
    steal_migrations: int = 0  # cross-device bucket-steal EVENTS this
                               # worker won (the arena's `migrations`
                               # gauge counts ROWS re-owned instead).
                               # Drain-bucket switches live on the
                               # clustered policies (`.switches`, per
                               # worker), not here.
    # locality traffic counters, shared with the distributed engine's
    # plan accounting (repro.core.buckets): task bodies add the bitmap
    # rows/bytes they swept via TaskScheduler.worker_stats()
    rows_touched: int = 0
    bytes_swept: int = 0
    # handle-based sweep requests this worker enqueued on the sweep
    # dispatcher (repro.core.join_backend); together with the
    # dispatcher's flush count this yields batch_occupancy
    sweeps_submitted: int = 0
    # hybrid-representation split: how many of this worker's sweeps ran
    # against a dense word-column prefix vs a tid-list/diffset one, and
    # the byte share of bytes_swept that went through the sparse
    # (gather-intersect) path
    dense_sweeps: int = 0
    sparse_sweeps: int = 0
    sparse_bytes_swept: int = 0


class SchedulingPolicy:
    """The scheduler 'concept' (paper §3): queue structure + steal rule."""

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.locks = [threading.Lock() for _ in range(n_workers)]

    def put(self, worker: int, task: Task) -> None:
        raise NotImplementedError

    def get(self, worker: int) -> Optional[Task]:
        raise NotImplementedError

    def steal(self, thief: int, victim: int) -> List[Task]:
        raise NotImplementedError

    def approx_len(self, worker: int) -> int:
        raise NotImplementedError


class CilkPolicy(SchedulingPolicy):
    """LIFO deque per worker; steal one task from the other end."""

    def __init__(self, n_workers: int):
        super().__init__(n_workers)
        self.queues: List[collections.deque] = [collections.deque()
                                                for _ in range(n_workers)]

    def put(self, worker, task):
        with self.locks[worker]:
            self.queues[worker].append(task)

    def get(self, worker):
        with self.locks[worker]:
            q = self.queues[worker]
            return q.pop() if q else None       # LIFO (depth-first)

    def steal(self, thief, victim):
        with self.locks[victim]:
            q = self.queues[victim]
            return [q.popleft()] if q else []   # breadth end, one task

    def approx_len(self, worker):
        return len(self.queues[worker])


class FifoPolicy(CilkPolicy):
    def get(self, worker):
        with self.locks[worker]:
            q = self.queues[worker]
            return q.popleft() if q else None


class ClusteredPolicy(SchedulingPolicy):
    """Paper §4: hash-table-of-buckets queues; bucket-granularity steals.

    ``cluster_of(attr)`` maps a task attribute to its bucket key (for FPM:
    XOR of item hashes over the (k-1)-prefix).

    Drain-bucket selection is *priority-then-depth-first*: when the
    current drain bucket empties, the bucket whose head task has the
    highest ``Task.priority`` (staleness-hotness, set by the streaming
    re-mine so popular stale prefixes converge first), tie-broken by
    the deepest ``Task.depth``, is picked next, scanning at most
    ``DRAIN_SCAN_CAP`` buckets. For the level-synchronous batch engine
    every task has priority 0 and depth 0 and this degenerates to the
    paper's first-non-empty rule; for the barrier-free engine the depth
    tiebreak drains each subtree before starting the next, bounding the
    number of retained parent-handed bitmaps.

    Multi-tenant fairness (:meth:`set_weights`): when tenant weights
    are configured, drain selection ranks buckets by *weighted
    deficit* first — ``weight(tenant) / (tasks served for tenant +
    1)``, per worker — so a heavy tenant's refresh cannot starve a
    light tenant's tasks out of the drain order; priority and depth
    break ties WITHIN the deficit rank, preserving the staleness /
    subtree semantics inside each tenant's share. With no weights set
    (every single-tenant run) the rank and the O(1) fast path are
    byte-for-byte the old behaviour.
    """

    DRAIN_SCAN_CAP = 64   # bound the deepest-bucket scan per switch

    def __init__(self, n_workers: int,
                 cluster_of: Callable[[Any], int] = hash):
        super().__init__(n_workers)
        self.cluster_of = cluster_of
        self.tables: List[Dict[int, collections.deque]] = [
            dict() for _ in range(n_workers)]
        self._drain: List[Optional[int]] = [None] * n_workers
        self.sizes = [0] * n_workers
        self._deep = [0] * n_workers   # queued tasks with depth > 0
        self._hot = [0] * n_workers    # queued tasks with priority > 0
        self.switches = [0] * n_workers  # drain-bucket selections (the
                                         # paper's bucket-switch count)
        self.weights: Optional[Dict[Any, float]] = None
        # per-worker tasks-served tally per tenant (the deficit
        # denominator); merged across workers by tenant_served()
        self._served: List[Dict[Any, int]] = [
            dict() for _ in range(n_workers)]

    def set_weights(self, weights: Optional[Dict[Any, float]]) -> None:
        """Configure tenant fairness weights (None/{} disables and
        restores the single-tenant fast path). Unlisted tenants —
        including ``tenant=None`` tasks — weigh 1.0."""
        self.weights = dict(weights) if weights else None

    def tenant_served(self) -> Dict[Any, int]:
        """Tasks drained per tenant, merged across workers."""
        out: Dict[Any, int] = {}
        for served in self._served:
            for t, n in served.items():
                out[t] = out.get(t, 0) + n
        return out

    def _deficit(self, worker: int, tenant: Any) -> float:
        w = self.weights.get(tenant, 1.0)
        return w / (self._served[worker].get(tenant, 0) + 1)

    def put(self, worker, task):
        key = self.cluster_of(task.attr)
        with self.locks[worker]:
            self.tables[worker].setdefault(key, collections.deque()
                                           ).append(task)
            self.sizes[worker] += 1
            if task.depth > 0:
                self._deep[worker] += 1
            if task.priority > 0:
                self._hot[worker] += 1

    def _pick_drain(self, worker: int,
                    tab: Dict[Any, collections.deque]) -> Any:
        """Highest-(priority, depth) head bucket among the NEWEST
        DRAIN_SCAN_CAP (dict order is insertion order, so the
        just-spawned deep children sit at the tail — scanning
        oldest-first would leave them beyond the cap whenever >CAP
        classes queue up, inverting the drain order and unbounding the
        retained-bitmap peak). With no deep or hot task queued (the
        level-synchronous batch engines: every depth and priority is 0)
        this is the paper's O(1) first-non-empty rule. Tenant weights
        prepend the weighted-deficit rank (see class docstring)."""
        weights = self.weights
        if (weights is None and not self._deep[worker]
                and not self._hot[worker]):
            return next(iter(tab))
        best, best_rank = None, None
        for i, key in enumerate(reversed(tab)):
            if i >= self.DRAIN_SCAN_CAP:
                break
            head = tab[key][0]
            rank = (head.priority, head.depth)
            if weights is not None:
                rank = (self._deficit(worker, head.tenant),) + rank
            if best_rank is None or rank > best_rank:
                best, best_rank = key, rank
        return best

    def get(self, worker):
        with self.locks[worker]:
            tab = self.tables[worker]
            if not tab:
                return None
            key = self._drain[worker]
            if key is None or key not in tab:
                key = self._pick_drain(worker, tab)
                self._drain[worker] = key
                self.switches[worker] += 1
            q = tab[key]
            task = q.popleft()
            if not q:
                del tab[key]
                self._drain[worker] = None
            self.sizes[worker] -= 1
            if task.depth > 0:
                self._deep[worker] -= 1
            if task.priority > 0:
                self._hot[worker] -= 1
            if self.weights is not None:
                served = self._served[worker]
                served[task.tenant] = served.get(task.tenant, 0) + 1
            return task

    def steal(self, thief, victim):
        with self.locks[victim]:
            tab = self.tables[victim]
            for key in list(tab):
                if key == self._drain[victim]:
                    continue                    # don't yank the hot bucket
                q = tab.pop(key)
                self._unaccount(victim, q)
                return list(q)                  # the WHOLE bucket
            # only the drain bucket remains: take it anyway
            for key in list(tab):
                q = tab.pop(key)
                self._unaccount(victim, q)
                self._drain[victim] = None
                return list(q)
            return []

    def _unaccount(self, victim: int, q: collections.deque) -> None:
        self.sizes[victim] -= len(q)
        self._deep[victim] -= sum(1 for t in q if t.depth > 0)
        self._hot[victim] -= sum(1 for t in q if t.priority > 0)

    def approx_len(self, worker):
        return self.sizes[worker]


class NearestNeighborPolicy(ClusteredPolicy):
    """The paper's FUTURE-WORK proposal (§6), implemented: a dynamic
    policy where a thread picks the bucket *nearest* to the task it just
    executed (here: largest item overlap between bucket keys, which are
    the prefix tuples themselves). Pairs with the hierarchical prefix
    cache in repro.core.fpm — neighbouring buckets share sub-prefixes, so
    partial intersections get reused across buckets, not only within one.
    """

    SCAN_CAP = 64   # bound the nearest-neighbour scan per switch

    def __init__(self, n_workers: int,
                 cluster_of: Callable[[Any], Any] = lambda a: a):
        super().__init__(n_workers, cluster_of)
        self._last: List[Optional[tuple]] = [None] * n_workers

    def get(self, worker):
        with self.locks[worker]:
            tab = self.tables[worker]
            if not tab:
                return None
            key = self._drain[worker]
            if key is None or key not in tab:
                last = self._last[worker]
                if last is None:
                    key = self._pick_drain(worker, tab)
                else:
                    # newest-first, like _pick_drain: fresh deep
                    # children live at the dict tail. Staleness
                    # priority dominates the nearest-neighbour rule —
                    # a stale-hot bucket is served before a merely
                    # nearby one, so the serving layer converges on
                    # popular prefixes first — then item overlap, then
                    # the depth-first tiebreak. Tenant weights prepend
                    # the weighted-deficit rank, like _pick_drain.
                    weights = self.weights
                    best, best_rank = None, None
                    for i, cand in enumerate(reversed(tab)):
                        if i >= self.SCAN_CAP:
                            break
                        ov = len(set(cand) & set(last)) \
                            if isinstance(cand, tuple) else 0
                        head = tab[cand][0]
                        rank = (head.priority, ov, head.depth)
                        if weights is not None:
                            rank = (self._deficit(worker, head.tenant),
                                    ) + rank
                        if best_rank is None or rank > best_rank:
                            best, best_rank = cand, rank
                    key = best
                self._drain[worker] = key
                self.switches[worker] += 1
            q = tab[key]
            task = q.popleft()
            if not q:
                del tab[key]
                self._drain[worker] = None
            if isinstance(key, tuple):
                self._last[worker] = key
            self.sizes[worker] -= 1
            if task.depth > 0:
                self._deep[worker] -= 1
            if task.priority > 0:
                self._hot[worker] -= 1
            if self.weights is not None:
                served = self._served[worker]
                served[task.tenant] = served.get(task.tenant, 0) + 1
            return task


class TaskScheduler:
    """Spawn tasks, run them on N worker threads under a policy, wait.

    ``device_of`` pins each worker to a device shard (the mesh-aware
    engine's affinity map; defaults to one shared shard). Because the
    clustered policy places tasks on workers by bucket hash, bucket
    placement *is* device placement. ``migrate_cb(handles, src, dst)``
    fires when a steal crosses device shards — the thief's explicit
    migration of the stolen bucket's retained arena bitmaps."""

    def __init__(self, n_workers: int, policy: SchedulingPolicy,
                 seed: int = 0,
                 device_of: Optional[Sequence[int]] = None,
                 migrate_cb: Optional[
                     Callable[[List[int], int, int], Any]] = None,
                 tracer=None, trace_pid: int = 0):
        self.n = n_workers
        # observability: None = tracing off (workers pay one `is not
        # None` test per event site); trace_pid is the host rank lane
        # group in cluster mode
        self.tracer = tracer
        self.trace_pid = trace_pid
        self.device_of = (list(device_of) if device_of is not None
                          else [0] * n_workers)
        if len(self.device_of) != n_workers:
            raise ValueError("device_of must have one entry per worker")
        self._migrate_cb = migrate_cb
        self.policy = policy
        self.stats = [WorkerStats() for _ in range(n_workers)]
        self._tls = threading.local()
        self._external_stats = WorkerStats()   # non-worker threads
        self._spawned = 0
        self._outstanding = 0
        self._work_seq = 0        # bumped on every put; parked workers
                                  # wait for it to move (wake-on-put)
        self._parked = 0          # workers currently parked on _cv
        self._cv = threading.Condition()
        self._stop = False
        self._rngs = [random.Random(seed + i) for i in range(n_workers)]
        self._spawn_rr = 0
        # cross-host steal hooks (cluster mode): _remote_steal_cb(i)
        # tries to migrate a bucket from a peer host's scheduler and
        # returns the number of tasks adopted; _remote_work_cb() says
        # whether any peer still has work, so idle workers keep a
        # timed park instead of sleeping through a steal opportunity.
        self._remote_steal_cb: Optional[Callable[[int], int]] = None
        self._remote_work_cb: Optional[Callable[[], bool]] = None
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(n_workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ spawn --
    def spawn(self, fn, *args, attr=None, depth: int = 0,
              priority: float = 0.0, tenant: Any = None,
              handles: Tuple[int, ...] = (),
              worker: Optional[int] = None):
        """Enqueue a task. When called from inside a task body, the child
        defaults onto the *spawning worker's* queue — the paper's runtime
        semantics: locality by construction, and a stolen bucket carries
        its whole subtree because descendants spawn on the thief. From
        the driver thread, placement is the bucket hash (ClusteredPolicy,
        via :func:`stable_hash` so placement reproduces across
        processes) or round-robin (approximates even initial placement).
        ``priority`` is the staleness-hotness the clustered policies'
        drain selection prefers; ``tenant`` tags the task for the
        weighted-fair drain (multi-tenant serving); ``handles`` names
        arena rows the task retains (the depth-first handoff bitmaps);
        a cross-device steal migrates them."""
        task = Task(fn, args, attr, depth, priority, tenant, handles)
        if worker is None:
            worker = getattr(self._tls, "worker_id", None)
        if worker is None:
            if isinstance(self.policy, ClusteredPolicy):
                worker = stable_hash(self.policy.cluster_of(attr)) % self.n
            else:
                worker = self._spawn_rr = (self._spawn_rr + 1) % self.n
        with self._cv:
            # one critical section: the outstanding bump must precede
            # the put (a fast child finishing before the bump could let
            # a blocked wait_all miss its wake), and the put must
            # precede the wake so a woken worker finds the task.
            # policy.put only takes per-worker policy locks, never _cv,
            # so the nesting cannot invert.
            self._spawned += 1
            self._outstanding += 1
            self.policy.put(worker, task)
            self._work_seq += 1
            if self._parked:
                self._cv.notify_all()
        return task

    def _signal_work(self):
        """Wake parked workers after new tasks became runnable. The
        notify is skipped when nobody is parked — the common case on a
        busy scheduler, where tasks spawn thousands of children."""
        with self._cv:
            self._work_seq += 1
            if self._parked:
                self._cv.notify_all()

    def wait_all(self):
        """Block until no task is outstanding. Dynamic: a task that
        spawns children mid-body keeps the count above zero (the child
        increments before the parent's own decrement), so one terminal
        wait covers a task graph that grows from inside tasks — no
        inter-level barriers needed."""
        with self._cv:
            self._cv.wait_for(lambda: self._outstanding == 0)

    def worker_stats(self) -> WorkerStats:
        """The calling thread's WorkerStats. Task bodies use this to
        account locality traffic (rows_touched / bytes_swept); calls
        from non-worker threads land in a shared fallback bucket that
        merged_stats() still includes."""
        return getattr(self._tls, "stats", self._external_stats)

    def worker_device(self) -> int:
        """The calling worker's device shard (0 for non-worker
        threads, e.g. the driver spawning root tasks)."""
        wid = getattr(self._tls, "worker_id", None)
        return 0 if wid is None else self.device_of[wid]

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5)

    # ---------------------------------------------------- cross-host steal --
    def set_remote_hooks(self, steal_cb: Callable[[int], int],
                         work_cb: Callable[[], bool]) -> None:
        """Install the cluster's cross-host steal protocol. ``steal_cb``
        runs on an idle worker AFTER its local probes all failed — the
        last-resort escalation that keeps the locality preference (own
        queue, then local victims, then a peer host)."""
        self._remote_steal_cb = steal_cb
        self._remote_work_cb = work_cb
        with self._cv:
            # force every already-parked worker through a fresh probe:
            # a worker that parked UNTIMED before the hooks existed
            # would otherwise sleep through every steal opportunity
            # (no local put will ever wake a host that owns no work)
            self._work_seq += 1
            if self._parked:
                self._cv.notify_all()

    def idle(self) -> bool:
        """True when nothing is outstanding here — spawned work that
        was DONATED to a peer counts against the adopter, so a cluster
        level is quiescent iff every host's scheduler is idle."""
        return self._outstanding == 0

    def queued_approx(self) -> int:
        """Racy total of queued (not yet running) tasks — the steal
        victim-selection signal, same contract as ``approx_len``."""
        return sum(self.policy.approx_len(i) for i in range(self.n))

    def donate_bucket(self) -> List[Task]:
        """Victim side of a cross-host steal: remove one bucket's tasks
        from this scheduler entirely — they stop counting against OUR
        outstanding total the moment they leave, and the adopter books
        them before any runs, so the window where neither host counts
        them is covered by the caller's migration lock (the global
        termination check takes the same lock)."""
        got: List[Task] = []
        for v in range(self.n):
            if self.policy.approx_len(v) == 0:
                continue
            got = list(self.policy.steal(0, v) or [])
            if got:
                break
        if got:
            with self._cv:
                self._outstanding -= len(got)
                if self._outstanding == 0:
                    self._cv.notify_all()
        return got

    def adopt(self, tasks: List[Task], worker: int = 0) -> None:
        """Thief side: book and enqueue migrated tasks on ``worker``'s
        queue. The tasks keep their closures — they still sweep through
        the ORIGIN host's dispatcher/arena (that is the migration's
        "shipped prefix slice"), and children they spawn route back to
        the origin scheduler too, keeping every arena handle on the
        host that owns it."""
        if not tasks:
            return
        with self._cv:
            for t in tasks:
                self._spawned += 1
                self._outstanding += 1
                self.policy.put(worker, t)
            self._work_seq += 1
            if self._parked:
                self._cv.notify_all()

    # ----------------------------------------------------------- worker --
    def _acquire(self, i: int) -> Optional[Task]:
        task = self.policy.get(i)
        if task is not None:
            return task
        st = self.stats[i]
        rng = self._rngs[i]
        tr = self.tracer
        t_steal = tr.now() if tr is not None else 0.0
        for _ in range(4 * self.n):
            victim = rng.randrange(self.n)
            if victim == i:
                continue
            st.steal_attempts += 1
            got = self.policy.steal(i, victim)
            if got:
                st.steals += 1
                st.tasks_stolen += len(got)
                src, dst = self.device_of[victim], self.device_of[i]
                if src != dst:
                    # cross-device steal = explicit migration: the
                    # stolen bucket's retained handoff bitmaps move
                    # (and are accounted) before any task runs here
                    st.steal_migrations += 1
                    if self._migrate_cb is not None:
                        moved = [h for t in got for h in t.handles]
                        if moved:
                            self._migrate_cb(moved, src, dst)
                if len(got) > 1:
                    for t in got[1:]:
                        self.policy.put(i, t)
                    self._signal_work()
                if tr is not None:
                    tr.span("steal", t_steal, cat="steal",
                            args={"victim": victim, "tasks": len(got),
                                  "migrated": src != dst, "hit": True})
                return got[0]
        # local queues and victims are all dry: escalate to a
        # cross-host steal if a cluster installed one. The callback
        # adopts a peer bucket onto THIS worker's queue, so a plain
        # re-probe picks it up.
        cb = self._remote_steal_cb
        if cb is not None and (self._remote_work_cb is None
                               or self._remote_work_cb()):
            st.steal_attempts += 1
            n = cb(i)
            if n > 0:
                st.steals += 1
                st.tasks_stolen += n
                if tr is not None:
                    tr.span("steal", t_steal, cat="steal",
                            args={"remote": True, "tasks": n,
                                  "hit": True})
                return self.policy.get(i)
        if tr is not None:
            tr.span("steal", t_steal, cat="steal", args={"hit": False})
        return None

    def _worker(self, i: int):
        st = self.stats[i]
        self._tls.stats = st
        self._tls.worker_id = i
        tr = self.tracer
        if tr is not None:
            tr.set_lane(f"worker-{i}", sort_index=10 + i,
                        pid=self.trace_pid)
        while True:
            # Snapshot the put sequence BEFORE probing the queues: a
            # spawn that lands between a failed probe and the park bumps
            # _work_seq past the snapshot, so the park predicate is
            # already true and the worker does not sleep on a runnable
            # task. (Put and bump share spawn's critical section, so a
            # snapshot that saw the bump also guarantees _acquire can
            # see the task.)
            with self._cv:
                if self._stop:
                    return
                seen = self._work_seq
            task = self._acquire(i)
            if task is None:
                # Park on the condition variable until a put bumps
                # _work_seq past the snapshot (or shutdown). No
                # busy-spin: an idle worker burns no CPU while one deep
                # branch stays live. The timeout is a residual safety
                # net (e.g. a steal victim's queue refilling between
                # our probe and the park without a new put) — but with
                # NOTHING outstanding there is no queue to refill and
                # no running task to spawn, so a fully idle scheduler
                # parks untimed: a persistent serving runtime costs
                # zero wakeups between refreshes.
                t_park = tr.now() if tr is not None else 0.0
                with self._cv:
                    if self._stop:
                        return
                    self._parked += 1
                    try:
                        # with cluster hooks installed, "nothing
                        # outstanding HERE" is not "nothing to do": a
                        # peer host may have (or later GET) stealable
                        # work, and no local put will ever wake us for
                        # it — so cluster mode always keeps the timed
                        # park. ~20 cheap probes/s per idle worker,
                        # only while a cluster is attached.
                        untimed = (self._outstanding == 0
                                   and self._remote_work_cb is None)
                        self._cv.wait_for(
                            lambda: (self._stop
                                     or self._work_seq != seen),
                            timeout=(None if untimed else 0.05))
                    finally:
                        self._parked -= 1
                if tr is not None:
                    tr.span("park", t_park, cat="idle")
                continue
            t_task = tr.now() if tr is not None else 0.0
            try:
                task.result = task.fn(*task.args)
            except BaseException as e:  # noqa: BLE001 - must not leak:
                task.error = e          # a dead worker would deadlock
                                        # wait_all (outstanding never 0)
            finally:
                task.args = ()      # drop arg refs even on error:
                                    # parent-handed bitmaps must free
                                    # once consumed
            if tr is not None:
                attr = task.attr
                args = {"depth": task.depth}
                if isinstance(attr, tuple) and len(attr) == 2:
                    args["bucket"] = attr[0]
                    args["prefix"] = repr(attr[1])
                elif attr is not None:
                    args["prefix"] = repr(attr)
                tr.span("task", t_task, cat="task", args=args)
            st.tasks_run += 1
            with self._cv:
                self._outstanding -= 1
                if self._outstanding == 0:
                    self._cv.notify_all()

    # ------------------------------------------------------------ stats --
    def merged_stats(self) -> Dict[str, float]:
        """Scheduler-wide counters on the ``repro.obs.schema``
        scheduler schema (counters int, ``tasks_per_steal`` the only
        derived float — recomputed, never summed)."""
        s = list(self.stats) + [self._external_stats]
        return obs_schema.scheduler_stats({
            "tasks_run": sum(w.tasks_run for w in s),
            "spawned": self._spawned,
            "steals": sum(w.steals for w in s),
            "tasks_stolen": sum(w.tasks_stolen for w in s),
            "steal_attempts": sum(w.steal_attempts for w in s),
            # drain-bucket switches are counted at the queue by the
            # clustered policies; non-bucket policies report 0
            "bucket_switches": sum(getattr(self.policy, "switches",
                                           ())),
            "steal_migrations": sum(w.steal_migrations for w in s),
            "rows_touched": sum(w.rows_touched for w in s),
            "bytes_swept": sum(w.bytes_swept for w in s),
            "sweeps_submitted": sum(w.sweeps_submitted for w in s),
            "dense_sweeps": sum(w.dense_sweeps for w in s),
            "sparse_sweeps": sum(w.sparse_sweeps for w in s),
            "sparse_bytes_swept": sum(w.sparse_bytes_swept for w in s),
        })


def make_policy(name: str, n_workers: int,
                cluster_of: Callable[[Any], Any] = hash
                ) -> SchedulingPolicy:
    if name == "cilk":
        return CilkPolicy(n_workers)
    if name == "fifo":
        return FifoPolicy(n_workers)
    if name == "clustered":
        return ClusteredPolicy(n_workers, cluster_of)
    if name == "nn":
        return NearestNeighborPolicy(n_workers, cluster_of)
    raise ValueError(name)
