"""PFunc analogue: a task-parallel runtime with *pluggable scheduling
policies* and *task attributes* (Sections 3-4 of the paper).

- ``Task`` carries an attribute (``attr``) — the paper's "task priority",
  which for FPM is a reference to the k-itemset being mined.
- A *policy* owns the per-worker queue structure and steal semantics:
    CilkPolicy      — per-worker LIFO deque, steal ONE task from the
                      opposite end of a random victim (Cilk-style work
                      stealing [Blumofe & Leiserson]).
    FifoPolicy      — per-worker FIFO deque, steal one.
    ClusteredPolicy — per-worker *hash table of buckets* keyed by the
                      task attribute's cluster hash; workers drain one
                      bucket at a time; steals take an ENTIRE bucket
                      (the paper's contribution).
- Worker threads release the GIL inside task bodies (numpy/jax compute),
  so wall-clock speedups are real on this container.

Hardware counters (PAPI in the paper) are replaced by scheduler-level
locality metrics: per-worker steal counts, tasks-per-steal, and bucket
switches; the FPM driver adds a prefix-intersection cache whose hit rate
is the direct analogue of the paper's dTLB locality (DESIGN.md §7).
"""
from __future__ import annotations

import collections
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class Task:
    fn: Callable[..., Any]
    args: Tuple
    attr: Any = None          # task attribute (paper: the itemset ref)
    result: Any = None
    error: Optional[BaseException] = None   # set if the body raised


@dataclass
class WorkerStats:
    tasks_run: int = 0
    steals: int = 0           # successful steal operations
    tasks_stolen: int = 0     # tasks acquired via steals
    steal_attempts: int = 0   # victim probes (incl. empty)
    bucket_switches: int = 0  # clustered: times the drain bucket changed
    # locality traffic counters, shared with the distributed engine's
    # plan accounting (repro.core.buckets): task bodies add the bitmap
    # rows/bytes they swept via TaskScheduler.worker_stats()
    rows_touched: int = 0
    bytes_swept: int = 0


class SchedulingPolicy:
    """The scheduler 'concept' (paper §3): queue structure + steal rule."""

    def __init__(self, n_workers: int):
        self.n_workers = n_workers
        self.locks = [threading.Lock() for _ in range(n_workers)]

    def put(self, worker: int, task: Task) -> None:
        raise NotImplementedError

    def get(self, worker: int) -> Optional[Task]:
        raise NotImplementedError

    def steal(self, thief: int, victim: int) -> List[Task]:
        raise NotImplementedError

    def approx_len(self, worker: int) -> int:
        raise NotImplementedError


class CilkPolicy(SchedulingPolicy):
    """LIFO deque per worker; steal one task from the other end."""

    def __init__(self, n_workers: int):
        super().__init__(n_workers)
        self.queues: List[collections.deque] = [collections.deque()
                                                for _ in range(n_workers)]

    def put(self, worker, task):
        with self.locks[worker]:
            self.queues[worker].append(task)

    def get(self, worker):
        with self.locks[worker]:
            q = self.queues[worker]
            return q.pop() if q else None       # LIFO (depth-first)

    def steal(self, thief, victim):
        with self.locks[victim]:
            q = self.queues[victim]
            return [q.popleft()] if q else []   # breadth end, one task

    def approx_len(self, worker):
        return len(self.queues[worker])


class FifoPolicy(CilkPolicy):
    def get(self, worker):
        with self.locks[worker]:
            q = self.queues[worker]
            return q.popleft() if q else None


class ClusteredPolicy(SchedulingPolicy):
    """Paper §4: hash-table-of-buckets queues; bucket-granularity steals.

    ``cluster_of(attr)`` maps a task attribute to its bucket key (for FPM:
    XOR of item hashes over the (k-1)-prefix).
    """

    def __init__(self, n_workers: int,
                 cluster_of: Callable[[Any], int] = hash):
        super().__init__(n_workers)
        self.cluster_of = cluster_of
        self.tables: List[Dict[int, collections.deque]] = [
            dict() for _ in range(n_workers)]
        self._drain: List[Optional[int]] = [None] * n_workers
        self.sizes = [0] * n_workers

    def put(self, worker, task):
        key = self.cluster_of(task.attr)
        with self.locks[worker]:
            self.tables[worker].setdefault(key, collections.deque()
                                           ).append(task)
            self.sizes[worker] += 1

    def get(self, worker):
        with self.locks[worker]:
            tab = self.tables[worker]
            if not tab:
                return None
            key = self._drain[worker]
            if key is None or key not in tab:
                # move to the first non-empty bucket (paper: iterate
                # buckets from the first non-empty one)
                key = next(iter(tab))
                self._drain[worker] = key
            q = tab[key]
            task = q.popleft()
            if not q:
                del tab[key]
                self._drain[worker] = None
            self.sizes[worker] -= 1
            return task

    def steal(self, thief, victim):
        with self.locks[victim]:
            tab = self.tables[victim]
            for key in list(tab):
                if key == self._drain[victim]:
                    continue                    # don't yank the hot bucket
                q = tab.pop(key)
                self.sizes[victim] -= len(q)
                return list(q)                  # the WHOLE bucket
            # only the drain bucket remains: take it anyway
            for key in list(tab):
                q = tab.pop(key)
                self.sizes[victim] -= len(q)
                self._drain[victim] = None
                return list(q)
            return []

    def approx_len(self, worker):
        return self.sizes[worker]


class NearestNeighborPolicy(ClusteredPolicy):
    """The paper's FUTURE-WORK proposal (§6), implemented: a dynamic
    policy where a thread picks the bucket *nearest* to the task it just
    executed (here: largest item overlap between bucket keys, which are
    the prefix tuples themselves). Pairs with the hierarchical prefix
    cache in repro.core.fpm — neighbouring buckets share sub-prefixes, so
    partial intersections get reused across buckets, not only within one.
    """

    SCAN_CAP = 64   # bound the nearest-neighbour scan per switch

    def __init__(self, n_workers: int,
                 cluster_of: Callable[[Any], Any] = lambda a: a):
        super().__init__(n_workers, cluster_of)
        self._last: List[Optional[tuple]] = [None] * n_workers

    def get(self, worker):
        with self.locks[worker]:
            tab = self.tables[worker]
            if not tab:
                return None
            key = self._drain[worker]
            if key is None or key not in tab:
                last = self._last[worker]
                if last is None:
                    key = next(iter(tab))
                else:
                    best, best_ov = None, -1
                    for i, cand in enumerate(tab):
                        if i >= self.SCAN_CAP:
                            break
                        ov = len(set(cand) & set(last)) \
                            if isinstance(cand, tuple) else 0
                        if ov > best_ov:
                            best, best_ov = cand, ov
                    key = best
                self._drain[worker] = key
            q = tab[key]
            task = q.popleft()
            if not q:
                del tab[key]
                self._drain[worker] = None
            if isinstance(key, tuple):
                self._last[worker] = key
            self.sizes[worker] -= 1
            return task


class TaskScheduler:
    """Spawn tasks, run them on N worker threads under a policy, wait."""

    def __init__(self, n_workers: int, policy: SchedulingPolicy,
                 seed: int = 0):
        self.n = n_workers
        self.policy = policy
        self.stats = [WorkerStats() for _ in range(n_workers)]
        self._tls = threading.local()
        self._external_stats = WorkerStats()   # non-worker threads
        self._spawned = 0
        self._outstanding = 0
        self._cv = threading.Condition()
        self._stop = False
        self._rngs = [random.Random(seed + i) for i in range(n_workers)]
        self._spawn_rr = 0
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(n_workers)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ spawn --
    def spawn(self, fn, *args, attr=None, worker: Optional[int] = None):
        """Enqueue a task. Default placement is round-robin (the paper's
        runtime places on the spawning thread; the driver here is a single
        host thread, so round-robin approximates even initial placement —
        for ClusteredPolicy the bucket hash decides affinity instead)."""
        task = Task(fn, args, attr)
        if worker is None:
            if isinstance(self.policy, ClusteredPolicy):
                worker = hash(self.policy.cluster_of(attr)) % self.n
            else:
                worker = self._spawn_rr = (self._spawn_rr + 1) % self.n
        with self._cv:
            self._spawned += 1
            self._outstanding += 1
        self.policy.put(worker, task)
        with self._cv:
            self._cv.notify_all()
        return task

    def wait_all(self):
        with self._cv:
            self._cv.wait_for(lambda: self._outstanding == 0)

    def worker_stats(self) -> WorkerStats:
        """The calling thread's WorkerStats. Task bodies use this to
        account locality traffic (rows_touched / bytes_swept); calls
        from non-worker threads land in a shared fallback bucket that
        merged_stats() still includes."""
        return getattr(self._tls, "stats", self._external_stats)

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5)

    # ----------------------------------------------------------- worker --
    def _acquire(self, i: int) -> Optional[Task]:
        task = self.policy.get(i)
        if task is not None:
            return task
        st = self.stats[i]
        rng = self._rngs[i]
        for _ in range(4 * self.n):
            victim = rng.randrange(self.n)
            if victim == i:
                continue
            st.steal_attempts += 1
            got = self.policy.steal(i, victim)
            if got:
                st.steals += 1
                st.tasks_stolen += len(got)
                for t in got[1:]:
                    self.policy.put(i, t)
                return got[0]
        return None

    def _worker(self, i: int):
        st = self.stats[i]
        self._tls.stats = st
        while True:
            task = self._acquire(i)
            if task is None:
                with self._cv:
                    if self._stop:
                        return
                    if self._outstanding == 0:
                        self._cv.wait(timeout=0.01)
                        continue
                time.sleep(0.0002)
                continue
            try:
                task.result = task.fn(*task.args)
            except BaseException as e:  # noqa: BLE001 - must not leak:
                task.error = e          # a dead worker would deadlock
                                        # wait_all (outstanding never 0)
            st.tasks_run += 1
            with self._cv:
                self._outstanding -= 1
                if self._outstanding == 0:
                    self._cv.notify_all()

    # ------------------------------------------------------------ stats --
    def merged_stats(self) -> Dict[str, float]:
        s = list(self.stats) + [self._external_stats]
        total = sum(w.tasks_run for w in s)
        steals = sum(w.steals for w in s)
        return {
            "tasks_run": total,
            "spawned": self._spawned,
            "steals": steals,
            "tasks_stolen": sum(w.tasks_stolen for w in s),
            "steal_attempts": sum(w.steal_attempts for w in s),
            "tasks_per_steal": (sum(w.tasks_stolen for w in s)
                                / max(steals, 1)),
            "rows_touched": sum(w.rows_touched for w in s),
            "bytes_swept": sum(w.bytes_swept for w in s),
        }


def make_policy(name: str, n_workers: int,
                cluster_of: Callable[[Any], Any] = hash
                ) -> SchedulingPolicy:
    if name == "cilk":
        return CilkPolicy(n_workers)
    if name == "fifo":
        return FifoPolicy(n_workers)
    if name == "clustered":
        return ClusteredPolicy(n_workers, cluster_of)
    if name == "nn":
        return NearestNeighborPolicy(n_workers, cluster_of)
    raise ValueError(name)
