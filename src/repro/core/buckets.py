"""Prefix-bucket planning + the shared rows-touched cost model.

The paper's clustered policy groups level-k candidate tasks by their
(k-1)-prefix (§4). ``repro.core.fpm`` makes the *bucket* the unit of
task execution (prefix intersection computed once, extensions swept
vectorized) — and since the engine went mesh-aware, bucket placement
on workers IS bucket placement on devices, so this grouping also
defines what a cross-device bucket steal migrates.

Cost model: the engine MEASURES rows-touched per task (cache hits
reduce it) and converts via :func:`rows_to_bytes`;
:func:`class_rows_touched` is the depth-first task's accounting.
:func:`bucket_rows_touched` / :func:`candidate_rows_touched` are the
corresponding ANALYTIC models — the (k-1)+E vs k·E contrast the paper
argues from — kept as the documented reference the measurements are
read against (and pinned by tests), not called on the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.itemsets import Itemset, prefix_hash

BYTES_PER_WORD = 4                    # uint32 TID-bitmap words


@dataclasses.dataclass(frozen=True)
class Bucket:
    """All level-k candidates sharing one (k-1)-prefix.

    ``key`` is the paper's XOR'd prefix hash (the clustered policy's
    bucket key); ``exts`` are the candidates' last items, sorted, so the
    bucket's candidate set is ``{prefix + (e,) for e in exts}``.
    """
    key: int
    prefix: Itemset
    exts: Tuple[int, ...]

    def __len__(self) -> int:
        return len(self.exts)

    def candidates(self) -> List[Itemset]:
        return [self.prefix + (e,) for e in self.exts]


def group_by_prefix(cands: Sequence[Itemset]) -> List[Bucket]:
    """Group candidates by (k-1)-prefix, preserving first-seen prefix
    order (Apriori's gen_candidates emits prefixes contiguously, so this
    is also prefix-sorted order for sorted inputs)."""
    groups: Dict[Tuple[int, Itemset], List[int]] = {}
    for c in cands:
        groups.setdefault((prefix_hash(c), c[:-1]), []).append(c[-1])
    return [Bucket(h, pref, tuple(sorted(ext)))
            for (h, pref), ext in groups.items()]


def bucket_rows_touched(prefix_len: int, n_exts: int) -> int:
    """Bitmap rows a bucket sweep reads: the (k-1) prefix rows once,
    plus one row per extension (the clustered/bucket cost model; the
    per-candidate model is ``k`` rows per candidate, no reuse)."""
    return prefix_len + n_exts


def candidate_rows_touched(k: int, n_cands: int) -> int:
    """Rows read when every candidate performs its full k-way join."""
    return k * n_cands


def class_rows_touched(n_exts: int, n_children: int) -> int:
    """Rows a depth-first equivalence-class task reads: its parent-handed
    prefix bitmap (1 row — never recomputed, where the bucket model pays
    ``k-1`` prefix rows per bucket), one row per extension in the sweep,
    and one row per *frequent* child whose bitmap it materializes for
    the handoff. Per-class the comparison vs the bucket model's
    ``(k-1) + E`` can go either way (the handoff saves ``k-2`` prefix
    rows but pays ``C`` materializations, and Eclat sweeps candidates
    Apriori's cross-class prune would drop), so total traffic is an
    empirical question the granularity benchmark measures."""
    return 1 + n_exts + n_children


def rows_to_bytes(rows: int, n_words: int) -> int:
    """Bitmap rows -> bytes of TID-bitmap traffic."""
    return rows * n_words * BYTES_PER_WORD


# ---------------------------------------------------------------------------
# Density-aware representation + granularity selection (dEclat hybrid)
# ---------------------------------------------------------------------------

REPRESENTATIONS = ("auto", "bitmap", "sparse")

# Breakeven between the two sweep primitives, in elements-per-word: a
# dense sweep touches every one of the row's W words (AND + popcount,
# ~1 fused pass/word); a sparse sweep gathers one ext word per tid and
# tests one bit (~2-3 scalar-equivalent ops/element, no locality).
# A tid-list of S entries therefore costs about S / TIDS_PER_WORD
# "word-equivalents", and sparse wins once S < TIDS_PER_WORD * W.
TIDS_PER_WORD = 2.0

# Ones-per-word above which level-synchronous buckets beat depth-first
# even in bitmap representation (very dense, very wide classes — chess
# territory: huge supports keep every word busy and the level barrier
# amortizes across few, fat sweeps). Mushroom sits near 5 ones/word
# (depth-first wins), chess above 20 (bucket wins on clustered).
DF_ONES_PER_WORD = 16.0

# EWMA weight for folding measured sweep supports into the density
# estimate (level-1 seeds it; each observed sweep nudges it).
DENSITY_EWMA = 0.2


@dataclasses.dataclass
class DensityModel:
    """Density-driven cost model for per-subtree representation and
    granularity selection — the hybrid-representation extension of
    :func:`class_rows_touched`.

    All costs are in *word-equivalents* (one dense uint32 word scanned
    = 1.0), so dense and sparse sweeps land on one axis: a bitmap row
    costs ``n_words`` regardless of support, a tid-list of S entries
    costs ``S / TIDS_PER_WORD``, and a dEclat diffset of D entries
    costs ``D / TIDS_PER_WORD`` (support comes from the parent's
    already-known sibling supports, so only the difference is swept).

    ``ones_per_word`` is the measured density gauge: seeded from the
    level-1 item supports (``seed_from_counts`` — free, because
    ``pack_database`` now counts ones while packing) and EWMA-updated
    from actual sweep results (:meth:`observe`), so the granularity
    choice tracks the subtree the engine is actually in, not the
    dataset-wide average.

    ``force`` pins the representation ("bitmap" / "sparse") for A/B
    runs; granularity selection still follows density.
    """
    n_words: int
    force: str | None = None          # None=auto, "bitmap", "sparse"
    tids_per_word: float = TIDS_PER_WORD
    ones_per_word: float = 0.0        # measured EWMA density gauge
    # decision counters (surfaced through MiningMetrics)
    bitmap_picks: int = 0
    tidlist_picks: int = 0
    diffset_picks: int = 0

    @classmethod
    def from_counts(cls, n_words: int, counts, force: str | None = None,
                    tids_per_word: float = TIDS_PER_WORD) -> "DensityModel":
        """Seed from per-item ones counts (pack_database's one-pass
        byproduct): ones_per_word starts at the mean item density."""
        m = cls(n_words=n_words, force=force, tids_per_word=tids_per_word)
        if counts is not None and len(counts) and n_words > 0:
            m.ones_per_word = float(sum(counts)) / (len(counts) * n_words)
        return m

    # ------------------------------------------------------------ costs --
    def row_cost(self, rep: str, size: int) -> float:
        """Word-equivalents one sweep pass over a row of this
        representation touches. ``size`` is the entry count (support
        for tid-lists, difference size for diffsets; ignored for
        bitmaps)."""
        if rep == "bitmap":
            return float(self.n_words)
        return size / self.tids_per_word

    def class_cost(self, rep: str, size: int, n_exts: int,
                   n_children: int) -> float:
        """Density-aware generalisation of :func:`class_rows_touched`:
        word-equivalents a depth-first class task touches — the prefix
        row once, one ext-row pass per extension (a sparse prefix
        gathers only ``size`` words per ext, never W), and one
        materialization per frequent child."""
        per_pass = self.row_cost(rep, size)
        return per_pass * (1 + n_exts + n_children)

    # -------------------------------------------------------- selection --
    def pick_rep(self, support: int) -> str:
        """Representation for a standalone row (no parent context):
        bitmap vs tid-list by sweep cost."""
        if self.force == "bitmap":
            return "bitmap"
        if self.force == "sparse":
            return "tidlist"
        if self.row_cost("tidlist", support) < self.n_words:
            return "tidlist"
        return "bitmap"

    def pick_child_rep(self, parent_support: int, child_support: int,
                       allow_diffset: bool = True) -> str:
        """Representation for a depth-first child handoff. Candidates:
        bitmap (W words), tid-list (child_support entries), diffset
        (parent_support - child_support entries, anchored on the
        parent). Cheapest sweep cost wins; ties prefer the simpler
        representation (bitmap > tidlist > diffset). Scalar arithmetic
        on purpose: this runs once per child class, so list-building
        would be a measurable share of the per-class Python floor."""
        if self.force != "bitmap":
            best = child_support / self.tids_per_word
            rep = "tidlist"
            if allow_diffset:
                diff = parent_support - child_support
                if diff < 0:
                    diff = 0
                df = diff / self.tids_per_word
                if df < best:
                    best = df
                    rep = "diffset"
            if self.force == "sparse" or best < self.n_words:
                if rep == "tidlist":
                    self.tidlist_picks += 1
                else:
                    self.diffset_picks += 1
                return rep
        self.bitmap_picks += 1
        return "bitmap"

    def pick_granularity(self, support: int) -> str:
        """Bucket vs depth-first for one subtree (``granularity="auto"``).
        Sparse subtrees always go depth-first (diffset handoffs shrink
        with depth; level-sync would re-pay full-width sweeps). Dense
        subtrees go depth-first only below DF_ONES_PER_WORD — beyond
        that (chess-dense) the bucket engine's fat, few sweeps win."""
        if self.pick_rep(support) != "bitmap":
            return "depth-first"
        if self.n_words and support / self.n_words <= DF_ONES_PER_WORD:
            return "depth-first"
        return "bucket"

    # ------------------------------------------------------ measurement --
    def observe(self, supports) -> None:
        """Fold measured sweep supports into the density gauge (EWMA),
        so per-subtree decisions track observed — not assumed —
        density."""
        if self.n_words <= 0 or len(supports) == 0:
            return
        mean = float(sum(supports)) / (len(supports) * self.n_words)
        self.ones_per_word += DENSITY_EWMA * (mean - self.ones_per_word)
