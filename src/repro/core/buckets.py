"""Prefix-bucket planning + the shared rows-touched cost model.

The paper's clustered policy groups level-k candidate tasks by their
(k-1)-prefix (§4). ``repro.core.fpm`` makes the *bucket* the unit of
task execution (prefix intersection computed once, extensions swept
vectorized) — and since the engine went mesh-aware, bucket placement
on workers IS bucket placement on devices, so this grouping also
defines what a cross-device bucket steal migrates.

Cost model: the engine MEASURES rows-touched per task (cache hits
reduce it) and converts via :func:`rows_to_bytes`;
:func:`class_rows_touched` is the depth-first task's accounting.
:func:`bucket_rows_touched` / :func:`candidate_rows_touched` are the
corresponding ANALYTIC models — the (k-1)+E vs k·E contrast the paper
argues from — kept as the documented reference the measurements are
read against (and pinned by tests), not called on the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.itemsets import Itemset, prefix_hash

BYTES_PER_WORD = 4                    # uint32 TID-bitmap words


@dataclasses.dataclass(frozen=True)
class Bucket:
    """All level-k candidates sharing one (k-1)-prefix.

    ``key`` is the paper's XOR'd prefix hash (the clustered policy's
    bucket key); ``exts`` are the candidates' last items, sorted, so the
    bucket's candidate set is ``{prefix + (e,) for e in exts}``.
    """
    key: int
    prefix: Itemset
    exts: Tuple[int, ...]

    def __len__(self) -> int:
        return len(self.exts)

    def candidates(self) -> List[Itemset]:
        return [self.prefix + (e,) for e in self.exts]


def group_by_prefix(cands: Sequence[Itemset]) -> List[Bucket]:
    """Group candidates by (k-1)-prefix, preserving first-seen prefix
    order (Apriori's gen_candidates emits prefixes contiguously, so this
    is also prefix-sorted order for sorted inputs)."""
    groups: Dict[Tuple[int, Itemset], List[int]] = {}
    for c in cands:
        groups.setdefault((prefix_hash(c), c[:-1]), []).append(c[-1])
    return [Bucket(h, pref, tuple(sorted(ext)))
            for (h, pref), ext in groups.items()]


def bucket_rows_touched(prefix_len: int, n_exts: int) -> int:
    """Bitmap rows a bucket sweep reads: the (k-1) prefix rows once,
    plus one row per extension (the clustered/bucket cost model; the
    per-candidate model is ``k`` rows per candidate, no reuse)."""
    return prefix_len + n_exts


def candidate_rows_touched(k: int, n_cands: int) -> int:
    """Rows read when every candidate performs its full k-way join."""
    return k * n_cands


def class_rows_touched(n_exts: int, n_children: int) -> int:
    """Rows a depth-first equivalence-class task reads: its parent-handed
    prefix bitmap (1 row — never recomputed, where the bucket model pays
    ``k-1`` prefix rows per bucket), one row per extension in the sweep,
    and one row per *frequent* child whose bitmap it materializes for
    the handoff. Per-class the comparison vs the bucket model's
    ``(k-1) + E`` can go either way (the handoff saves ``k-2`` prefix
    rows but pays ``C`` materializations, and Eclat sweeps candidates
    Apriori's cross-class prune would drop), so total traffic is an
    empirical question the granularity benchmark measures."""
    return 1 + n_exts + n_children


def rows_to_bytes(rows: int, n_words: int) -> int:
    """Bitmap rows -> bytes of TID-bitmap traffic."""
    return rows * n_words * BYTES_PER_WORD
