"""Multi-host mining: transaction-axis partitioning, two-phase support
counting, cross-host steal-as-migration.

The decomposition follows the distributed-FPM literature (Yoshizoe et
al.; Aouad et al.): *count distribution* over a partitioned transaction
axis. Each host owns a contiguous word range of every TID bitmap — its
slice lives in a local :class:`BitmapArena` whose segment ids stay
globally aligned (streaming ingest appends ZERO-WIDTH segments on
non-owner hosts, which every backend already skips) — and runs its own
:class:`TaskScheduler` + :class:`SweepDispatcher`. Support counting is
two-phase: the local backend produces partial counts over owned words,
then the dispatcher's flush hook (:meth:`ClusterContext.reduce_flush`)
evaluates the SAME flush — shipped as compact *descriptors* (prefix
items + extension items + segment ids, never bitmap payload) — against
every peer slice and sums the partials. One reduction per flush, so the
collective amortizes exactly like the dispatcher amortizes kernel
launches. Counts are integer sums of disjoint word ranges, so results
are bit-identical to a single-host ``mine()``.

Task partition rides on :func:`stable_hash`: every driver generates the
full candidate frontier but spawns only the buckets it OWNS
(``stable_hash(prefix) % n_hosts``), then a per-level exchange merges
the counted pairs so all drivers threshold identically — no frontier
drift, no duplicated sweeps.

Two transports implement the same context API:

  ``LoopbackCluster``     N logical hosts in one process (driver
      threads + a shared bus). Reduction is a direct peer-arena
      evaluation; the exchange is a barrier + shared slot. This is the
      tier-1-testable mode, and the only mode with DYNAMIC cross-host
      steal: an idle host's worker migrates a whole bucket from the
      busiest peer (the victim "ships" the bucket's prefix rows — its
      owned-word slice — billed to ``steal_net``/``net_bytes``), while
      :class:`ClusteredPolicy` ownership spawning keeps buckets local
      so migrations stay rare.
  ``DistributedContext``  real processes over ``jax.distributed``. XLA
      collectives are unavailable on the CPU backend in this jaxlib
      ("Multiprocess computations aren't implemented on the CPU
      backend"), so the transport is the coordination service's
      key-value store (``key_value_set_bytes`` /
      ``blocking_key_value_get_bytes``, ~0.35 ms RTT on localhost):
      descriptor flushes become point-to-point eval requests served by
      a per-peer service thread, level exchanges become one KV blob per
      rank. On TPU the per-flush reduction could drop into a real
      ``psum`` over the [B, E] count matrix; the flush hook is the
      seam. Work stays statically partitioned (no cross-process steal).
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import fpm, tidlist
from repro.core.join_backend import FLUSH_US, MAX_BATCH, SweepRequest
from repro.core.scheduler import stable_hash
from repro.core.tidlist import BitmapArena, partition_words
from repro.obs import schema as obs_schema

Itemset = Tuple[int, ...]


class ClusterGauges:
    """Interconnect billing, shared by every host of one cluster run:
    ``net_bytes`` is everything that crossed (or, loopback, would have
    crossed) the wire — descriptor flushes, count replies, exchange
    blobs, and steal migrations; ``steal_net`` is the steal share of it
    (the migrated buckets' prefix-row slices). ``eval_s``/``eval_bytes``
    attribute each peer-slice evaluation to the host that OWNS the
    slice — the per-host busy accounting the multihost benchmark's
    aggregate-capacity metric divides by."""

    def __init__(self, n_hosts: int):
        self.lock = threading.Lock()
        self.net_bytes = 0
        self.steal_net = 0
        self.cross_steals = 0
        self.reduced_flushes = 0
        self.eval_s = [0.0] * n_hosts
        self.eval_bytes = [0] * n_hosts

    def snapshot(self) -> Dict[str, int]:
        with self.lock:
            return {"net_bytes": self.net_bytes,
                    "steal_net": self.steal_net,
                    "cross_steals": self.cross_steals,
                    "reduced_flushes": self.reduced_flushes}


def _desc_of(req: SweepRequest, arena: BitmapArena) -> Itemset:
    """The request's portable descriptor: the prefix as base ITEM ids
    (extension handles are always base ids already). Tuple prefixes and
    base-row handles self-describe; a cached/materialized handle is
    meaningless on a peer, so those call sites pass ``desc=`` — the
    prefix itemset — explicitly."""
    if req.desc is not None:
        return req.desc
    p = req.prefix_handle
    if isinstance(p, tuple):
        return p
    if p < arena.n_base:
        return (p,)
    raise RuntimeError(
        "cluster sweep of a derived arena handle needs an explicit "
        "desc= (the prefix itemset)")


def _desc_batch(requests: Sequence[SweepRequest], arena: BitmapArena
                ) -> List[Tuple[Itemset, Tuple[int, ...],
                                Optional[Tuple[int, ...]]]]:
    return [(_desc_of(r, arena), r.ext_handles, r.segments)
            for r in requests]


def _desc_nbytes(descs) -> Tuple[int, int]:
    """(request, reply) wire cost of a descriptor flush: 4 B per item /
    segment id out, 8 B per count back."""
    out = sum(len(d) + len(e) + (len(s) if s is not None else 0)
              for d, e, s in descs)
    back = sum(len(e) for _, e, _ in descs)
    return out * 4, back * 8


def _eval_rows_bytes(descs, arena: BitmapArena) -> int:
    """Bytes of ``arena``'s slice a descriptor flush reads in the
    steady state: one (memoized) prefix row + the extension rows over
    the swept segments' local words."""
    total = 0
    for d, e, s in descs:
        w = (arena.n_words if s is None
             else sum(arena.seg_words(g) for g in s))
        total += (1 + len(e)) * w * 4
    return total


# bound on memoized prefix rows per peer slice (FIFO eviction); at
# typical slice widths this is ~1-2 MB of reduced rows
_PCACHE_CAP = 512


def _eval_descs(arena: BitmapArena, descs,
                cache: Dict[Any, np.ndarray]) -> List[np.ndarray]:
    """Evaluate a descriptor flush against ``arena``'s slice directly:
    gather the extension rows, AND with the prefix row, fused popcount.
    The prefix AND-reduction is memoized per (prefix, segment) — the
    peer-side twin of the engine's intersection cache — so a hot prefix
    costs one [E, w] pass instead of re-reducing its k base rows on
    every flush. Counts are exact integer partials over the local
    words, so the cross-host sum stays bit-identical."""
    out: List[np.ndarray] = []
    for d, e, segs in descs:
        gs = range(arena.n_segments) if segs is None else segs
        total = np.zeros(len(e), np.int64)
        for g in gs:
            if not arena.seg_words(g):
                continue
            rows = arena.seg_view(g)
            key = (d, g)
            pr = cache.get(key)
            if pr is None:
                pr = rows[d[0]]
                for i in d[1:]:
                    pr = pr & rows[i]
                if len(cache) >= _PCACHE_CAP:
                    cache.pop(next(iter(cache)))
                cache[key] = pr
            ext = rows[list(e)] & pr
            total = total + tidlist.popcount32(ext).sum(
                axis=1, dtype=np.int64)
        out.append(total)
    return out


class _LoopbackBus:
    """Shared state of one in-process cluster: the lockstep barrier,
    exchange slots, peer arenas/schedulers, and the migration lock that
    makes cross-host steals atomic against the global level-termination
    check."""

    def __init__(self, n_hosts: int, arenas: List[BitmapArena]):
        self.n = n_hosts
        self.arenas = arenas
        self.gauges = ClusterGauges(n_hosts)
        self.scheds: List[Any] = []
        self.barrier = threading.Barrier(n_hosts)
        self.lock = threading.Lock()
        self.slots: Dict[int, Dict[int, Any]] = {}
        self.rets: Dict[int, Any] = {}
        self.mig_lock = threading.Lock()
        self._level_done = False

    def abort(self) -> None:
        self.barrier.abort()

    def wait(self) -> None:
        try:
            self.barrier.wait()
        except threading.BrokenBarrierError:
            raise RuntimeError(
                "cluster peer host failed (barrier broken)") from None

    def exchange(self, seq: int, host: int, payload,
                 update: Optional[Callable]) -> Any:
        """All-to-all merge at one lockstep point. ``update`` (when
        given) runs ONCE — on host 0, between the barriers — because
        loopback hosts share their delta/known stores; its return value
        is what every host gets back."""
        with self.lock:
            self.slots.setdefault(seq, {})[host] = payload
        self.wait()
        if host == 0:
            with self.lock:
                parts = self.slots.pop(seq)
            merged = [x for h in sorted(parts) for x in parts[h]]
            self.rets[seq] = update(merged) if update else merged
        self.wait()
        ret = self.rets[seq]
        self.wait()                 # all read before host 0 may recycle
        if host == 0:
            with self.lock:
                self.rets.pop(seq, None)
        return ret

    def level_wait(self, host: int) -> None:
        """Global quiescence: a host's own ``wait_all`` is not enough
        once buckets migrate — an idle host's worker may ADOPT work
        after its driver's wait returned. Loop until host 0, holding
        the migration lock (so no donation is mid-flight), sees every
        scheduler idle."""
        scheds = self.scheds
        while True:
            scheds[host].wait_all()
            self.wait()
            if host == 0:
                with self.mig_lock:
                    self._level_done = all(s.idle() for s in scheds)
            self.wait()
            if self._level_done:
                return

    def install_steal(self) -> None:
        """Hook every host's scheduler with the cross-host steal
        protocol: an idle worker (local queues and victims empty) picks
        the busiest PEER host, takes one whole bucket from it, and
        adopts it locally. The donated tasks keep their closures — they
        still sweep through the ORIGIN host's dispatcher and arena
        slice, which is exactly the semantics of the victim shipping
        the bucket's prefix bitmap slice; the shipment is billed here
        (prefix rows × the victim's owned words)."""
        bus = self

        def make_steal(thief: int):
            def steal_cb(worker: int) -> int:
                with bus.mig_lock:
                    best, best_q = -1, 0
                    for v, s in enumerate(bus.scheds):
                        if v == thief:
                            continue
                        q = s.queued_approx()
                        if q > best_q:
                            best, best_q = v, q
                    if best < 0:
                        return 0
                    tasks = bus.scheds[best].donate_bucket()
                    if not tasks:
                        return 0
                    rows = sum(len(t.handles) or 1 for t in tasks)
                    moved = rows * bus.arenas[best].n_words * 4
                    with bus.gauges.lock:
                        bus.gauges.cross_steals += 1
                        bus.gauges.steal_net += moved
                        bus.gauges.net_bytes += moved
                    bus.scheds[thief].adopt(tasks, worker=worker)
                    return len(tasks)
            return steal_cb

        def make_work(me: int):
            def work_cb() -> bool:
                return any(not s.idle()
                           for v, s in enumerate(bus.scheds) if v != me)
            return work_cb

        for h, sched in enumerate(self.scheds):
            sched.set_remote_hooks(make_steal(h), make_work(h))


class LoopbackContext:
    """One logical host's view of an in-process cluster. Implements the
    context API the engine consumes: ``owns``/``reduce_flush``/
    ``exchange``/``level_wait``."""

    def __init__(self, bus: _LoopbackBus, host_id: int,
                 owner_fn: Optional[Callable[[Itemset], int]] = None):
        self.bus = bus
        self.host_id = host_id
        self.n_hosts = bus.n
        self.arena = bus.arenas[host_id]
        self.gauges = bus.gauges
        self._owner_fn = owner_fn
        # per-peer memoized prefix rows for direct slice evaluation
        self._pcache: List[Dict[Any, np.ndarray]] = [
            {} for _ in range(bus.n)]
        self._xseq = 0             # lockstep: all hosts count together

    def owns(self, key: Itemset) -> bool:
        if self._owner_fn is not None:
            return self._owner_fn(key) == self.host_id
        return stable_hash(key) % self.n_hosts == self.host_id

    def reduce_flush(self, requests: Sequence[SweepRequest],
                     results: List[np.ndarray]) -> List[np.ndarray]:
        """Phase two of a flush: evaluate the flush's descriptors on
        every peer slice and sum the partial counts. The evaluation
        runs on the calling (origin) thread here, but its time and
        bytes are attributed to the slice-owning host — the capacity a
        real peer would spend."""
        descs = _desc_batch(requests, self.arena)
        out, back = _desc_nbytes(descs)
        totals = [np.asarray(c, np.int64) for c in results]
        for p, peer in enumerate(self.bus.arenas):
            if p == self.host_id:
                continue
            t0 = time.perf_counter()
            partial = _eval_descs(peer, descs, self._pcache[p])
            dt = time.perf_counter() - t0
            g = self.gauges
            with g.lock:
                g.net_bytes += out + back
                g.eval_s[p] += dt
                g.eval_bytes[p] += _eval_rows_bytes(descs, peer)
            for i, c in enumerate(partial):
                totals[i] = totals[i] + np.asarray(c, np.int64)
        with self.gauges.lock:
            self.gauges.reduced_flushes += 1
        return totals

    def exchange(self, pairs: Sequence, update: Optional[Callable] = None
                 ) -> Any:
        seq = self._xseq
        self._xseq += 1
        return self.bus.exchange(seq, self.host_id, list(pairs), update)

    def level_wait(self, sched) -> None:
        self.bus.level_wait(self.host_id)


class DistributedContext:
    """Real-process transport over the ``jax.distributed`` coordination
    service's KV store. Descriptor flushes: the origin writes
    ``ev/{peer}/{me}/{seq}`` and blocks on the reply key
    ``er/{me}/{peer}/{seq}``; one service thread per peer scans its
    inbox sequence, evaluates against the local slice with the numpy
    backend, and writes the counts back. Exchanges: one
    ``x/{seq}/{rank}`` blob per rank, blocking-get the peers'.
    ``update`` runs on EVERY rank here — stores are replicated, not
    shared. Work is statically partitioned: no cross-process steal."""

    REPLY_TIMEOUT_MS = 300_000
    POLL_TIMEOUT_MS = 2_000

    def __init__(self, client, rank: int, n_procs: int,
                 arena: BitmapArena,
                 owner_fn: Optional[Callable[[Itemset], int]] = None):
        self.client = client
        self.host_id = rank
        self.n_hosts = n_procs
        self.arena = arena
        self.gauges = ClusterGauges(n_procs)
        self._owner_fn = owner_fn
        self._xseq = 0
        self._send_seq = [0] * n_procs
        self._send_lock = threading.Lock()
        self._stop = False
        self._threads: List[threading.Thread] = []
        self._svc_error: Optional[BaseException] = None

    def owns(self, key: Itemset) -> bool:
        if self._owner_fn is not None:
            return self._owner_fn(key) == self.host_id
        return stable_hash(key) % self.n_hosts == self.host_id

    # ---------------------------------------------------------- service --
    def start_service(self) -> None:
        for peer in range(self.n_hosts):
            if peer == self.host_id:
                continue
            t = threading.Thread(target=self._serve_peer, args=(peer,),
                                 daemon=True,
                                 name=f"cluster-eval-{peer}")
            t.start()
            self._threads.append(t)

    def _serve_peer(self, peer: int) -> None:
        me, seq = self.host_id, 0
        pcache: Dict[Any, np.ndarray] = {}   # thread-private memo
        try:
            while not self._stop:
                key = f"ev/{me}/{peer}/{seq}"
                try:
                    blob = self.client.blocking_key_value_get_bytes(
                        key, self.POLL_TIMEOUT_MS)
                except Exception:   # deadline: poll the stop flag
                    continue
                descs = pickle.loads(blob)
                t0 = time.perf_counter()
                counts = _eval_descs(self.arena, descs, pcache)
                dt = time.perf_counter() - t0
                reply = pickle.dumps([np.asarray(c, np.int64)
                                      for c in counts])
                self.client.key_value_set_bytes(
                    f"er/{peer}/{me}/{seq}", reply)
                with self.gauges.lock:
                    self.gauges.eval_s[me] += dt
                    self.gauges.eval_bytes[me] += _eval_rows_bytes(
                        descs, self.arena)
                try:
                    self.client.key_value_delete(key)
                except Exception:   # pragma: no cover - best effort
                    pass
                seq += 1
        except BaseException as e:  # pragma: no cover - surfaced later
            self._svc_error = e

    def finish(self, tag: str = "fin") -> None:
        """Barrier with every rank, then stop the service threads — no
        rank may tear down its evaluator while a peer still mines."""
        self.client.wait_at_barrier(tag, self.REPLY_TIMEOUT_MS)
        self._stop = True
        for t in self._threads:
            t.join(timeout=2 * self.POLL_TIMEOUT_MS / 1000 + 5)
        if self._svc_error is not None:
            raise self._svc_error

    # ------------------------------------------------------------ engine --
    def reduce_flush(self, requests: Sequence[SweepRequest],
                     results: List[np.ndarray]) -> List[np.ndarray]:
        descs = _desc_batch(requests, self.arena)
        blob = pickle.dumps(descs)
        me = self.host_id
        sent: List[Tuple[int, int]] = []
        with self._send_lock:
            for peer in range(self.n_hosts):
                if peer == me:
                    continue
                seq = self._send_seq[peer]
                self._send_seq[peer] = seq + 1
                sent.append((peer, seq))
        for peer, seq in sent:
            self.client.key_value_set_bytes(f"ev/{peer}/{me}/{seq}",
                                            blob)
        totals = [np.asarray(c, np.int64) for c in results]
        wire = 0
        for peer, seq in sent:
            reply = self.client.blocking_key_value_get_bytes(
                f"er/{me}/{peer}/{seq}", self.REPLY_TIMEOUT_MS)
            wire += len(blob) + len(reply)
            for i, c in enumerate(pickle.loads(reply)):
                totals[i] = totals[i] + np.asarray(c, np.int64)
            try:
                self.client.key_value_delete(f"er/{me}/{peer}/{seq}")
            except Exception:       # pragma: no cover - best effort
                pass
        with self.gauges.lock:
            self.gauges.net_bytes += wire
            self.gauges.reduced_flushes += 1
        return totals

    def exchange(self, pairs: Sequence, update: Optional[Callable] = None
                 ) -> Any:
        seq = self._xseq
        self._xseq += 1
        me = self.host_id
        blob = pickle.dumps(list(pairs))
        self.client.key_value_set_bytes(f"x/{seq}/{me}", blob)
        parts: Dict[int, list] = {me: list(pairs)}
        wire = 0
        for peer in range(self.n_hosts):
            if peer == me:
                continue
            got = self.client.blocking_key_value_get_bytes(
                f"x/{seq}/{peer}", self.REPLY_TIMEOUT_MS)
            wire += len(blob) + len(got)
            parts[peer] = pickle.loads(got)
        with self.gauges.lock:
            self.gauges.net_bytes += wire
        merged = [x for h in sorted(parts) for x in parts[h]]
        return update(merged) if update else merged

    def level_wait(self, sched) -> None:
        sched.wait_all()            # static partition: local quiescence
                                    # suffices; exchanges align ranks

    def allreduce_counts(self, local: np.ndarray) -> np.ndarray:
        """Sum per-item partial counts across ranks (level 1 over the
        partitioned axis) — the KV-store stand-in for ``psum``."""
        total = np.asarray(local, np.int64).copy()
        merged = self.exchange([np.asarray(local, np.int64)])
        for i, arr in enumerate(merged):
            if i != self.host_id:
                total += arr
        return total


# --------------------------------------------------------------- driving --
def _drive(store: BitmapArena, runtime, min_support: int, max_k: int, *,
           policy: str, n_workers: int, granularity: str,
           cache_size: int, item_counts) -> Tuple[Dict[Itemset, int],
                                                  "fpm.MiningMetrics"]:
    """One host's driver: level 1 from GLOBAL item counts (identical on
    every host), then the shared engine cores with the cluster context
    threaded through the runtime. Representation is pinned to "bitmap":
    sparse payloads are positional in the LOCAL slice and must not leak
    into cross-host descriptors."""
    t0 = time.perf_counter()   # monotonic: finalize() subtracts from it
    supports = np.asarray(item_counts)
    result: Dict[Itemset, int] = {
        (i,): int(supports[i]) for i in range(store.n_base)
        if supports[i] >= min_support}
    frequent = sorted(result)
    run = fpm.MiningRun(store, policy=policy, n_workers=n_workers,
                        granularity=granularity, cache_size=cache_size,
                        representation="bitmap",
                        item_counts=item_counts, runtime=runtime)
    # level-1 frequent count is GLOBAL: bill it on host 0 only, so the
    # merged view neither double-counts it (depth-first sums hosts) nor
    # loses it (levelwise takes host 0)
    if runtime.cluster.host_id == 0:
        run.metrics.frequent += len(frequent)
    try:
        fpm.mine_more(run, min_support, max_k, result, frequent)
    finally:
        run.close()
    return result, run.finalize(t0)


_SUM_FIELDS = ("buckets", "cache_hits", "cache_misses",
               "cache_partial_hits", "rows_touched", "bytes_swept",
               "h2d_bytes", "flushes", "d2d_bytes", "migrations",
               "dense_sweeps", "sparse_sweeps", "sparse_bytes_swept",
               "sparse_rows", "densify_ops", "densify_bytes",
               "sparsify_ops", "sparsify_bytes")
_MAX_FIELDS = ("wall_s", "levels", "peak_retained_bitmaps",
               "peak_bytes_retained")


def merge_metrics(per_host: List["fpm.MiningMetrics"],
                  gauges: ClusterGauges, granularity: str
                  ) -> "fpm.MiningMetrics":
    """One cluster-wide metrics view. Per-host gauges SUM; lockstep
    level gauges take host 0 (every levelwise driver counts the global
    frontier) except under depth-first, where each host counts only its
    owned subtrees and the sum is the global figure."""
    m = fpm.MiningMetrics(n_devices=per_host[0].n_devices)
    for f in _SUM_FIELDS:
        setattr(m, f, sum(getattr(h, f) for h in per_host))
    for f in _MAX_FIELDS:
        setattr(m, f, max(getattr(h, f) for h in per_host))
    if granularity == "depth-first":
        m.candidates = sum(h.candidates for h in per_host)
        m.frequent = sum(h.frequent for h in per_host)
    else:
        m.candidates = per_host[0].candidates
        m.frequent = per_host[0].frequent
    m.representation = per_host[0].representation
    # scheduler/per_device/per_host rows all travel the repro.obs
    # schema: counters sum, derived ratios rebuild after the merge
    m.scheduler = obs_schema.scheduler_stats(obs_schema.merge_counters(
        [h.scheduler for h in per_host],
        obs_schema.SCHEDULER_COUNTERS))
    m.per_device = [
        obs_schema.device_stats({**row, "host": hid})
        for hid, h in enumerate(per_host) for row in h.per_device]
    total_req = sum(int(r["sweep_requests"]) for r in m.per_device)
    m.batch_occupancy = (total_req / m.flushes if m.flushes else 0.0)
    g = gauges.snapshot()
    m.n_hosts = len(per_host)
    m.net_bytes = g["net_bytes"]
    m.steal_net = g["steal_net"]
    m.cross_steals = g["cross_steals"]
    m.per_host = [
        obs_schema.host_stats(
            {"host": hid,
             "bytes_swept": h.bytes_swept,
             "sweep_s": sum(float(r.get("sweep_s", 0.0))
                            for r in h.per_device),
             "eval_s": gauges.eval_s[hid],
             "eval_bytes": gauges.eval_bytes[hid]})
        for hid, h in enumerate(per_host)]
    return m


def mine_cluster(bitmaps: np.ndarray, min_support: int, *,
                 hosts: int, policy: str = "clustered",
                 n_workers: int = 8, max_k: int = 8,
                 cache_size: int = 32, granularity: str = "bucket",
                 backend: str = "auto", max_batch: int = MAX_BATCH,
                 flush_us: float = FLUSH_US, item_counts=None,
                 owner_fn: Optional[Callable[[Itemset], int]] = None,
                 tracer=None,
                 ) -> Tuple[Dict[Itemset, int], "fpm.MiningMetrics"]:
    """Loopback-cluster ``mine()``: N logical hosts in one process,
    each with its own word-sliced arena, scheduler and dispatchers,
    reduction by direct peer evaluation. Bit-identical to single-host
    ``mine()`` — and the tier-1-testable twin of the real-process
    entry point :func:`mine_distributed_process`.

    ``owner_fn`` overrides the ``stable_hash`` bucket→host map (tests
    use it to force every bucket onto one host so cross-host steals
    MUST fire). ``tracer`` (a shared :class:`repro.obs.Tracer`) merges
    every host's lanes into ONE global timeline — each host's workers,
    dispatchers and driver record under its own Chrome-trace pid."""
    if hosts < 2:
        raise ValueError(f"mine_cluster needs hosts >= 2, got {hosts}")
    n_items, n_w = bitmaps.shape
    ranges = partition_words(n_w, hosts)
    arenas = [BitmapArena.from_bitmaps(
        np.ascontiguousarray(bitmaps[:, a:b])) for a, b in ranges]
    if item_counts is None:
        item_counts = tidlist.popcount32(bitmaps).sum(axis=1)
    bus = _LoopbackBus(hosts, arenas)
    ctxs = [LoopbackContext(bus, h, owner_fn) for h in range(hosts)]
    runtimes = [fpm.EngineRuntime(arenas[h], policy=policy,
                                  n_workers=n_workers,
                                  granularity=granularity,
                                  backend=backend, max_batch=max_batch,
                                  flush_us=flush_us, cluster=ctxs[h],
                                  tracer=tracer)
                for h in range(hosts)]
    bus.scheds = [rt.sched for rt in runtimes]
    bus.install_steal()
    results: List[Optional[Dict]] = [None] * hosts
    mets: List[Optional[fpm.MiningMetrics]] = [None] * hosts
    errs: List[Optional[BaseException]] = [None] * hosts

    def driver(h: int) -> None:
        try:
            results[h], mets[h] = _drive(
                arenas[h], runtimes[h], min_support, max_k,
                policy=policy, n_workers=n_workers,
                granularity=granularity, cache_size=cache_size,
                item_counts=item_counts)
        except BaseException as e:  # noqa: BLE001 - peer must unblock
            errs[h] = e
            bus.abort()

    threads = [threading.Thread(target=driver, args=(h,),
                                name=f"cluster-driver-{h}")
               for h in range(hosts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        for e in errs:
            if e is not None and not isinstance(e, RuntimeError):
                raise e
        for e in errs:
            if e is not None:
                raise e
    finally:
        for rt in runtimes:
            rt.shutdown()
    merged = merge_metrics(mets, bus.gauges, granularity)
    return results[0], merged


def mine_distributed_process(bitmaps: np.ndarray, min_support: int, *,
                             rank: int, n_procs: int, coordinator: str,
                             policy: str = "clustered",
                             n_workers: int = 4, max_k: int = 6,
                             cache_size: int = 32,
                             granularity: str = "bucket",
                             backend: str = "numpy",
                             max_batch: int = MAX_BATCH,
                             flush_us: float = FLUSH_US,
                             ) -> Tuple[Dict[Itemset, int],
                                        "fpm.MiningMetrics"]:
    """One rank of a real 2+-process mine over ``jax.distributed``.
    Every process loads the same packed database, keeps only its
    word-slice, and drives the shared engine cores with the KV-store
    transport. Returns this rank's (full, exchanged) result and
    metrics — ranks all hold the identical result dict at the end."""
    import jax
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=n_procs, process_id=rank)
    from jax._src import distributed as _jdist
    client = _jdist.global_state.client
    n_items, n_w = bitmaps.shape
    a, b = partition_words(n_w, n_procs)[rank]
    arena = BitmapArena.from_bitmaps(
        np.ascontiguousarray(bitmaps[:, a:b]))
    ctx = DistributedContext(client, rank, n_procs, arena)
    ctx.start_service()
    try:
        # level 1 two-phase, like every later level: local partial
        # popcount over owned words, allreduced through the transport
        local = tidlist.popcount32(
            np.ascontiguousarray(bitmaps[:, a:b])).sum(axis=1)
        item_counts = ctx.allreduce_counts(local)
        runtime = fpm.EngineRuntime(arena, policy=policy,
                                    n_workers=n_workers,
                                    granularity=granularity,
                                    backend=backend,
                                    max_batch=max_batch,
                                    flush_us=flush_us, cluster=ctx)
        try:
            result, met = _drive(arena, runtime, min_support, max_k,
                                 policy=policy, n_workers=n_workers,
                                 granularity=granularity,
                                 cache_size=cache_size,
                                 item_counts=item_counts)
        finally:
            ctx.finish(tag=f"fin-{granularity}-{min_support}")
            runtime.shutdown()
    except BaseException:
        ctx._stop = True
        raise
    g = ctx.gauges.snapshot()
    met.n_hosts = n_procs
    met.net_bytes = g["net_bytes"]
    met.steal_net = g["steal_net"]
    met.cross_steals = g["cross_steals"]
    return result, met
