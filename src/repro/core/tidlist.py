"""Transaction-ID (TID) bitmap machinery.

The paper's per-task computation is a TID-list join: support(itemset) =
|∩_{i∈itemset} tidlist(i)|. On TPU (and for GIL-released numpy in the
shared-memory scheduler) TID lists are packed uint32 bitmaps: the join is
AND + popcount — VPU work that the Pallas ``bitmap_join`` kernel tiles so
the shared *prefix* bitmap stays VMEM-resident (the paper's cache reuse,
re-expressed; DESIGN.md §3).
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

WORD = 32


def n_words(n_transactions: int) -> int:
    return (n_transactions + WORD - 1) // WORD


def pack_database(db: Sequence[Sequence[int]], n_items: int,
                  return_counts: bool = False):
    """db: list of transactions (item id lists) -> [n_items, W] uint32.

    Packs per-word directly — O(n_items × W) memory, never the dense
    [n_items, n_transactions] bool matrix (which on scaled Quest/retail
    profiles could exceed the packed bitmaps by 32× and blow host
    memory before mining even starts).

    With ``return_counts=True`` also returns the per-item ones count
    (``[n_items] int64``) tallied during the same pass — the level-1
    supports and density seed, with no post-hoc popcount sweep over
    the packed words."""
    m = len(db)
    out = np.zeros((n_items, n_words(m)), dtype=np.uint32)
    counts = np.zeros(n_items, dtype=np.int64)
    for t, txn in enumerate(db):
        word = t >> 5
        bit = np.uint32(1 << (t & 31))
        for i in txn:
            if not out[i, word] & bit:
                counts[i] += 1
            out[i, word] |= bit
    if return_counts:
        return out, counts
    return out


def pack_bool(bits: np.ndarray) -> np.ndarray:
    """[I, T] bool -> [I, W] uint32 (little-endian bit order per word)."""
    i, t = bits.shape
    w = n_words(t)
    padded = np.zeros((i, w * WORD), dtype=bool)
    padded[:, :t] = bits
    packed = np.packbits(padded.reshape(i, w, WORD)[:, :, ::-1], axis=-1)
    return packed.view(">u4").astype(np.uint32).reshape(i, w)


def unpack_bool(packed: np.ndarray, n_transactions: int) -> np.ndarray:
    """[I, W] uint32 -> [I, T] bool."""
    i, w = packed.shape
    be = packed.astype(">u4")
    by = be.view(np.uint8).reshape(i, w, 4)
    bits = np.unpackbits(by, axis=-1).reshape(i, w * WORD).astype(bool)
    # restore per-word little-endian bit order
    bits = bits.reshape(i, w, WORD)[:, :, ::-1].reshape(i, w * WORD)
    return bits[:, :n_transactions]


def popcount32(x: np.ndarray) -> np.ndarray:
    """Vectorized popcount for uint32 arrays (numpy, GIL-released)."""
    if hasattr(np, "bitwise_count"):          # numpy >= 2.0: one ufunc pass
        return np.bitwise_count(x).astype(np.int64)
    if x.dtype != np.uint32:                  # hot path: no copy when the
        x = x.astype(np.uint32)               # input is already uint32
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(np.int64)


def intersect(bitmaps: np.ndarray) -> np.ndarray:
    """AND-reduce [k, W] -> [W]."""
    out = bitmaps[0].copy()
    for b in bitmaps[1:]:
        out &= b
    return out


def support_of(bitmap_rows: np.ndarray) -> int:
    """|∩ rows| for a [k, W] stack of bitmaps."""
    return int(popcount32(intersect(bitmap_rows)).sum())


# Target working-set size for one [chunk, W] AND+popcount temporary:
# roughly half an L2 slice, so the chunk stays cache-resident even on
# scaled datasets where W grows with the transaction count.
CHUNK_TARGET_BYTES = 4 << 20


def support_counts(prefix: np.ndarray, exts: np.ndarray,
                   chunk: int | None = None) -> np.ndarray:
    """counts[e] = |prefix ∩ exts[e]|. prefix: [W]; exts: [E, W].

    This is the numpy bucket-sweep: one fused AND+popcount pass with the
    prefix row broadcast (cache-resident) across all extensions — the
    vectorized analogue of the Pallas bitmap_join kernel. ``chunk``
    bounds the [chunk, W] temporary; by default it adapts to W so the
    temporary stays ~CHUNK_TARGET_BYTES regardless of dataset scale."""
    e, w = exts.shape
    if e == 1:
        # single-extension fast path (deep, narrow equivalence classes):
        # skip the [E, W] broadcast temporary entirely
        return popcount32(exts[0] & prefix).sum(keepdims=True)
    if chunk is None:
        chunk = max(64, CHUNK_TARGET_BYTES // max(w * (WORD // 8), 1))
    if e <= chunk:
        return popcount32(exts & prefix[None, :]).sum(axis=1)
    out = np.empty(e, dtype=np.int64)
    for lo in range(0, e, chunk):
        hi = min(lo + chunk, e)
        out[lo:hi] = popcount32(exts[lo:hi] & prefix[None, :]).sum(axis=1)
    return out


# ---------------------------------------------------------------------------
# Sparse (tid-list / dEclat diffset) row helpers
# ---------------------------------------------------------------------------
# A *tid* is a global bit position on the concatenated segment word
# axis: tid = 32 * word_index + bit. Packing zero-fills past the real
# transaction count and compact() concatenates segments in order, so a
# sparse row's tids stay valid across ingest and compaction without
# rewriting.

REP_BITMAP, REP_TIDLIST, REP_DIFFSET = 0, 1, 2
REP_NAMES = ("bitmap", "tidlist", "diffset")


def bitmap_to_tids(words: np.ndarray) -> np.ndarray:
    """[W] uint32 word-column -> sorted uint32 tids of its set bits."""
    w = words.shape[0]
    if w == 0:
        return np.zeros(0, np.uint32)
    bits = unpack_bool(words[None, :], w * WORD)[0]
    return np.flatnonzero(bits).astype(np.uint32)


def tids_to_bitmap(tids: np.ndarray, n_words_: int) -> np.ndarray:
    """Sorted uint32 tids -> [n_words_] uint32 word-column."""
    out = np.zeros(n_words_, np.uint32)
    if len(tids):
        t = np.asarray(tids, np.uint32)
        np.bitwise_or.at(out, t >> np.uint32(5),
                         np.uint32(1) << (t & np.uint32(31)))
    return out


def gather_bits(tids: np.ndarray, ext_words: np.ndarray) -> np.ndarray:
    """bit test of ``ext_words`` at each tid -> [len(tids)] bool.

    The sparse sweep primitive: O(|tids|) gathered words regardless of
    row width W — exactly what the Pallas ``gather_intersect_many``
    kernel batches on device."""
    if len(tids) == 0:
        return np.zeros(0, bool)
    t = np.asarray(tids, np.uint32)
    return ((ext_words[t >> np.uint32(5)] >> (t & np.uint32(31)))
            & np.uint32(1)).astype(bool)


def gather_count(tids: np.ndarray, ext_words: np.ndarray) -> int:
    """|tids ∩ ext| for one sparse row against one word-column."""
    if len(tids) == 0:
        return 0
    t = np.asarray(tids, np.uint32)
    return int((((ext_words[t >> np.uint32(5)] >> (t & np.uint32(31)))
                 & np.uint32(1))).sum())


def sorted_difference(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a \\ b for sorted unique uint32 arrays (diffset reconstruction:
    tids(P) = tids(parent) \\ diffset). Binary-search based — ``np.isin``
    re-sorts the concatenation, which dominates diffset-chain walks."""
    if len(b) == 0 or len(a) == 0:
        return a
    idx = np.searchsorted(b, a)
    np.minimum(idx, len(b) - 1, out=idx)
    return a[b[idx] != a]


def partition_words(n_words_: int, n_hosts: int) -> List[Tuple[int, int]]:
    """Contiguous balanced word ranges ``[(w0, w1), ...]`` over the
    transaction axis, one per host.

    Multi-host mining slices the packed ``[n_items, W]`` database on
    the word (= 32-transaction block) axis: host ``h`` builds its
    local :class:`BitmapArena` from ``bitmaps[:, w0:w1]`` and sweeps
    only those columns. Word granularity keeps every host's slice a
    plain view with no bit surgery, and the remainder is spread over
    the leading hosts so slice widths differ by at most one word.
    Hosts beyond ``n_words_`` get empty ``(w, w)`` ranges — legal, the
    backends skip zero-width segments."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    base, extra = divmod(n_words_, n_hosts)
    ranges: List[Tuple[int, int]] = []
    w = 0
    for h in range(n_hosts):
        width = base + (1 if h < extra else 0)
        ranges.append((w, w + width))
        w += width
    return ranges


# ---------------------------------------------------------------------------
# BitmapArena: the device-resident home of every TID bitmap
# ---------------------------------------------------------------------------

ARENA_BACKINGS = ("auto", "numpy", "jax")


class BitmapArena:
    """Append-only ``[N, W]`` uint32 row store with integer handles.

    Every bitmap the mining engines touch lives here: the pinned item
    bitmaps loaded once by :meth:`from_bitmaps` (handle == item id),
    cached prefix intersections, and the depth-first engine's
    materialized child bitmaps. Tasks pass *handles* around instead of
    floating ndarrays, so the sweep dispatcher can batch many workers'
    requests into one multi-prefix kernel launch without re-marshalling
    bitmap payloads.

    Rows are refcounted: :meth:`push`/:meth:`materialize` return a
    handle with refcount 1, :meth:`retain`/:meth:`release` adjust it,
    and a row whose count reaches zero goes on a free list — the next
    push reuses the slot, so the depth-first engine's churn of child
    bitmaps recycles storage instead of growing ``N`` without bound.
    Rows below ``n_base`` (the item bitmaps) are pinned: retain/release
    on them are no-ops.

    Device residency (``backing``):
      "auto"   a jax mirror is created lazily on the first
               :meth:`device_rows` call and kept in sync incrementally —
               only rows appended or recycled since the last sync are
               uploaded, and those payload bytes accumulate in
               ``h2d_bytes`` (index uploads, 4 B/row vs ``4·W`` B of
               payload, are not counted).
      "jax"    same, but the initial upload happens eagerly at load.
      "numpy"  host-only; :meth:`device_rows` returns None, so Pallas
               backends fall back to per-batch host gathers (the old
               transfer-bound behaviour, kept as the A/B baseline for
               the h2d benchmark).

    Sharded mode (``n_shards`` > 1, optionally with a ``devices`` list
    from a jax mesh): one mirror per shard. Pinned item rows are
    *replicated* into every shard's mirror; a materialized row is
    *owned* by the shard that created it (``push``/``materialize``
    take a ``shard=`` argument) and lives only in its owner's mirror.
    When a sweep on shard *s* references a row owned by shard *t*, the
    row is fetched into *s*'s mirror on demand and the payload is
    counted in the ``d2d_bytes`` gauge — the modeled cross-device
    traffic (on this container's virtual devices the bits physically
    route through the host, but the gauge records what a real mesh
    would ship device-to-device). :meth:`migrate` re-owners rows
    explicitly (the scheduler's cross-device bucket steal) and counts
    the same gauge. Host-only ("numpy") backings keep the identical
    ownership/residency bookkeeping via :meth:`note_access`, so the
    tier-1 CPU suite exercises the same d2d accounting without a
    device in sight.

    Segmented transaction axis (streaming ingest): the store is a list
    of per-segment ``[cap, W_seg]`` word-column blocks sharing one slot
    space. :meth:`add_segment` appends a FRESH block holding the new
    transactions' packed item bitmaps — the existing segments are never
    repacked or re-uploaded, so an ingest's device cost is exactly the
    new segment's payload. A row's logical bitmap is the concatenation
    of its per-segment words; ``cover[h]`` records how many leading
    segments a row has real data in (base item rows are extended by
    every ``add_segment`` and always cover all segments; pushed /
    materialized rows cover the segments that existed when they were
    created, and read as zeros beyond). Sweeps may restrict themselves
    to a segment subset — the streaming engine's support-delta pass
    reads ONLY the freshly ingested segments.

    Thread-safe: workers push/release concurrently; each shard's
    mirror is touched only by that shard's dispatcher thread. Growth
    reallocates the backing store, but handed-out row views keep the
    old buffer alive and live rows are never mutated, so views stay
    content-correct.
    """

    GROW = 2                      # capacity doubling factor

    def __init__(self, n_words_: int, backing: str = "auto",
                 capacity: int = 64, n_shards: int = 1,
                 devices: Optional[Sequence] = None):
        if backing not in ARENA_BACKINGS:
            raise ValueError(
                f"arena backing must be one of {ARENA_BACKINGS}, "
                f"got {backing!r}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if devices is not None and len(devices) != n_shards:
            raise ValueError(
                f"devices list ({len(devices)}) must match n_shards "
                f"({n_shards})")
        self.backing = backing
        self.n_shards = n_shards
        self.devices = list(devices) if devices is not None else None
        cap = max(capacity, 1)
        # per-segment word-column stores sharing one slot space;
        # segment 0 is the load-time database
        self._seg_words: List[int] = [n_words_]
        # owning tenant per segment (multi-tenant serving): None =
        # default/single-tenant. Purely bookkeeping — sweeps restrict
        # by explicit segment lists, so tenants isolate by construction
        self._seg_tenant: List[object] = [None]
        self._stores: List[np.ndarray] = [np.zeros((cap, n_words_),
                                                   np.uint32)]
        self._refs = np.zeros(cap, np.int32)
        # owning shard per row; -1 = replicated (pinned base rows)
        self._owner = np.full(cap, -1, np.int32)
        # leading segments a row has data in (see class docstring)
        self._cover = np.zeros(cap, np.int32)
        self.n_rows = 0               # high-water mark (rows ever used)
        self.n_base = 0               # pinned item rows [0, n_base)
        self._free: List[int] = []
        self._lock = threading.Lock()
        # live-row gauges (rows beyond the pinned base — the engines'
        # retained-bitmap memory bound)
        self.live_extra = 0
        self.peak_live_extra = 0
        # per-(shard, segment) mirror state, all dicts keyed by segment
        # id so freshly added segments default to "nothing synced". A
        # handle h < _dev_n[s][g] is resident in mirror (s, g) iff
        # h not in _invalid[s][g]; _invalid holds foreign rows never
        # fetched plus recycled slots whose mirror content went stale.
        self._dev: List[dict] = [dict() for _ in range(n_shards)]
        self._dev_n: List[dict] = [dict() for _ in range(n_shards)]
        self._invalid: List[dict] = [dict() for _ in range(n_shards)]
        # rows whose transfer to this shard was already billed as d2d
        # (by migrate) but whose payload has not physically landed in
        # the mirror yet — their eventual placement is free
        self._migrated_in: List[dict] = [dict() for _ in range(n_shards)]
        self.h2d_bytes = 0            # bitmap payload uploaded, total
        self.d2d_bytes = 0            # modeled cross-shard row traffic
        self.migrations = 0           # rows re-owned by migrate()
        self.compaction_bytes = 0     # host bytes repacked by compact()
        self.compactions = 0          # compact() calls that merged
        # observability: None = off (the engines attach a tracer;
        # h2d/d2d/compaction then emit spans on the calling lane)
        self.tracer = None
        # hybrid sparse representation: per-slot tag plus a
        # variable-length tid/diffset store sharing the same handle
        # space, refcounting, coverage and accounting as word-columns.
        # Sparse slots carry NO payload in the word-column stores or
        # device mirrors; their tid arrays ship per-launch (billed at
        # actual nbytes) and cross-shard reads bill d2d once per
        # residency via _note_sparse.
        self._rep = np.zeros(cap, np.int8)        # REP_* tag per slot
        self._sparse: dict = {}                   # handle -> uint32 tids
        self._anchor: dict = {}                   # diffset -> parent handle
        self._ssupport: dict = {}                 # handle -> support
        self._sparse_res: List[set] = [set() for _ in range(n_shards)]
        self.sparse_pushed = 0        # sparse rows ever created
        self.sparse_live = 0          # live sparse rows gauge
        self.sparse_bytes_live = 0    # live sparse payload bytes
        self.peak_sparse_bytes = 0
        self.densify_ops = 0          # sparse->dense conversions billed
        self.densify_bytes = 0
        self.sparsify_ops = 0         # dense->sparse conversions billed
        self.sparsify_bytes = 0

    # ---------------------------------------------------------- segments --
    @property
    def n_words(self) -> int:
        """Total logical row width (words) across all segments."""
        return sum(self._seg_words)

    @property
    def n_segments(self) -> int:
        return len(self._seg_words)

    def seg_words(self, seg: int) -> int:
        return self._seg_words[seg]

    def seg_nbytes(self, seg: int) -> int:
        """Payload bytes of one segment's pinned base rows — what an
        ingest must upload to a device mirror (and nothing more)."""
        return self.n_base * self._seg_words[seg] * 4

    def seg_tenant(self, seg: int):
        """Owning tenant of one segment (None = default)."""
        return self._seg_tenant[seg]

    def tenant_segments(self, tenant) -> Tuple[int, ...]:
        """All segment ids owned by ``tenant``, ascending — the
        segment set every one of that tenant's sweeps restricts to."""
        return tuple(g for g, t in enumerate(self._seg_tenant)
                     if t == tenant)

    def _covered(self, handle: int, seg: int) -> bool:
        return seg < int(self._cover[handle])

    def n_words_upto(self, upto: int) -> int:
        """Total row width (words) of the first ``upto`` segments."""
        return sum(self._seg_words[:upto])

    def compact(self, upto: int) -> int:
        """Merge the first ``upto`` segments into one wide word-column
        store (LSM-style). Handles, refcounts, owners and the free list
        are untouched — only the segment axis collapses, so the
        per-segment sweep loop and the jit shape zoo stop growing with
        ingest count. Segments at index >= ``upto`` shift down by
        ``upto - 1``; a row's coverage is remapped accordingly (a row
        that covered any merged segment now covers the merged block —
        its store content beyond the old coverage is already zero, so
        reads stay identical). Host repack bytes are billed to
        ``compaction_bytes``. Device mirrors are merged device-side up
        to the least-synced row count; rows beyond that re-sync (and
        re-bill) on the next :meth:`device_rows`, which for the
        streaming engine's fully-synced mirrors means no extra h2d.

        Must not run concurrently with sweeps that hold segment ids —
        the streaming engine serializes it with refresh/ingest (and
        gates it behind in-flight query sweeps). Refuses (returns 0)
        when the merge prefix spans more than one tenant: positional
        merging would fuse foreign transactions into one segment and
        every tenant-restricted segment list would go stale.
        Returns the number of segments removed (``upto - 1``)."""
        t0 = time.perf_counter() if self.tracer is not None else 0.0
        with self._lock:
            if not 2 <= upto <= len(self._seg_words):
                return 0
            if len(set(self._seg_tenant[:upto])) > 1:
                return 0
            new_w = sum(self._seg_words[:upto])
            merged = np.concatenate(self._stores[:upto], axis=1)
            self._stores[:upto] = [np.ascontiguousarray(merged)]
            self._seg_words[:upto] = [new_w]
            self._seg_tenant[:upto] = [self._seg_tenant[0]]
            self.compaction_bytes += self.n_rows * new_w * 4
            self.compactions += 1
            # cover remap: >= upto -> minus (upto-1); in (0, upto) -> 1
            cov = self._cover
            self._cover = np.where(
                cov >= upto, cov - (upto - 1),
                np.minimum(cov, 1)).astype(np.int32)
            for s in range(self.n_shards):
                self._merge_mirror(s, upto)
            if self.tracer is not None:
                self.tracer.span(
                    "compaction", t0, cat="arena",
                    args={"merged": upto,
                          "bytes": self.n_rows * new_w * 4})
            return upto - 1

    def _merge_mirror(self, shard: int, upto: int) -> None:
        # caller holds self._lock
        dn, dev = self._dev_n[shard], self._dev[shard]
        inv, mig = self._invalid[shard], self._migrated_in[shard]

        def _remap(d: dict, merged_val) -> dict:
            out = {0: merged_val}
            for g in sorted(k for k in d if k >= upto):
                out[g - (upto - 1)] = d[g]
            return out
        nmin = min(dn.get(g, 0) for g in range(upto))
        self._dev_n[shard] = _remap(dn, nmin)
        # a row stale in ANY merged segment is stale in the merged block
        inv_m = set()
        for g in range(upto):
            inv_m |= {h for h in inv.get(g, ()) if h < nmin}
        self._invalid[shard] = _remap(inv, inv_m)
        mig_m = set()
        for g in range(upto):
            mig_m |= mig.get(g, set())
        self._migrated_in[shard] = _remap(mig, mig_m)
        if not self.device_enabled:
            # host-only backing: residency bookkeeping merged above,
            # no physical mirrors to touch
            self._dev[shard] = {}
            return
        blocks = [dev.get(g) for g in range(upto)]
        if nmin > 0 and all(b is not None for b in blocks):
            import jax.numpy as jnp
            new_dev = _remap(dev, jnp.concatenate(
                [b[:nmin] for b in blocks], axis=1))
        else:
            # nothing fully mirrored yet: the merged block re-syncs
            # from scratch on the next device_rows
            self._dev_n[shard][0] = 0
            self._invalid[shard][0] = set()
            new_dev = _remap(dev, None)
            del new_dev[0]
        self._dev[shard] = new_dev

    def add_segment(self, base_bitmaps: np.ndarray,
                    tenant=None) -> int:
        """Append a fresh transaction segment: ``base_bitmaps`` is the
        ``[n_base, W_seg]`` packed item bitmaps of the NEW transactions
        only. Existing segments are untouched — no repack, no
        re-upload; with eager ("jax") backing the new segment's base
        payload is mirrored immediately and its bytes (exactly
        :meth:`seg_nbytes`) are the entire h2d bill. ``tenant`` tags
        the segment's owner for multi-tenant serving (None = default).
        Returns the new segment id."""
        bm = np.ascontiguousarray(base_bitmaps, dtype=np.uint32)
        if bm.ndim != 2 or bm.shape[0] != self.n_base:
            raise ValueError(
                f"segment bitmaps must be [n_base={self.n_base}, W_seg], "
                f"got {bm.shape}")
        with self._lock:
            w = bm.shape[1]
            seg = len(self._seg_words)
            cap = self._refs.shape[0]
            store = np.zeros((cap, w), np.uint32)
            store[:self.n_base] = bm
            self._seg_words.append(w)
            self._seg_tenant.append(tenant)
            self._stores.append(store)
            # base item rows now extend into the new segment; live
            # non-base rows keep their creation-time coverage and read
            # as zeros there
            self._cover[:self.n_base] = seg + 1
        if self.backing == "jax":
            for s in range(self.n_shards):
                self.device_rows(s, segment=seg)   # eager, W_seg only
        return seg

    # ------------------------------------------------------------- load --
    @classmethod
    def from_bitmaps(cls, bitmaps: np.ndarray, backing: str = "auto",
                     n_shards: int = 1, devices: Optional[Sequence] = None
                     ) -> "BitmapArena":
        """Load packed item bitmaps as the pinned base rows (handle ==
        item id). One copy, once — every later sweep references rows by
        handle instead of re-marshalling them."""
        n, w = bitmaps.shape
        arena = cls(w, backing, capacity=max(64, 2 * n),
                    n_shards=n_shards, devices=devices)
        arena._stores[0][:n] = bitmaps
        arena._refs[:n] = 1
        arena._cover[:n] = 1
        arena.n_rows = arena.n_base = n
        if backing == "jax":
            for s in range(arena.n_shards):
                arena.device_rows(s)  # eager initial (replicated) upload
        return arena

    @classmethod
    def from_database(cls, db: Sequence[Sequence[int]], n_items: int,
                      backing: str = "auto") -> "BitmapArena":
        """pack_database straight into the arena (no intermediate)."""
        return cls.from_bitmaps(pack_database(db, n_items), backing)

    # ------------------------------------------------------ row lifecycle --
    def _alloc_slot(self) -> int:
        # caller holds self._lock
        if self._free:
            slot = self._free.pop()
            for s in range(self.n_shards):
                dn = self._dev_n[s]
                for g in range(len(self._seg_words)):
                    if slot < dn.get(g, 0):
                        # mirror content stale in every segment block
                        self._invalid[s].setdefault(g, set()).add(slot)
                    mig = self._migrated_in[s].get(g)
                    if mig:
                        mig.discard(slot)  # old row is gone
            return slot
        if self.n_rows == self._refs.shape[0]:
            cap = self.GROW * self._refs.shape[0]
            for g, old in enumerate(self._stores):
                store = np.zeros((cap, self._seg_words[g]), np.uint32)
                store[:self.n_rows] = old[:self.n_rows]
                self._stores[g] = store
            refs = np.zeros(cap, np.int32)
            refs[:self.n_rows] = self._refs[:self.n_rows]
            owner = np.full(cap, -1, np.int32)
            owner[:self.n_rows] = self._owner[:self.n_rows]
            cover = np.zeros(cap, np.int32)
            cover[:self.n_rows] = self._cover[:self.n_rows]
            rep = np.zeros(cap, np.int8)
            rep[:self.n_rows] = self._rep[:self.n_rows]
            self._refs, self._owner, self._cover = refs, owner, cover
            self._rep = rep
        slot = self.n_rows
        self.n_rows += 1
        return slot

    def _bump_live(self) -> None:
        self.live_extra += 1
        self.peak_live_extra = max(self.peak_live_extra, self.live_extra)

    def push(self, row: np.ndarray, shard: int = 0,
             cover: Optional[int] = None) -> int:
        """Append (or recycle a slot for) one bitmap row; refcount 1.
        ``shard`` records the owning shard in sharded mode. Without
        ``cover``, ``row`` is the full-width concatenation over all
        segments; with ``cover=c``, ``row`` spans only the first ``c``
        segments (:meth:`n_words_upto`) and the slot is zeroed beyond —
        an overlapped refresh pushes rows at its generation boundary
        even after an ingest has appended newer segments."""
        with self._lock:
            slot = self._alloc_slot()
            cov = len(self._seg_words) if cover is None else cover
            off = 0
            for g, w in enumerate(self._seg_words):
                if g < cov:
                    self._stores[g][slot] = row[off:off + w]
                    off += w
                else:
                    self._stores[g][slot] = 0
            self._refs[slot] = 1
            self._owner[slot] = shard
            self._cover[slot] = cov
            self._rep[slot] = REP_BITMAP
            self._bump_live()
            return slot

    def materialize(self, prefix_handle: int, ext_handle: int,
                    shard: int = 0) -> int:
        """``row(prefix) ∧ row(ext)`` appended in place — the depth-first
        parent→child handoff, with no floating temporary. The new row is
        owned by ``shard`` (the materializing worker's device) and
        covers the segments both parents cover (beyond that it is
        zeroed, so recycled-slot garbage can never leak into a read)."""
        with self._lock:
            slot = self._alloc_slot()
            cov = min(int(self._cover[prefix_handle]),
                      int(self._cover[ext_handle]))
            for g, store in enumerate(self._stores):
                if g < cov:
                    np.bitwise_and(store[prefix_handle],
                                   store[ext_handle],
                                   out=store[slot])
                else:
                    store[slot] = 0
            self._refs[slot] = 1
            self._owner[slot] = shard
            self._cover[slot] = cov
            self._rep[slot] = REP_BITMAP
            self._bump_live()
            return slot

    # ------------------------------------------------- sparse lifecycle --
    def _push_sparse(self, rep: int, tids: np.ndarray, support: int,
                     shard: int, cover: Optional[int],
                     anchor: Optional[int] = None) -> int:
        t = np.ascontiguousarray(tids, dtype=np.uint32)
        with self._lock:
            slot = self._alloc_slot()
            self._refs[slot] = 1
            self._owner[slot] = shard
            self._cover[slot] = (len(self._seg_words) if cover is None
                                 else cover)
            self._rep[slot] = rep
            self._sparse[slot] = t
            self._ssupport[slot] = int(support)
            if anchor is not None:
                self._anchor[slot] = anchor
                if anchor >= self.n_base:     # pin the diffset's parent
                    self._refs[anchor] += 1
            self.sparse_pushed += 1
            self.sparse_live += 1
            self.sparse_bytes_live += t.nbytes
            self.peak_sparse_bytes = max(self.peak_sparse_bytes,
                                         self.sparse_bytes_live)
            self._bump_live()
            return slot

    def push_tids(self, tids: np.ndarray, shard: int = 0,
                  cover: Optional[int] = None) -> int:
        """Append one sparse row as a sorted uint32 tid-list; refcount 1.
        Shares the handle space (and refcounting / coverage / owner
        bookkeeping) with word-column rows, but carries no word-column
        payload — the slot's store words are dead and device mirrors
        keep it zeroed."""
        return self._push_sparse(REP_TIDLIST, tids, len(tids), shard,
                                 cover)

    def push_diffset(self, diff: np.ndarray, anchor: int, support: int,
                     shard: int = 0, cover: Optional[int] = None) -> int:
        """Append one dEclat diffset row: ``diff`` holds the tids of the
        *anchor* (parent prefix) row NOT in this row, so this row's tid
        set is ``tids(anchor) \\ diff`` and its support is
        ``support(anchor) - len(diff)`` (stored explicitly as
        ``support``). The anchor is retained until this row is
        released — releasing a diffset cascades one release to its
        anchor."""
        return self._push_sparse(REP_DIFFSET, diff, support, shard,
                                 cover, anchor=anchor)

    def sparsify_push(self, row: np.ndarray, shard: int = 0,
                      cover: Optional[int] = None) -> int:
        """Scan a dense word-row into a tid-list row (billed sparsify
        conversion) — the prefix cache's path when the density model
        says a freshly built intersection should live sparse."""
        t = bitmap_to_tids(row)
        with self._lock:
            self.sparsify_ops += 1
            self.sparsify_bytes += row.nbytes
        return self.push_tids(t, shard=shard, cover=cover)

    def rep_of(self, handle: int) -> int:
        """REP_BITMAP / REP_TIDLIST / REP_DIFFSET tag of a row."""
        return int(self._rep[handle])

    def rep_name(self, handle: int) -> str:
        return REP_NAMES[self.rep_of(handle)]

    def cover_of(self, handle: int) -> int:
        return int(self._cover[handle])

    def tids_of(self, handle: int) -> np.ndarray:
        """Raw sparse payload of a tid-list or diffset row (for a
        diffset this is the *difference*, not the tid set — see
        :meth:`resolve_tids`)."""
        return self._sparse[handle]

    def anchor_of(self, handle: int) -> Optional[int]:
        return self._anchor.get(handle)

    def sparse_support(self, handle: int) -> int:
        """Stored support of a sparse row (len(tids) for tid-lists,
        anchor support minus difference size for diffsets)."""
        return self._ssupport[handle]

    def resolve_tids(self, handle: int) -> np.ndarray:
        """Explicit sorted tid set of ANY row. Tid-lists are returned
        as-is; diffsets reconstruct ``tids(anchor) \\ diff`` (walking
        the anchor chain); bitmap rows are scanned — billed as a
        sparsify conversion, since it turns W words into a tid array."""
        rep = int(self._rep[handle])
        if rep == REP_TIDLIST:
            return self._sparse[handle]
        if rep == REP_DIFFSET:
            parent = self.resolve_tids(self._anchor[handle])
            return sorted_difference(parent, self._sparse[handle])
        tids = bitmap_to_tids(self.row(handle))
        with self._lock:
            self.sparsify_ops += 1
            self.sparsify_bytes += self.n_words * 4
        return tids

    def densify(self, handle: int) -> np.ndarray:
        """Full-width dense word-column of ANY row; for sparse rows
        this is a billed densify conversion (the transient bitmap a
        dense-only consumer forces)."""
        rep = int(self._rep[handle])
        if rep == REP_BITMAP:
            return self.row(handle)
        if rep == REP_TIDLIST:
            out = tids_to_bitmap(self._sparse[handle], self.n_words)
        else:
            anchor = self.densify(self._anchor[handle])
            out = anchor.copy()
            d = self._sparse[handle]
            if len(d):
                np.bitwise_and.at(
                    out, d >> np.uint32(5),
                    ~(np.uint32(1) << (d & np.uint32(31))))
        with self._lock:
            self.densify_ops += 1
            self.densify_bytes += self.n_words * 4
        return out

    def seg_tid_range(self, seg: int) -> Tuple[int, int]:
        """[lo, hi) global tid bounds of one segment — the searchsorted
        window a segment-restricted sparse sweep filters tids with."""
        lo = 32 * sum(self._seg_words[:seg])
        return lo, lo + 32 * self._seg_words[seg]

    def gather_bits_rows(self, tids: np.ndarray,
                         handles: Sequence[int]) -> np.ndarray:
        """[len(handles), len(tids)] bool: bit test of each handle's
        DENSE row at each tid — the class task's batched child carve.
        One ``np.ix_`` gather per segment serves every row at once;
        per-child :func:`gather_bits` calls pay ~10x numpy call
        overhead for the same reads."""
        out = np.zeros((len(handles), len(tids)), bool)
        if not len(tids) or not len(handles):
            return out
        hs = [int(h) for h in handles]
        for g in range(self.n_segments):
            if not self.seg_words(g):
                continue
            lo, hi = self.seg_tid_range(g)
            i0, i1 = np.searchsorted(tids, [lo, hi])
            if i0 == i1:
                continue
            t = tids[i0:i1].astype(np.int64) - lo
            w = self.seg_view(g)[np.ix_(hs, t >> 5)]
            out[:, i0:i1] = (w >> (t & 31).astype(np.uint32)[None, :]
                             ) & np.uint32(1)
        return out

    def owner_of(self, handle: int) -> int:
        """Owning shard of a row; -1 for replicated (pinned base) rows."""
        if handle < self.n_base:
            return -1
        return int(self._owner[handle])

    def migrate(self, handles: Sequence[int], dst: int) -> int:
        """Re-owner rows onto shard ``dst`` — the explicit transfer
        behind a cross-device bucket steal. A row's payload is billed
        to ``d2d_bytes`` exactly once per crossing: a row the
        destination already fetched (resident in its mirror) flips
        owner for free, and a billed-here row's later physical landing
        in the destination mirror costs no additional h2d/d2d. Pinned
        base rows are replicated everywhere and never migrate. Returns
        the number of rows moved."""
        moved = 0
        tr = self.tracer
        t0 = time.perf_counter() if tr is not None else 0.0
        d2d0 = self.d2d_bytes
        with self._lock:
            dn = self._dev_n[dst]
            inv = self._invalid[dst]
            mig = self._migrated_in[dst]
            for h in handles:
                if h < self.n_base:
                    continue
                if int(self._owner[h]) == dst:
                    continue
                self._owner[h] = dst
                if self._rep[h] != REP_BITMAP:
                    # sparse payload crosses once, at its actual size
                    if h not in self._sparse_res[dst]:
                        self.d2d_bytes += self._sparse[h].nbytes
                        self._sparse_res[dst].add(h)
                else:
                    for g in range(int(self._cover[h])):
                        wb = self._seg_words[g] * 4
                        if not wb:
                            continue
                        resident = (h < dn.get(g, 0)
                                    and h not in inv.get(g, ()))
                        if not resident:
                            self.d2d_bytes += wb
                            mig.setdefault(g, set()).add(h)
                self.migrations += 1
                moved += 1
        if tr is not None and moved:
            tr.span("d2d-migrate", t0, cat="arena",
                    args={"rows": moved, "dst": dst,
                          "bytes": self.d2d_bytes - d2d0})
        return moved

    def retain(self, handle: int) -> None:
        if handle < self.n_base:
            return                    # pinned item row
        with self._lock:
            self._refs[handle] += 1

    def release(self, handle: int) -> None:
        """Drop one reference; a freed diffset row cascades one release
        to its anchor (the parent row it pinned at push time), walking
        the chain iteratively outside the lock."""
        h: Optional[int] = handle
        while h is not None:
            h = self._release_one(h)

    def _release_one(self, handle: int) -> Optional[int]:
        if handle < self.n_base:
            return None               # pinned item row
        with self._lock:
            self._refs[handle] -= 1
            if self._refs[handle] == 0:
                self._free.append(handle)
                self.live_extra -= 1
                if self._rep[handle] != REP_BITMAP:
                    t = self._sparse.pop(handle)
                    self.sparse_live -= 1
                    self.sparse_bytes_live -= t.nbytes
                    self._ssupport.pop(handle, None)
                    self._rep[handle] = REP_BITMAP
                    for s in range(self.n_shards):
                        self._sparse_res[s].discard(handle)
                    return self._anchor.pop(handle, None)
            elif self._refs[handle] < 0:   # pragma: no cover - API misuse
                raise RuntimeError(f"double release of handle {handle}")
        return None

    def refcount(self, handle: int) -> int:
        return int(self._refs[handle])

    # ------------------------------------------------------------ access --
    def row(self, handle: int) -> np.ndarray:
        """[n_words] view of one live row. Zero-copy for single-segment
        arenas (the non-streaming hot path); for segmented arenas this
        is a concatenated copy, zero-filled past the row's coverage.
        Sparse rows densify on the fly (billed — see :meth:`densify`),
        so dense-only consumers stay correct on any handle."""
        if self._rep[handle] != REP_BITMAP:
            return self.densify(handle)
        if len(self._stores) == 1:
            return self._stores[0][handle]
        cov = int(self._cover[handle])
        return np.concatenate(
            [store[handle] if g < cov
             else np.zeros(self._seg_words[g], np.uint32)
             for g, store in enumerate(self._stores)])

    def row_upto(self, handle: int, upto: int) -> np.ndarray:
        """Row words over the first ``upto`` segments only, zero-filled
        past the row's coverage — the boundary-consistent read for an
        overlapped refresh (segments appended after the boundary are
        invisible, so two reads of the same handle agree in width)."""
        if self._rep[handle] != REP_BITMAP:
            return self.densify(handle)[:self.n_words_upto(upto)]
        if upto == 1:
            return self._stores[0][handle]
        cov = int(self._cover[handle])
        return np.concatenate(
            [store[handle] if g < cov
             else np.zeros(self._seg_words[g], np.uint32)
             for g, store in enumerate(self._stores[:upto])])

    def seg_row(self, seg: int, handle: int) -> np.ndarray:
        """Zero-copy [W_seg] view of one row's words in one segment."""
        return self._stores[seg][handle]

    def seg_view(self, seg: int) -> np.ndarray:
        """Zero-copy [n_rows, W_seg] view of one segment's store (numpy
        backend sweeps index this directly)."""
        return self._stores[seg][:self.n_rows]

    def rows_view(self) -> np.ndarray:
        """[n_rows, n_words] view of the whole store — zero-copy for
        single-segment arenas, a concatenated copy otherwise."""
        if len(self._stores) == 1:
            return self._stores[0][:self.n_rows]
        return np.concatenate([s[:self.n_rows] for s in self._stores],
                              axis=1)

    def seg_gather(self, seg: int, handles: Sequence[int]) -> np.ndarray:
        """One segment's rows for ``handles`` — a zero-copy slice view
        when the handles are contiguous (item ranges often are), a
        fancy-index copy otherwise."""
        store = self._stores[seg]
        h0 = handles[0]
        n = len(handles)
        if all(handles[i] == h0 + i for i in range(1, n)):
            return store[h0:h0 + n]
        return store[list(handles)]

    def gather(self, handles: Sequence[int]) -> np.ndarray:
        """Full-width rows for ``handles`` (see :meth:`seg_gather`)."""
        if len(self._stores) == 1:
            return self.seg_gather(0, handles)
        return np.concatenate(
            [self.seg_gather(g, handles)
             for g in range(len(self._stores))], axis=1)

    @property
    def live_bytes_extra(self) -> int:
        """Retained non-base payload: dense rows at full row width,
        sparse rows at their actual tid-array size."""
        return ((self.live_extra - self.sparse_live) * self.n_words * 4
                + self.sparse_bytes_live)

    @property
    def peak_bytes_extra(self) -> int:
        return self.peak_live_extra * self.n_words * 4

    @property
    def nbytes_base(self) -> int:
        return self.n_base * self.n_words * 4

    # ------------------------------------------------------------ device --
    @property
    def device_enabled(self) -> bool:
        return self.backing != "numpy"

    def _sync_plan(self, shard: int, seg: int,
                   needed: Optional[Sequence[int]]
                   ) -> Tuple[int, int, List[int], int,
                              List[int], List[int]]:
        """Advance mirror (shard, seg) bookkeeping to ``n_rows`` and
        classify work.

        Caller holds the lock. Returns ``(lo, n, fresh_owned, fresh_h2d,
        reupload, fetch)``: rows [lo, n) are new to this mirror (of
        which ``fresh_owned`` — owned-by-shard or replicated base, live,
        and covering this segment — carry payload, ``fresh_h2d`` of
        them at h2d cost; the rest enter ``_invalid`` as unfetched
        foreign/stale rows); ``reupload`` are owned rows whose mirror
        content went stale (recycled slots), billed h2d; ``fetch`` are
        rows placed without an h2d bill — foreign rows ``needed`` now
        (their payload is counted in ``d2d_bytes`` here, once per
        residency; a later recycle invalidates and recounts),
        migrated-in rows whose d2d was prepaid by :meth:`migrate`, and
        dead/uncovered rows whose placement carries no real payload."""
        n = self.n_rows
        lo = self._dev_n[shard].get(seg, 0)
        inv = self._invalid[shard].setdefault(seg, set())
        mig = self._migrated_in[shard].setdefault(seg, set())
        fresh_owned: List[int] = []
        fresh_h2d = 0

        def _live(h: int) -> bool:
            return h < self.n_base or int(self._refs[h]) > 0

        def _owned(h: int) -> bool:
            return h < self.n_base or int(self._owner[h]) in (-1, shard)

        for h in range(lo, n):
            if (_owned(h) and _live(h) and self._covered(h, seg)
                    and self._rep[h] == REP_BITMAP):
                fresh_owned.append(h)
                if h in mig:          # transfer billed at migrate time
                    mig.discard(h)
                else:
                    fresh_h2d += 1
            else:
                inv.add(h)
        self._dev_n[shard][seg] = n
        reupload: List[int] = []
        fetch: List[int] = []
        row_bytes = self._seg_words[seg] * 4

        def _classify(h: int) -> None:
            inv.discard(h)
            if (not (_live(h) and self._covered(h, seg))
                    or self._rep[h] != REP_BITMAP):
                # no word-column payload: dead/uncovered rows, and
                # sparse rows (their tid payload ships per-launch and
                # bills via _note_sparse / count_h2d instead)
                fetch.append(h)
            elif _owned(h):
                if h in mig:          # prepaid migration landing
                    mig.discard(h)
                    fetch.append(h)
                else:
                    reupload.append(h)
            else:
                fetch.append(h)
                self.d2d_bytes += row_bytes

        if needed is not None:
            for h in set(needed):
                if h in inv:
                    _classify(h)
        else:
            # no access set: refresh every stale owned row (the
            # pre-sharding "dirty" semantics); foreign rows wait for a
            # needed-based sync
            for h in sorted(inv):
                if _owned(h):
                    _classify(h)
        return lo, n, fresh_owned, fresh_h2d, reupload, fetch

    def note_access(self, shard: int, handles: Sequence[int],
                    segments: Optional[Sequence[int]] = None) -> None:
        """Residency/d2d bookkeeping for host-only sweeps: a sweep on
        ``shard`` reading a row owned elsewhere counts one cross-shard
        fetch (``d2d_bytes``), after which the row is resident there
        until its slot recycles. ``segments`` restricts the bill to the
        segment subset actually swept (a streaming delta pass reads —
        and ships — only the fresh segments). Device-backed arenas get
        the same accounting (plus the physical mirror ops) via
        :meth:`device_rows`."""
        if self.n_shards == 1:
            return
        tr = self.tracer
        d2d0 = self.d2d_bytes if tr is not None else 0
        with self._lock:
            self._note_sparse(shard, handles)
            segs = (segments if segments is not None
                    else range(len(self._seg_words)))
            for g in segs:
                self._sync_plan(shard, g, handles)
        if tr is not None and self.d2d_bytes != d2d0:
            tr.instant("d2d", cat="arena",
                       args={"shard": shard,
                             "bytes": self.d2d_bytes - d2d0})

    def _note_sparse(self, shard: int, handles: Sequence[int]) -> None:
        """Cross-shard residency billing for sparse rows (caller holds
        the lock): a foreign tid/diffset payload read by ``shard`` is
        billed to d2d once per residency, at its actual nbytes — the
        sparse analogue of _sync_plan's per-row word-column bill."""
        res = self._sparse_res[shard]
        for h in set(handles):
            if (self._rep[h] != REP_BITMAP and h not in res
                    and int(self._owner[h]) not in (-1, shard)):
                t = self._sparse.get(h)
                if t is not None:
                    self.d2d_bytes += t.nbytes
                    res.add(h)

    def device_rows(self, shard: int = 0,
                    needed: Optional[Sequence[int]] = None,
                    segment: int = 0):
        """jax mirror of one segment's ``seg_view()`` for one shard,
        synced incrementally (only that shard's dispatcher thread calls
        this). Returns None for host-only ("numpy") backing.

        ``needed`` lists the handles the caller is about to gather:
        foreign rows among them are fetched into this shard's mirror
        and counted in ``d2d_bytes``. Without ``needed`` (single-shard
        callers), every stale owned row is refreshed.

        "Incremental" bounds host→device PAYLOAD (the ``h2d_bytes``
        gauge): only changed rows cross the bus, and only this
        segment's words — an ingest that appended segment g uploads
        ``seg_nbytes(g)``, never the older segments. The functional
        update (concatenate / ``.at[].set``) still rebuilds the mirror
        buffer on device, an O(n_rows) device-to-device copy per sync
        with fresh rows — acceptable while mirrors are MBs; a donated
        preallocated buffer would remove it when arenas reach device
        memory scale."""
        if not self.device_enabled:
            if needed is not None:
                self.note_access(shard, needed, segments=(segment,))
            return None
        tr = self.tracer
        t_sync = time.perf_counter() if tr is not None else 0.0
        with self._lock:
            if needed is not None:
                self._note_sparse(shard, needed)
            lo, n, fresh_owned, fresh_h2d, reupload, fetch = \
                self._sync_plan(shard, segment, needed)
            store = self._stores[segment]
            fresh = None
            if n > lo:
                fresh = store[lo:n].copy()
                owned = set(fresh_owned)
                for j, h in enumerate(range(lo, n)):
                    if h not in owned:
                        fresh[j] = 0          # unfetched foreign row
            re_rows = store[reupload].copy() if reupload else None
            fe_rows = store[fetch].copy() if fetch else None
            if fe_rows is not None:
                for j, h in enumerate(fetch):
                    if self._rep[h] != REP_BITMAP:
                        fe_rows[j] = 0    # sparse slot: store words dead
        import jax.numpy as jnp

        def _place(arr):
            a = jnp.asarray(arr)
            if self.devices is not None:
                import jax
                a = jax.device_put(a, self.devices[shard])
            return a

        row_bytes = self._seg_words[segment] * 4
        h2d_delta = 0
        dev = self._dev[shard].get(segment)
        if dev is None:
            dev = _place(fresh if fresh is not None
                         else store[:0])
            h2d_delta += fresh_h2d * row_bytes
        elif fresh is not None:
            dev = jnp.concatenate([dev, _place(fresh)])
            h2d_delta += fresh_h2d * row_bytes
        if re_rows is not None:
            dev = dev.at[_place(np.asarray(reupload, np.int32))
                         ].set(_place(re_rows))
            h2d_delta += len(reupload) * row_bytes
        if fe_rows is not None:
            # payload already billed (d2d at fetch/migrate time) or
            # dead/uncovered (no real payload); on this container's
            # virtual devices the bits physically route through the
            # host
            dev = dev.at[_place(np.asarray(fetch, np.int32))
                         ].set(_place(fe_rows))
        self._dev[shard][segment] = dev
        if h2d_delta:
            self.count_h2d(h2d_delta, _traced=False)
            if tr is not None:
                # only syncs that actually moved payload get a span —
                # the steady-state no-op sync stays invisible
                tr.span("h2d-sync", t_sync, cat="arena",
                        args={"shard": shard, "segment": segment,
                              "bytes": h2d_delta})
        return dev

    def count_h2d(self, nbytes: int, _traced: bool = True) -> None:
        """Backends add per-batch host→device payload here (the
        host-gather fallback path). Locked: with one dispatcher thread
        per shard, concurrent flushes update the shared gauge."""
        with self._lock:
            self.h2d_bytes += nbytes
        if _traced and self.tracer is not None:
            self.tracer.instant("h2d", cat="arena",
                                args={"bytes": nbytes})

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"<BitmapArena rows={self.n_rows} base={self.n_base} "
                f"live_extra={self.live_extra} backing={self.backing} "
                f"shards={self.n_shards} segments={self.n_segments}>")
