"""Transaction-ID (TID) bitmap machinery.

The paper's per-task computation is a TID-list join: support(itemset) =
|∩_{i∈itemset} tidlist(i)|. On TPU (and for GIL-released numpy in the
shared-memory scheduler) TID lists are packed uint32 bitmaps: the join is
AND + popcount — VPU work that the Pallas ``bitmap_join`` kernel tiles so
the shared *prefix* bitmap stays VMEM-resident (the paper's cache reuse,
re-expressed; DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

WORD = 32


def n_words(n_transactions: int) -> int:
    return (n_transactions + WORD - 1) // WORD


def pack_database(db: Sequence[Sequence[int]], n_items: int) -> np.ndarray:
    """db: list of transactions (item id lists) -> [n_items, W] uint32."""
    m = len(db)
    bits = np.zeros((n_items, m), dtype=bool)
    for t, txn in enumerate(db):
        for i in txn:
            bits[i, t] = True
    return pack_bool(bits)


def pack_bool(bits: np.ndarray) -> np.ndarray:
    """[I, T] bool -> [I, W] uint32 (little-endian bit order per word)."""
    i, t = bits.shape
    w = n_words(t)
    padded = np.zeros((i, w * WORD), dtype=bool)
    padded[:, :t] = bits
    packed = np.packbits(padded.reshape(i, w, WORD)[:, :, ::-1], axis=-1)
    return packed.view(">u4").astype(np.uint32).reshape(i, w)


def unpack_bool(packed: np.ndarray, n_transactions: int) -> np.ndarray:
    """[I, W] uint32 -> [I, T] bool."""
    i, w = packed.shape
    be = packed.astype(">u4")
    by = be.view(np.uint8).reshape(i, w, 4)
    bits = np.unpackbits(by, axis=-1).reshape(i, w * WORD).astype(bool)
    # restore per-word little-endian bit order
    bits = bits.reshape(i, w, WORD)[:, :, ::-1].reshape(i, w * WORD)
    return bits[:, :n_transactions]


def popcount32(x: np.ndarray) -> np.ndarray:
    """Vectorized popcount for uint32 arrays (numpy, GIL-released)."""
    if hasattr(np, "bitwise_count"):          # numpy >= 2.0: one ufunc pass
        return np.bitwise_count(x).astype(np.int64)
    x = x.astype(np.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(np.int64)


def intersect(bitmaps: np.ndarray) -> np.ndarray:
    """AND-reduce [k, W] -> [W]."""
    out = bitmaps[0].copy()
    for b in bitmaps[1:]:
        out &= b
    return out


def support_of(bitmap_rows: np.ndarray) -> int:
    """|∩ rows| for a [k, W] stack of bitmaps."""
    return int(popcount32(intersect(bitmap_rows)).sum())


# Target working-set size for one [chunk, W] AND+popcount temporary:
# roughly half an L2 slice, so the chunk stays cache-resident even on
# scaled datasets where W grows with the transaction count.
CHUNK_TARGET_BYTES = 4 << 20


def support_counts(prefix: np.ndarray, exts: np.ndarray,
                   chunk: int | None = None) -> np.ndarray:
    """counts[e] = |prefix ∩ exts[e]|. prefix: [W]; exts: [E, W].

    This is the numpy bucket-sweep: one fused AND+popcount pass with the
    prefix row broadcast (cache-resident) across all extensions — the
    vectorized analogue of the Pallas bitmap_join kernel. ``chunk``
    bounds the [chunk, W] temporary; by default it adapts to W so the
    temporary stays ~CHUNK_TARGET_BYTES regardless of dataset scale."""
    e, w = exts.shape
    if e == 1:
        # single-extension fast path (deep, narrow equivalence classes):
        # skip the [E, W] broadcast temporary entirely
        return popcount32(exts[0] & prefix).sum(keepdims=True)
    if chunk is None:
        chunk = max(64, CHUNK_TARGET_BYTES // max(w * (WORD // 8), 1))
    if e <= chunk:
        return popcount32(exts & prefix[None, :]).sum(axis=1)
    out = np.empty(e, dtype=np.int64)
    for lo in range(0, e, chunk):
        hi = min(lo + chunk, e)
        out[lo:hi] = popcount32(exts[lo:hi] & prefix[None, :]).sum(axis=1)
    return out
