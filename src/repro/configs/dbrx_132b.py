"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    norm="layernorm",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, moe=MoEConfig(n_experts=4, top_k=2),
    )
