"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, chunk=256),
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=16, chunk=32),
        vocab_size=256,
    )
