"""Architecture registry: ``--arch <id>`` → ModelConfig.

Cells: every arch × its applicable shapes. ``long_500k`` only for
subquadratic families (ssm/hybrid); decode shapes skipped for
encoder-only archs (none assigned here — whisper is enc-dec and decodes).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (chameleon_34b, dbrx_132b, glm4_9b, mamba2_1_3b,
                           olmo_1b, qwen2_5_14b, qwen3_moe_235b, stablelm_3b,
                           whisper_tiny, zamba2_1_2b)
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

_MODULES = {
    "dbrx-132b": dbrx_132b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "mamba2-1.3b": mamba2_1_3b,
    "olmo-1b": olmo_1b,
    "stablelm-3b": stablelm_3b,
    "qwen2.5-14b": qwen2_5_14b,
    "glm4-9b": glm4_9b,
    "zamba2-1.2b": zamba2_1_2b,
    "chameleon-34b": chameleon_34b,
    "whisper-tiny": whisper_tiny,
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells for one architecture."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # full-attention archs skip 500k decode (DESIGN.md §4)
        out.append(s)
    return out


def all_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells."""
    cells = []
    for arch in ARCH_IDS:
        for s in applicable_shapes(get_config(arch)):
            cells.append((arch, s.name))
    return cells
