"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens (image tokens share the text
vocab; the VQ tokenizer frontend is a stub: input_specs() supplies token
ids, which is exactly chameleon's early-fusion interface).
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    norm="rmsnorm",
    qkv_bias=False,
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab_size=512)
