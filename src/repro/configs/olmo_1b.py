"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric_ln",
    tie_embeddings=True,
    glu=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab_size=256)
