"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
encoder-decoder; conv frontend is a STUB (input_specs() provides precomputed
frame embeddings [B, n_frames, d_model]). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    glu=False,
    act="gelu",
    rope_theta=0.0,             # whisper uses learned/sinusoidal positions
    encdec=EncDecConfig(encoder_layers=4, n_frames=1500, frontend="stub"),
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, encdec=EncDecConfig(encoder_layers=2, n_frames=50),
    )
