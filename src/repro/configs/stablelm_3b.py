"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=96, vocab_size=256)
