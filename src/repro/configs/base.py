"""Config system: model / shape / mesh / run configs.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact published dims) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests). ``repro.configs.registry`` maps
``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # 'clustered' = sorted/bucketed dispatch (paper-aligned);
    # 'onehot'    = GShard one-hot einsum dispatch (unclustered baseline).
    dispatch: str = "clustered"
    router_dtype: str = "float32"
    # token-group count for dispatch; 0 = auto (clustered: one group per
    # DP shard so sort/scatter stay device-local; onehot: ~1024-token
    # groups, the classic GShard grouping).
    n_groups: int = 0
    onehot_group: int = 1024


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 256
    # A (negative real) init range
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + a single *shared* attention block
    applied every ``attn_every`` backbone layers."""
    attn_every: int = 6
    shared_attn: bool = True
    # sliding window used for the shared attn block at long context
    long_ctx_window: int = 4096


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 4
    n_frames: int = 1500        # whisper 30s @ 50Hz after conv stub
    frontend: str = "stub"      # precomputed frame embeddings via input_specs()


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparametric_ln
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    act: str = "silu"           # silu (swiglu) | gelu (plain mlp)
    glu: bool = True            # gated FFN
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"         # none | dots | full
    scan_layers: bool = True
    # attention memory policy: blockwise (online-softmax lax.scan) above this
    # many query tokens; keeps prefill_32k within HBM without Pallas on CPU.
    attn_block_q: int = 1024
    attn_blockwise_threshold: int = 8192
    use_pallas: bool = False    # TPU target: flash-attention kernel path
    # f32 attention logits/softmax (default). False = bf16 softmax: halves
    # the S^2 HBM traffic on the jnp path (the Pallas flash kernel removes
    # it entirely on TPU) — EXPERIMENTS.md §Perf hillclimb A.
    attn_softmax_f32: bool = True
    # KV-cache dtype for decode: bfloat16 | int8 (per-(pos,head) scales;
    # halves decode HBM traffic — EXPERIMENTS.md §Perf extensions)
    kv_cache_dtype: str = "bfloat16"
    # long-context: subquadratic families only (ssm/hybrid) may run long_500k
    subquadratic: bool = False

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytical parameter counts (for MODEL_FLOPS = 6*N*D) ----
    def param_count(self) -> int:
        """Total parameters (analytical)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: top_k of n_experts)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    n = 0
    # embeddings (counted once; lm head tied or not)
    n += cfg.vocab_size * d
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d

    def attn_params() -> int:
        p = d * cfg.n_heads * hd            # q
        p += 2 * d * cfg.n_kv_heads * hd    # k, v
        p += cfg.n_heads * hd * d           # o
        if cfg.qkv_bias:
            p += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        return p

    def ffn_params(dff: int) -> int:
        mult = 3 if cfg.glu else 2
        return mult * d * dff

    def norm_params() -> int:
        if cfg.norm == "nonparametric_ln":
            return 0
        return d if cfg.norm == "rmsnorm" else 2 * d

    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + ffn_params(cfg.d_ff) + 2 * norm_params()
        n += cfg.n_layers * per_layer
    elif cfg.family == "moe":
        m = cfg.moe
        e = m.top_k if active_only else m.n_experts
        per_layer = (attn_params() + e * ffn_params(cfg.d_ff)
                     + d * m.n_experts        # router
                     + 2 * norm_params())
        n += cfg.n_layers * per_layer
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        nheads = d_in // s.headdim
        per_layer = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                     + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)      # conv
                     + nheads * 2                                          # A, dt_bias
                     + d_in                                                # D skip + norm
                     + d_in * d                                            # out_proj
                     + norm_params())
        n += cfg.n_layers * per_layer
    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        nheads = d_in // s.headdim
        mamba_layer = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                       + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                       + nheads * 2 + d_in + d_in * d + norm_params())
        n += cfg.n_layers * mamba_layer
        # one shared attn+ffn block (params counted once; reused)
        n += attn_params() + ffn_params(cfg.d_ff) + 2 * norm_params()
    elif cfg.family == "audio":
        ed = cfg.encdec
        enc_layer = attn_params() + ffn_params(cfg.d_ff) + 2 * norm_params()
        dec_layer = 2 * attn_params() + ffn_params(cfg.d_ff) + 3 * norm_params()
        n += ed.encoder_layers * enc_layer + cfg.n_layers * dec_layer
    else:
        raise ValueError(cfg.family)
    return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    zero: bool = True            # shard optimizer state over DP axes
    compress_grads: bool = False # int8 error-feedback all-reduce


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    # sharding rule-set name (see repro.parallel.sharding.RULESETS)
    sharding_rules: str = "default"
    microbatches: int = 1        # >1 enables grad accumulation
    pipeline_stages: int = 1     # >1 enables pipeline parallelism


def smoke_shape(kind: str = "train") -> ShapeConfig:
    if kind == "train":
        return ShapeConfig("smoke_train", 128, 4, "train")
    if kind == "prefill":
        return ShapeConfig("smoke_prefill", 256, 2, "prefill")
    return ShapeConfig("smoke_decode", 256, 2, "decode")
