"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per-expert) vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,               # qwen3 uses head_dim 128 (64H*128 != d_model)
    norm="rmsnorm",
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=128, top_k=8),
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, moe=MoEConfig(n_experts=8, top_k=2),
    )
