"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE + GQA. [hf:THUDM/glm-4-9b; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    norm="rmsnorm",
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab_size=256)
