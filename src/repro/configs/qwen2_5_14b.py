"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, GQA + QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=96, vocab_size=256)
