"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, chunk=256),
    hybrid=HybridConfig(attn_every=6, shared_attn=True, long_ctx_window=4096),
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=16, chunk=32),
        hybrid=HybridConfig(attn_every=2, shared_attn=True, long_ctx_window=64),
    )
