"""BitmapArena lifecycle: refcounts, handle reuse, device-mirror sync
accounting, and engine-level refcount hygiene on task error."""
import numpy as np
import pytest

from repro.core import fpm as fpm_mod
from repro.core.fpm import mine
from repro.core.join_backend import NumpyBackend
from repro.core.tidlist import BitmapArena, pack_database

RNG = np.random.default_rng(11)


def small_arena(n=6, w=4, backing="auto"):
    rows = RNG.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    return BitmapArena.from_bitmaps(rows, backing=backing), rows


# ----------------------------------------------------------- lifecycle
def test_base_rows_are_pinned_item_handles():
    arena, rows = small_arena()
    assert arena.n_base == 6 and arena.n_rows == 6
    for i in range(6):
        np.testing.assert_array_equal(arena.row(i), rows[i])
        arena.release(i)                     # no-op on pinned rows
        assert arena.refcount(i) == 1
    assert arena.live_extra == 0


def test_push_retain_release_refcounts():
    arena, rows = small_arena()
    h = arena.push(rows[0] | rows[1])
    assert h == 6 and arena.refcount(h) == 1 and arena.live_extra == 1
    arena.retain(h)
    assert arena.refcount(h) == 2
    arena.release(h)
    assert arena.refcount(h) == 1 and arena.live_extra == 1
    arena.release(h)
    assert arena.live_extra == 0             # freed


def test_handle_reuse_after_free():
    arena, rows = small_arena()
    h1 = arena.push(rows[0])
    h2 = arena.push(rows[1])
    arena.release(h1)
    h3 = arena.push(rows[2])                 # recycles h1's slot
    assert h3 == h1 and h3 != h2
    np.testing.assert_array_equal(arena.row(h3), rows[2])
    assert arena.n_rows == 8                 # no growth past high-water


def test_materialize_is_the_and_of_both_rows():
    arena, rows = small_arena()
    h = arena.materialize(2, 4)
    np.testing.assert_array_equal(arena.row(h), rows[2] & rows[4])
    child = arena.materialize(h, 1)          # chained (depth-first)
    np.testing.assert_array_equal(arena.row(child),
                                  rows[2] & rows[4] & rows[1])
    assert arena.peak_live_extra == 2
    assert arena.peak_bytes_extra == 2 * arena.n_words * 4


def test_growth_preserves_rows_and_views_stay_correct():
    arena, rows = small_arena(n=3, w=5)
    view = arena.row(1)
    handles = [arena.push(rows[i % 3]) for i in range(300)]  # force grow
    np.testing.assert_array_equal(arena.row(1), rows[1])
    np.testing.assert_array_equal(view, rows[1])   # old view still right
    for h in handles:
        arena.release(h)
    assert arena.live_extra == 0


def test_gather_contiguous_is_view_strided_is_copy():
    arena, rows = small_arena()
    g = arena.gather([2, 3, 4])
    assert g.base is not None                # slice view, zero-copy
    np.testing.assert_array_equal(g, rows[2:5])
    s = arena.gather([0, 2, 5])
    np.testing.assert_array_equal(s, rows[[0, 2, 5]])


def test_bad_backing_rejected():
    with pytest.raises(ValueError, match="backing"):
        BitmapArena(4, backing="cuda")


# -------------------------------------------------------- device mirror
def test_device_sync_is_incremental_and_counts_h2d():
    arena, rows = small_arena(n=4, w=8)
    row_bytes = 8 * 4
    dev = arena.device_rows()                # initial upload: 4 rows
    assert dev.shape == (4, 8) and arena.h2d_bytes == 4 * row_bytes
    dev = arena.device_rows()                # no change -> no upload
    assert arena.h2d_bytes == 4 * row_bytes
    h = arena.push(rows[0] & rows[1])
    dev = arena.device_rows()                # one appended row
    assert dev.shape == (5, 8)
    assert arena.h2d_bytes == 5 * row_bytes
    np.testing.assert_array_equal(np.asarray(dev[h]), rows[0] & rows[1])
    # recycled slot: freed row rewritten -> resynced as dirty, not
    # re-uploading the whole store
    arena.release(h)
    h2 = arena.push(rows[2] | rows[3])
    assert h2 == h
    dev = arena.device_rows()
    assert arena.h2d_bytes == 6 * row_bytes
    np.testing.assert_array_equal(np.asarray(dev[h2]), rows[2] | rows[3])


def test_numpy_backing_never_creates_device_mirror():
    arena, _ = small_arena(backing="numpy")
    assert not arena.device_enabled
    assert arena.device_rows() is None
    assert arena.h2d_bytes == 0


def test_jax_backing_uploads_eagerly():
    arena, _ = small_arena(n=5, w=3, backing="jax")
    assert arena.h2d_bytes == 5 * 3 * 4


# ------------------------------------------------------- sharded mode
def sharded_arena(n=6, w=4, backing="numpy", n_shards=2):
    rows = RNG.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    return BitmapArena.from_bitmaps(rows, backing=backing,
                                    n_shards=n_shards), rows


def test_sharded_ownership_and_base_replication():
    arena, _ = sharded_arena()
    assert arena.n_shards == 2
    for i in range(arena.n_base):
        assert arena.owner_of(i) == -1       # replicated, never owned
    h0 = arena.materialize(0, 1, shard=0)
    h1 = arena.materialize(2, 3, shard=1)
    assert arena.owner_of(h0) == 0 and arena.owner_of(h1) == 1


def test_foreign_fetch_counts_d2d_once_per_residency():
    arena, _ = sharded_arena(w=8)
    row_bytes = 8 * 4
    h = arena.materialize(0, 1, shard=0)
    arena.note_access(0, [h, 0, 1])          # owner reads: free
    assert arena.d2d_bytes == 0
    arena.note_access(1, [h, 0])             # shard 1 fetches h
    assert arena.d2d_bytes == row_bytes
    arena.note_access(1, [h])                # cached: no recount
    assert arena.d2d_bytes == row_bytes
    # recycling the slot invalidates residency everywhere
    arena.release(h)
    h2 = arena.materialize(2, 3, shard=0)
    assert h2 == h
    arena.note_access(1, [h2])               # re-fetch after recycle
    assert arena.d2d_bytes == 2 * row_bytes


def test_migrate_reowners_and_accounts():
    arena, _ = sharded_arena(w=4)
    row_bytes = 4 * 4
    h = arena.materialize(0, 1, shard=0)
    moved = arena.migrate([h, 0, h], dst=1)  # base row 0 never moves;
    assert moved == 1                        # second h already at dst
    assert arena.owner_of(h) == 1
    assert arena.migrations == 1
    assert arena.d2d_bytes == row_bytes
    # after migration the new owner reads it for free
    arena.note_access(1, [h])
    assert arena.d2d_bytes == row_bytes


def test_migrate_after_fetch_is_free():
    """A row the destination already fetched (resident in its mirror)
    crossed the link once — migrating it flips ownership without a
    second d2d bill."""
    arena, _ = sharded_arena(w=8)
    row_bytes = 8 * 4
    h = arena.materialize(0, 1, shard=0)
    arena.note_access(1, [h])                # fetch: billed once
    assert arena.d2d_bytes == row_bytes
    moved = arena.migrate([h], dst=1)
    assert moved == 1 and arena.migrations == 1
    assert arena.d2d_bytes == row_bytes      # no double count


def test_migrated_row_lands_on_dst_mirror_without_h2d():
    """Device-backed shards: a migrated row's physical landing in the
    destination mirror is the d2d transfer already billed by migrate()
    — it must not also be billed as a host upload."""
    arena, rows = sharded_arena(n=4, w=8, backing="auto")
    row_bytes = 8 * 4
    h = arena.materialize(0, 1, shard=0)
    arena.device_rows(0, needed=[h])         # shard 0: base + own row
    h2d_before = arena.h2d_bytes
    arena.migrate([h], dst=1)
    assert arena.d2d_bytes == row_bytes
    d1 = arena.device_rows(1, needed=[h])
    np.testing.assert_array_equal(np.asarray(d1[h]), rows[0] & rows[1])
    # shard 1's first sync uploads only the replicated base rows; the
    # migrated row rides its prepaid d2d transfer
    assert arena.h2d_bytes == h2d_before + arena.n_base * row_bytes
    assert arena.d2d_bytes == row_bytes      # still billed exactly once


def test_sharded_device_mirrors_fetch_foreign_rows():
    """Device-backed shards: each mirror holds base rows + its own
    rows; a foreign row is fetched on demand (content-correct, counted
    as d2d) and zero-filled until then."""
    arena, rows = sharded_arena(n=4, w=8, backing="auto")
    h = arena.materialize(0, 1, shard=0)
    d0 = arena.device_rows(0, needed=[h, 0])
    np.testing.assert_array_equal(np.asarray(d0[h]), rows[0] & rows[1])
    assert arena.d2d_bytes == 0
    d1 = arena.device_rows(1, needed=[0, 2])  # base rows only: no d2d
    np.testing.assert_array_equal(np.asarray(d1[:4]), rows)
    assert (np.asarray(d1[h]) == 0).all()     # unfetched foreign row
    assert arena.d2d_bytes == 0
    d1 = arena.device_rows(1, needed=[h])     # now fetch it
    np.testing.assert_array_equal(np.asarray(d1[h]), rows[0] & rows[1])
    assert arena.d2d_bytes == 8 * 4


def test_sharded_ctor_validation():
    with pytest.raises(ValueError, match="n_shards"):
        BitmapArena(4, n_shards=0)
    with pytest.raises(ValueError, match="devices"):
        BitmapArena(4, n_shards=2, devices=[object()])


# --------------------------------------------- engine refcount hygiene
@pytest.fixture()
def capture_arena(monkeypatch):
    """Route fpm.mine's arena construction through a spy so the test
    can inspect refcounts after mining ends."""
    captured = []
    orig = BitmapArena.from_bitmaps.__func__

    class Spy(BitmapArena):
        @classmethod
        def from_bitmaps(cls, bitmaps, backing="auto", **kw):
            arena = orig(cls, bitmaps, backing, **kw)
            captured.append(arena)
            return arena

    monkeypatch.setattr(fpm_mod, "BitmapArena", Spy)
    return captured


def retail_bitmaps():
    from repro.data.transactions import load
    db, p = load("retail", seed=0)
    db = db[:800]
    return pack_database(db, p.n_items), int(0.03 * len(db))


def test_depth_first_releases_every_handoff_row(capture_arena):
    """Clean depth-first run: every materialized child handle is
    released by its task's ``finally`` — no live rows beyond the
    pinned base remain when mining ends."""
    bm, ms = retail_bitmaps()
    _, met = mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                  granularity="depth-first")
    (arena,) = capture_arena
    assert met.peak_retained_bitmaps > 0     # handoffs happened
    assert arena.live_extra == 0             # ... and all released


def test_refcount_released_on_task_error(capture_arena):
    """A class task that errors mid-subtree must still release its own
    handle AND the handles of children it materialized but never
    spawned — an error may not leak arena rows."""

    class ChildBomb(NumpyBackend):
        def sweep_many(self, arena, requests):
            if any(r.prefix_handle >= arena.n_base for r in requests):
                raise RuntimeError("child boom")
            return super().sweep_many(arena, requests)

    import repro.core.fpm as fpm
    bm, ms = retail_bitmaps()
    orig_resolve = fpm.resolve_backend
    fpm.resolve_backend = lambda spec: ChildBomb()
    try:
        with pytest.raises(RuntimeError, match="child boom"):
            mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                 granularity="depth-first")
    finally:
        fpm.resolve_backend = orig_resolve
    (arena,) = capture_arena
    assert arena.peak_live_extra > 0         # children were materialized
    assert arena.live_extra == 0             # ... and none leaked


def test_mine_with_jax_arena_matches_serial():
    from repro.core.fpm import mine_serial
    bm, ms = retail_bitmaps()
    ref = mine_serial(bm, ms, max_k=4)
    got, met = mine(bm, ms, n_workers=3, max_k=4, arena="jax",
                    backend="pallas-interpret")
    assert got == ref
    assert met.h2d_bytes >= bm.nbytes        # the eager initial upload


# ----------------------------------------------- segmented arena (streaming)
def test_add_segment_extends_base_rows_only():
    arena, rows = small_arena(n=4, w=3)
    seg = RNG.integers(0, 2 ** 32, size=(4, 2), dtype=np.uint32)
    g = arena.add_segment(seg)
    assert g == 1 and arena.n_segments == 2
    assert arena.n_words == 5 and arena.seg_words(1) == 2
    for i in range(4):
        np.testing.assert_array_equal(arena.row(i),
                                      np.concatenate([rows[i], seg[i]]))
        np.testing.assert_array_equal(arena.seg_row(1, i), seg[i])


def test_add_segment_rejects_wrong_row_count():
    arena, _ = small_arena(n=4, w=3)
    with pytest.raises(ValueError, match="n_base"):
        arena.add_segment(np.zeros((3, 2), np.uint32))


def test_pre_segment_rows_read_zeros_beyond_their_coverage():
    """A row materialized BEFORE an ingest covers only the segments
    that existed then — its words in later segments read as zeros, so
    a stale retained row can never fabricate support in transactions
    it never saw."""
    arena, rows = small_arena(n=4, w=3)
    h = arena.materialize(0, 1)
    seg = np.full((4, 2), 0xFFFFFFFF, np.uint32)
    arena.add_segment(seg)
    got = arena.row(h)
    np.testing.assert_array_equal(got[:3], rows[0] & rows[1])
    assert (got[3:] == 0).all()
    # a row pushed AFTER the ingest covers both segments
    h2 = arena.push(arena.row(0))
    np.testing.assert_array_equal(arena.row(h2), arena.row(0))
    # and a materialize of base rows post-ingest spans both segments
    h3 = arena.materialize(2, 3)
    np.testing.assert_array_equal(
        arena.row(h3), np.concatenate([rows[2] & rows[3],
                                       seg[2] & seg[3]]))


def test_segment_mirror_sync_bills_only_new_segment_bytes():
    """Device mirrors are per-segment: after an ingest, syncing the new
    segment uploads exactly its payload; the old segment's mirror is
    untouched (no re-upload of the whole arena)."""
    arena, rows = small_arena(n=4, w=8)
    arena.device_rows()                          # seg 0: 4 rows x 8 w
    assert arena.h2d_bytes == 4 * 8 * 4
    seg = RNG.integers(0, 2 ** 32, size=(4, 2), dtype=np.uint32)
    arena.add_segment(seg)
    dev1 = arena.device_rows(segment=1)
    assert arena.h2d_bytes == 4 * 8 * 4 + arena.seg_nbytes(1)
    assert arena.seg_nbytes(1) == 4 * 2 * 4
    np.testing.assert_array_equal(np.asarray(dev1), seg)
    arena.device_rows()                          # seg 0 unchanged:
    assert arena.h2d_bytes == 4 * 8 * 4 + 4 * 2 * 4   # no new upload


def test_eager_backing_uploads_each_segment_once():
    arena, _ = small_arena(n=5, w=3, backing="jax")
    assert arena.h2d_bytes == 5 * 3 * 4
    arena.add_segment(np.ones((5, 4), np.uint32))
    # eager: the ingest itself mirrored the new segment — and ONLY it
    assert arena.h2d_bytes == 5 * 3 * 4 + 5 * 4 * 4


def test_slot_recycle_across_segments_invalidates_every_mirror():
    """A recycled slot's stale words must be invalidated (and resynced
    on demand) in EVERY segment mirror, not just segment 0."""
    arena, rows = small_arena(n=4, w=4)
    seg = RNG.integers(0, 2 ** 32, size=(4, 3), dtype=np.uint32)
    arena.add_segment(seg)
    h = arena.materialize(0, 1)
    arena.device_rows(segment=0)
    arena.device_rows(segment=1)
    h2d = arena.h2d_bytes
    arena.release(h)
    h2 = arena.materialize(2, 3)
    assert h2 == h                               # slot recycled
    d0 = arena.device_rows(segment=0)
    d1 = arena.device_rows(segment=1)
    np.testing.assert_array_equal(np.asarray(d0[h2]), rows[2] & rows[3])
    np.testing.assert_array_equal(np.asarray(d1[h2]), seg[2] & seg[3])
    # reupload billed per segment at that segment's width
    assert arena.h2d_bytes == h2d + 4 * 4 + 3 * 4


def test_segmented_sweep_restricted_to_segment_subset():
    """The numpy backend sums per-segment joins; a segments= request
    reads only those segments (the streaming delta sweep)."""
    from repro.core.join_backend import NumpyBackend, SweepRequest
    from repro.core.tidlist import popcount32
    arena, rows = small_arena(n=4, w=3)
    seg = RNG.integers(0, 2 ** 32, size=(4, 2), dtype=np.uint32)
    arena.add_segment(seg)
    be = NumpyBackend()
    full = be.sweep_many(arena, [SweepRequest(0, (1, 2))])[0]
    want_full = [int(popcount32(np.concatenate([rows[0] & rows[e],
                                                seg[0] & seg[e]])).sum())
                 for e in (1, 2)]
    assert list(full) == want_full
    delta = be.sweep_many(arena,
                          [SweepRequest(0, (1, 2), segments=(1,))])[0]
    want_delta = [int(popcount32(seg[0] & seg[e]).sum()) for e in (1, 2)]
    assert list(delta) == want_delta
    both = be.sweep_many(arena,
                         [SweepRequest(0, (1, 2), segments=(0, 1))])[0]
    assert list(both) == want_full


def test_zero_width_segments_are_skipped():
    """An empty initial database (or empty batch) packs to a
    zero-width segment; sweeps skip it and counts stay correct."""
    from repro.core.join_backend import NumpyBackend, SweepRequest
    from repro.core.tidlist import popcount32
    arena = BitmapArena.from_bitmaps(np.zeros((3, 0), np.uint32))
    seg = RNG.integers(0, 2 ** 32, size=(3, 2), dtype=np.uint32)
    arena.add_segment(seg)
    arena.add_segment(np.zeros((3, 0), np.uint32))
    be = NumpyBackend()
    counts = be.sweep_many(arena, [SweepRequest(0, (1, 2))])[0]
    want = [int(popcount32(seg[0] & seg[e]).sum()) for e in (1, 2)]
    assert list(counts) == want


def test_pallas_interpret_matches_numpy_on_segmented_arena():
    from repro.core.join_backend import (NumpyBackend,
                                         PallasInterpretBackend,
                                         SweepRequest)
    arena, rows = small_arena(n=6, w=4)
    arena.add_segment(RNG.integers(0, 2 ** 32, size=(6, 3),
                                   dtype=np.uint32))
    reqs = [SweepRequest(0, (1, 2, 3)),
            SweepRequest(1, (2, 4), segments=(1,)),
            SweepRequest(2, (3,), segments=(0,))]
    a = NumpyBackend().sweep_many(arena, reqs)
    b = PallasInterpretBackend().sweep_many(arena, reqs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------- compaction
def test_compact_merges_segments_preserving_rows_and_handles():
    """compact() collapses the segment axis only: every handle reads
    the same full-width row before and after, coverage semantics
    (zeros beyond a stale row's ingest horizon) included."""
    arena, rows = small_arena(n=4, w=3)
    h_pre = arena.materialize(0, 1)             # covers segment 0 only
    seg1 = RNG.integers(0, 2 ** 32, size=(4, 2), dtype=np.uint32)
    seg2 = RNG.integers(0, 2 ** 32, size=(4, 1), dtype=np.uint32)
    arena.add_segment(seg1)
    arena.add_segment(seg2)
    h_post = arena.materialize(2, 3)            # covers all three
    before = {h: arena.row(h).copy()
              for h in (0, 1, 2, 3, h_pre, h_post)}
    removed = arena.compact(3)
    assert removed == 2
    assert arena.n_segments == 1
    assert arena.seg_words(0) == 3 + 2 + 1 == arena.n_words
    assert arena.compactions == 1
    assert arena.compaction_bytes == arena.n_rows * 6 * 4
    for h, want in before.items():
        np.testing.assert_array_equal(arena.row(h), want)
    # the pre-ingest row still reads zeros beyond its old coverage
    assert (arena.row(h_pre)[3:] == 0).all()


def test_compact_partial_prefix_and_segment_id_shift():
    """compact(upto=2) folds only the cold prefix; the remaining
    segment shifts down and keeps serving segment-restricted sweeps."""
    from repro.core.join_backend import NumpyBackend, SweepRequest
    from repro.core.tidlist import popcount32
    arena, rows = small_arena(n=4, w=2)
    seg1 = RNG.integers(0, 2 ** 32, size=(4, 1), dtype=np.uint32)
    seg2 = RNG.integers(0, 2 ** 32, size=(4, 3), dtype=np.uint32)
    arena.add_segment(seg1)
    arena.add_segment(seg2)
    full_before = arena.row(0).copy()
    assert arena.compact(2) == 1
    assert arena.n_segments == 2
    assert arena.seg_words(0) == 3 and arena.seg_words(1) == 3
    np.testing.assert_array_equal(arena.row(0), full_before)
    # old segment 2 is now segment 1
    delta = NumpyBackend().sweep_many(
        arena, [SweepRequest(0, (1, 2), segments=(1,))])[0]
    want = [int(popcount32(seg2[0] & seg2[e]).sum()) for e in (1, 2)]
    assert list(delta) == want


def test_compact_guards_reject_trivial_or_out_of_range():
    arena, _ = small_arena(n=4, w=2)
    assert arena.compact(1) == 0                # nothing to merge
    assert arena.compact(2) == 0                # only one segment
    arena.add_segment(np.ones((4, 1), np.uint32))
    assert arena.compact(3) == 0                # beyond segment count
    assert arena.compactions == 0
    assert arena.compact(2) == 1


def test_compact_recycled_slot_spans_compaction():
    """A slot recycled BEFORE a compaction keeps its new content and
    its new coverage through the merge."""
    arena, rows = small_arena(n=4, w=2)
    h = arena.materialize(0, 1)
    seg1 = RNG.integers(0, 2 ** 32, size=(4, 2), dtype=np.uint32)
    arena.add_segment(seg1)
    arena.release(h)
    h2 = arena.materialize(2, 3)                # recycles the slot,
    assert h2 == h                              # now covers both segs
    arena.compact(2)
    np.testing.assert_array_equal(
        arena.row(h2), np.concatenate([rows[2] & rows[3],
                                       seg1[2] & seg1[3]]))


def test_compact_fully_synced_mirror_merges_without_h2d():
    """Eager backing keeps every segment mirror complete, so compact()
    merges them device-side: the next device_rows() is free."""
    arena, rows = small_arena(n=4, w=2, backing="jax")
    seg1 = RNG.integers(0, 2 ** 32, size=(4, 1), dtype=np.uint32)
    arena.add_segment(seg1)
    h2d = arena.h2d_bytes
    arena.compact(2)
    dev = arena.device_rows(segment=0)
    assert arena.h2d_bytes == h2d               # no re-upload
    np.testing.assert_array_equal(
        np.asarray(dev)[:4], np.concatenate([rows, seg1], axis=1))


def test_compact_unsynced_mirror_resyncs_from_host():
    """With a lazily-backed arena that never synced, compact() leaves
    the merged block host-only; a later device_rows() re-syncs it at
    the merged width and the content is exact."""
    arena, rows = small_arena(n=4, w=2)
    seg1 = RNG.integers(0, 2 ** 32, size=(4, 1), dtype=np.uint32)
    arena.add_segment(seg1)
    arena.compact(2)
    dev = arena.device_rows(segment=0)
    if dev is not None:                         # device backing enabled
        np.testing.assert_array_equal(
            np.asarray(dev)[:4], np.concatenate([rows, seg1], axis=1))
        assert arena.h2d_bytes >= 4 * 3 * 4


def test_sweeps_identical_across_compaction():
    """The same batch of (tuple-prefix, segment-restricted) sweeps
    returns identical counts before and after compact()."""
    from repro.core.join_backend import NumpyBackend, SweepRequest

    def reqs():
        return [SweepRequest(0, (1, 2, 3)),
                SweepRequest((0, 1), (2, 3)),
                SweepRequest(2, (3,), segments=(2,))]

    arena, rows = small_arena(n=5, w=2)
    arena.add_segment(RNG.integers(0, 2 ** 32, (5, 1), np.uint32))
    arena.add_segment(RNG.integers(0, 2 ** 32, (5, 2), np.uint32))
    be = NumpyBackend()
    before = be.sweep_many(arena, reqs())
    arena.compact(2)                            # old seg 2 -> seg 1
    after = be.sweep_many(
        arena, [SweepRequest(0, (1, 2, 3)),
                SweepRequest((0, 1), (2, 3)),
                SweepRequest(2, (3,), segments=(1,))])
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, y)
