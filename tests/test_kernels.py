"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.bitmap_join.kernel import (bitmap_join_kernel,
                                              bitmap_join_many_kernel)
from repro.kernels.bitmap_join.ops import bitmap_join
from repro.kernels.bitmap_join.ref import (bitmap_join_many_ref,
                                           bitmap_join_ref)

RNG = np.random.default_rng(0)


# ------------------------------------------------------------ bitmap_join
@pytest.mark.parametrize("e,w", [(1, 1), (7, 33), (256, 512), (300, 700),
                                 (513, 1025)])
def test_bitmap_join_shapes(e, w):
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=w, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(e, w),
                                    dtype=np.uint32))
    out = bitmap_join_kernel(prefix, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_ref(prefix, exts))


def test_bitmap_join_ops_dispatches_to_ref_on_cpu():
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=64, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(8, 64),
                                    dtype=np.uint32))
    np.testing.assert_array_equal(bitmap_join(prefix, exts),
                                  bitmap_join_ref(prefix, exts))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96))
def test_property_bitmap_join_random(e, w):
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=w, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(e, w),
                                    dtype=np.uint32))
    out = bitmap_join_kernel(prefix, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_ref(prefix, exts))


# ------------------------------------------------- bitmap_join_many (batched)
@pytest.mark.parametrize("b,e,w", [(1, 1, 1), (3, 7, 33), (2, 64, 512),
                                   (5, 70, 600)])
def test_bitmap_join_many_shapes(b, e, w):
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    out = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_many_ref(prefixes, exts))


def test_bitmap_join_many_each_row_matches_single_prefix_kernel():
    """Batch semantics: row b of the batched launch is exactly the
    single-prefix kernel run on (prefixes[b], exts[b])."""
    b, e, w = 4, 10, 40
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    batched = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    for i in range(b):
        np.testing.assert_array_equal(
            batched[i], bitmap_join_kernel(prefixes[i], exts[i],
                                           interpret=True))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 6), st.integers(1, 32), st.integers(1, 70))
def test_property_bitmap_join_many_random(b, e, w):
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    out = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_many_ref(prefixes, exts))
