"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.bitmap_join.kernel import (bitmap_join_kernel,
                                              bitmap_join_many_kernel)
from repro.kernels.bitmap_join.ops import bitmap_join
from repro.kernels.bitmap_join.ref import (bitmap_join_many_ref,
                                           bitmap_join_ref)
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_gram.kernel import masked_gram_kernel
from repro.kernels.masked_gram.ref import masked_gram_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------ bitmap_join
@pytest.mark.parametrize("e,w", [(1, 1), (7, 33), (256, 512), (300, 700),
                                 (513, 1025)])
def test_bitmap_join_shapes(e, w):
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=w, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(e, w),
                                    dtype=np.uint32))
    out = bitmap_join_kernel(prefix, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_ref(prefix, exts))


def test_bitmap_join_ops_dispatches_to_ref_on_cpu():
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=64, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(8, 64),
                                    dtype=np.uint32))
    np.testing.assert_array_equal(bitmap_join(prefix, exts),
                                  bitmap_join_ref(prefix, exts))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 96))
def test_property_bitmap_join_random(e, w):
    prefix = jnp.asarray(RNG.integers(0, 2 ** 32, size=w, dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(e, w),
                                    dtype=np.uint32))
    out = bitmap_join_kernel(prefix, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_ref(prefix, exts))


# ------------------------------------------------- bitmap_join_many (batched)
@pytest.mark.parametrize("b,e,w", [(1, 1, 1), (3, 7, 33), (2, 64, 512),
                                   (5, 70, 600)])
def test_bitmap_join_many_shapes(b, e, w):
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    out = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_many_ref(prefixes, exts))


def test_bitmap_join_many_each_row_matches_single_prefix_kernel():
    """Batch semantics: row b of the batched launch is exactly the
    single-prefix kernel run on (prefixes[b], exts[b])."""
    b, e, w = 4, 10, 40
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    batched = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    for i in range(b):
        np.testing.assert_array_equal(
            batched[i], bitmap_join_kernel(prefixes[i], exts[i],
                                           interpret=True))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 6), st.integers(1, 32), st.integers(1, 70))
def test_property_bitmap_join_many_random(b, e, w):
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, e, w),
                                    dtype=np.uint32))
    out = bitmap_join_many_kernel(prefixes, exts, interpret=True)
    np.testing.assert_array_equal(out, bitmap_join_many_ref(prefixes, exts))


# ------------------------------------------------------------ masked_gram
@pytest.mark.parametrize("i,t", [(1, 1), (5, 40), (128, 512), (130, 515),
                                 (200, 900)])
def test_masked_gram_shapes(i, t):
    a = jnp.asarray((RNG.random((i, t)) < 0.3).astype(np.float32))
    m = jnp.asarray((RNG.random(t) < 0.5).astype(np.float32))
    out = masked_gram_kernel(a, m, interpret=True)
    np.testing.assert_allclose(out, masked_gram_ref(a, m), atol=1e-3)


def test_masked_gram_counts_are_supports():
    """C[i,j] must equal |prefix ∩ i ∩ j| exactly (integers in f32)."""
    bits = (RNG.random((9, 200)) < 0.4)
    mask = (RNG.random(200) < 0.5)
    a = jnp.asarray(bits.astype(np.float32))
    m = jnp.asarray(mask.astype(np.float32))
    out = np.asarray(masked_gram_kernel(a, m, interpret=True))
    for i in range(9):
        for j in range(9):
            want = int(np.sum(bits[i] & bits[j] & mask))
            assert out[i, j] == want


# -------------------------------------------------------- flash_attention
@pytest.mark.parametrize("s,t,d", [(128, 128, 64), (256, 256, 64),
                                   (257, 257, 64), (128, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(s, t, d, causal):
    if causal and s != t:
        pytest.skip("causal assumes aligned q/kv")
    if not causal and (t % 128):
        pytest.skip("non-causal ragged handled by ops wrapper via ref")
    q = jnp.asarray(RNG.standard_normal((2, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, t, d)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=3e-2, rtol=3e-2)


def test_flash_attention_matches_model_blockwise_path():
    """Kernel and models/attention.py q-chunked path agree on one oracle."""
    from repro.configs.registry import get_smoke_config
    from repro.models import attention as mattn
    cfg = get_smoke_config("olmo-1b").with_(
        dtype="float32", attn_blockwise_threshold=64, attn_block_q=64)
    b, s, h, d = 2, 256, 4, 16
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    blockwise = mattn.attention(cfg, q, k, v, causal=True)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kern = flash_attention_kernel(qf, kf, vf, causal=True, interpret=True)
    kern = kern.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(blockwise), np.asarray(kern),
                               atol=2e-5, rtol=1e-4)
