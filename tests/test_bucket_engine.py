"""Bucket-sweep engine: planning, equivalence across policies ×
granularities × datasets, locality accounting, property tests."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import tidlist
from repro.core.buckets import (bucket_rows_touched,
                                candidate_rows_touched, group_by_prefix,
                                rows_to_bytes)
from repro.core.fpm import mine, mine_serial
from repro.core.itemsets import (brute_force_frequent, gen_candidates,
                                 prefix_hash)
from repro.core.tidlist import pack_database
from repro.data.transactions import load

POLICIES = ["cilk", "fifo", "clustered", "nn"]


# ------------------------------------------------------------- planning
def test_group_by_prefix_partitions_candidates():
    cands = [(0, 1, 2), (0, 1, 5), (0, 1, 3), (2, 3, 4), (2, 3, 9)]
    buckets = group_by_prefix(cands)
    assert len(buckets) == 2
    regen = [c for b in buckets for c in b.candidates()]
    assert sorted(regen) == sorted(cands)
    for b in buckets:
        assert b.exts == tuple(sorted(b.exts))
        assert b.key == prefix_hash(b.prefix + (b.exts[0],))


def test_group_by_prefix_on_real_candidates():
    db, p = load("mushroom", seed=0)
    bm = pack_database(db[:200], p.n_dense_items)
    freq = sorted(mine_serial(bm, 60, max_k=2))
    cands = gen_candidates([f for f in freq if len(f) == 2])
    buckets = group_by_prefix(cands)
    assert sum(len(b) for b in buckets) == len(cands)
    assert len({b.prefix for b in buckets}) == len(buckets)


def test_traffic_model_bucket_beats_candidate():
    # 1 bucket of E extensions at level k: (k-1)+E rows vs k*E rows
    k, e = 4, 32
    assert bucket_rows_touched(k - 1, e) < candidate_rows_touched(k, e)
    assert rows_to_bytes(10, 8) == 10 * 8 * 4


# ---------------------------------------------------------- equivalence
@pytest.fixture(scope="module")
def datasets():
    out = {}
    for name, n_txn, frac in [("mushroom", 250, 0.3), ("chess", 150, 0.8),
                              ("retail", 800, 0.03)]:
        db, p = load(name, seed=0)
        db = db[:n_txn]
        n_items = p.n_dense_items if p.kind == "dense" else p.n_items
        bm = pack_database(db, n_items)
        ms = int(frac * len(db))
        out[name] = (db, bm, ms)
    return out


@pytest.mark.parametrize("name", ["mushroom", "chess"])
def test_serial_matches_brute_force(datasets, name):
    db, bm, ms = datasets[name]
    assert mine_serial(bm, ms, max_k=4) == brute_force_frequent(
        db, ms, max_k=4)


@pytest.mark.parametrize("granularity",
                         ["bucket", "candidate", "depth-first"])
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("name", ["mushroom", "chess", "retail"])
def test_engine_equivalence(datasets, name, policy, granularity):
    """The acceptance matrix: every policy × every granularity returns
    supports identical to the serial reference, on three datasets
    (dense mushroom/chess + the sparse long-tail retail profile)."""
    db, bm, ms = datasets[name]
    ref = mine_serial(bm, ms, max_k=4)
    got, met = mine(bm, ms, policy=policy, n_workers=3, max_k=4,
                    granularity=granularity)
    assert got == ref, (name, policy, granularity)
    assert met.scheduler["tasks_run"] == met.scheduler["spawned"]


def test_bucket_rows_touched_below_candidate(datasets):
    """Locality, measured: the bucket sweep reads each prefix once."""
    _, bm, ms = datasets["mushroom"]
    _, m_b = mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                  granularity="bucket")
    _, m_c = mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                  granularity="candidate")
    assert 0 < m_b.rows_touched < m_c.rows_touched
    assert 0 < m_b.bytes_swept < m_c.bytes_swept


@pytest.mark.parametrize("backend", ["numpy", "pallas-interpret"])
@pytest.mark.parametrize("granularity",
                         ["bucket", "candidate", "depth-first"])
def test_backend_granularity_equivalence(datasets, granularity, backend):
    """The arena/dispatcher acceptance matrix: every granularity ×
    every CPU-capable backend produces identical frequent itemsets
    through the handle-based request path."""
    _, bm, ms = datasets["mushroom"]
    ref = mine_serial(bm, ms, max_k=3)
    got, met = mine(bm, ms, policy="clustered", n_workers=2, max_k=3,
                    granularity=granularity, backend=backend)
    assert got == ref, (granularity, backend)
    if granularity != "candidate":
        # sweeps went through the dispatcher, and every request was
        # answered by a flush
        assert met.flushes > 0
        assert round(met.flushes * met.batch_occupancy) == \
            met.scheduler["sweeps_submitted"]
    if backend == "pallas-interpret" and granularity != "candidate":
        # device-resident arena: the h2d gauge saw the initial upload
        # plus incrementally synced prefix/handoff rows (at most ~2 per
        # sweep) — never a per-sweep re-upload of extension bitmaps
        row_bytes = bm.shape[1] * 4
        sweeps = met.scheduler["sweeps_submitted"]
        assert bm.nbytes <= met.h2d_bytes <= \
            bm.nbytes + 2 * sweeps * row_bytes


def test_bad_granularity_raises(datasets):
    _, bm, ms = datasets["mushroom"]
    with pytest.raises(ValueError, match="granularity"):
        mine(bm, ms, granularity="itemset")


@pytest.mark.parametrize("granularity", ["bucket", "candidate"])
def test_cache_size_zero_is_a_valid_no_cache_knob(datasets, granularity):
    """cache_size=0 (the 'no cache' A/B setting) must work: get()
    retains a caller reference before the instant eviction releases
    the cache's own, so the handle stays live through the sweep."""
    _, bm, ms = datasets["chess"]
    ref = mine_serial(bm, ms, max_k=4)
    got, met = mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                    granularity=granularity, cache_size=0)
    assert got == ref
    assert met.cache_hits == 0               # nothing ever cached


# ----------------------------------------------------- depth-first engine
def test_depth_first_handoff_makes_cache_vestigial(datasets):
    """The parent→child bitmap handoff: no prefix is ever recomputed or
    cache-probed, so the LRU cache shows zero traffic; the engine also
    reports its retained-bitmap peak (children exist on this dataset)."""
    _, bm, ms = datasets["retail"]
    got, met = mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
                    granularity="depth-first")
    assert met.cache_hits == met.cache_misses == 0
    assert met.peak_retained_bitmaps > 0        # children were spawned
    assert met.peak_bytes_retained > 0
    assert met.buckets == met.scheduler["tasks_run"]
    assert got == mine_serial(bm, ms, max_k=4)


def test_depth_first_child_error_surfaces_on_driver(datasets, monkeypatch):
    """A task body raising inside a spawned-from-task child class must
    surface on the driver thread (not deadlock the terminal wait_all).
    Child classes are exactly the tasks whose prefix handle is an OWNED
    materialized arena row (handle >= n_base); root classes hand the
    pinned base row's handle (== item id)."""
    from repro.core import fpm as fpm_mod
    from repro.core.join_backend import NumpyBackend

    class ChildBomb(NumpyBackend):
        def sweep_many(self, arena, requests):
            if any(r.prefix_handle >= arena.n_base for r in requests):
                raise RuntimeError("child boom")
            return super().sweep_many(arena, requests)

    monkeypatch.setattr(fpm_mod, "resolve_backend",
                        lambda spec: ChildBomb())
    _, bm, ms = datasets["retail"]
    with pytest.raises(RuntimeError, match="child boom"):
        mine(bm, ms, policy="clustered", n_workers=3, max_k=4,
             granularity="depth-first")


def test_depth_first_single_frequent_item_spawns_nothing():
    db = [[0], [0], [0]]
    bm = pack_database(db, 1)
    got, met = mine(bm, 2, granularity="depth-first", n_workers=2)
    assert got == {(0,): 3}
    assert met.scheduler["spawned"] == 0


# ------------------------------------------------------ property tests
@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 15), max_size=8), min_size=1,
                max_size=30))
def test_property_pack_unpack_roundtrip(db):
    db = [sorted(set(t)) for t in db]
    bits = np.zeros((16, len(db)), dtype=bool)
    for t, txn in enumerate(db):
        for i in txn:
            bits[i, t] = True
    packed = tidlist.pack_bool(bits)
    back = tidlist.unpack_bool(packed, len(db))
    np.testing.assert_array_equal(back, bits)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 30), st.integers(0, 2 ** 31))
def test_property_support_counts_vs_naive_loop(e, w, seed):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, 2 ** 32, size=w, dtype=np.uint32)
    exts = rng.integers(0, 2 ** 32, size=(e, w), dtype=np.uint32)
    got = tidlist.support_counts(prefix, exts)
    want = [sum(bin(int(prefix[j]) & int(exts[i, j])).count("1")
                for j in range(w)) for i in range(e)]
    np.testing.assert_array_equal(got, np.array(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_bucket_engine_equals_brute_force(seed):
    rng = np.random.default_rng(seed)
    db = [sorted(rng.choice(10, size=rng.integers(1, 6),
                            replace=False).tolist()) for _ in range(40)]
    ms = int(rng.integers(2, 10))
    ref = brute_force_frequent(db, ms, max_k=4)
    bm = pack_database(db, 10)
    for gran in ("bucket", "depth-first"):
        got, _ = mine(bm, ms, policy="clustered", n_workers=2, max_k=4,
                      granularity=gran)
        assert got == ref, gran
