"""Candidate generation + prefix hash tests (paper §2, §4)."""
import pytest
from _hyp import given, settings, st

from repro.core.itemsets import (brute_force_frequent, gen_candidates,
                                 prefix_hash)


def test_gen_candidates_example_from_paper():
    # Paper §2: frequent {AB, AC, AD} at stage 2 -> candidates
    # {ABC, ABD, ACD} at stage 3 (A=0, B=1, C=2, D=3) — before the
    # anti-monotone prune (BC, BD, CD are not frequent so all 3-itemsets
    # get pruned; with prune disabled for k<=2-subsets only ABC needs BC..)
    frequent = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]
    cands = gen_candidates(frequent)
    assert (0, 1, 2) in cands and (0, 1, 3) in cands
    assert (0, 2, 3) in cands and (1, 2, 3) in cands


def test_gen_candidates_prunes_infrequent_subsets():
    # (1,2) missing -> (0,1,2) must be pruned
    frequent = [(0, 1), (0, 2), (0, 3), (2, 3)]
    cands = gen_candidates(frequent)
    assert (0, 1, 2) not in cands
    assert (0, 2, 3) in cands


def test_prefix_hash_clusters_same_prefix():
    # ABC and ABD share prefix AB -> same bucket (paper §4)
    assert prefix_hash((0, 1, 2)) == prefix_hash((0, 1, 3))
    assert prefix_hash((0, 1, 2)) != prefix_hash((0, 2, 3))


def test_prefix_hash_xor_is_order_insensitive_over_prefix():
    assert prefix_hash((1, 2, 9)) == prefix_hash((2, 1, 9))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(0, 11), min_size=1, max_size=6),
                min_size=1, max_size=30),
       st.integers(1, 5))
def test_property_anti_monotone(db, min_support):
    """Every subset of a frequent itemset is frequent (Apriori core)."""
    db = [sorted(set(t)) for t in db]
    freq = brute_force_frequent(db, min_support, max_k=4)
    for itemset, sup in freq.items():
        assert sup >= min_support
        if len(itemset) > 1:
            for j in range(len(itemset)):
                sub = itemset[:j] + itemset[j + 1:]
                assert sub in freq
                assert freq[sub] >= sup
