"""Checkpoint save/restore/async/gc + fault-tolerant loop tests."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime.fault import FaultInjector, run_with_recovery


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)},
            "d": jnp.zeros((), jnp.float32)}


def test_save_load_roundtrip(tmp_path):
    t = tree()
    ckpt.save(tmp_path, 3, t, extra={"note": "hi"})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, man = ckpt.load(tmp_path, 3, like)
    assert man["step"] == 3 and man["extra"]["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_atomicity_no_tmp_left(tmp_path):
    ckpt.save(tmp_path, 1, tree())
    assert not list(tmp_path.glob("*.tmp"))
    assert ckpt.list_steps(tmp_path) == [1]


def test_async_checkpointer_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        saver.save_async(s, tree())
    saver.wait()
    assert ckpt.list_steps(tmp_path) == [3, 4]
    assert ckpt.latest_step(tmp_path) == 4


def test_load_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, tree())
    bad = {"a": jax.ShapeDtypeStruct((2, 4), jnp.float32),
           "b": {"c": jax.ShapeDtypeStruct((5,), jnp.int32)},
           "d": jax.ShapeDtypeStruct((), jnp.float32)}
    with pytest.raises(ValueError):
        ckpt.load(tmp_path, 1, bad)


def test_run_with_recovery_restores_after_fault(tmp_path):
    """Inject a failure mid-run; the loop must resume from the last
    checkpoint and produce the exact same final state as a clean run."""
    def step_fn(params, opt_state, batch):
        return params + batch, opt_state + 1, {"loss": jnp.sum(params)}

    def batches(step):
        return jnp.float32(step + 1)

    init = (jnp.zeros(()), jnp.zeros((), jnp.int32))
    clean, _ = run_with_recovery(
        step_fn=step_fn, init_state=init, batch_iter=batches,
        n_steps=20, ckpt_dir=str(tmp_path / "clean"), ckpt_every=5)
    faulty, report = run_with_recovery(
        step_fn=step_fn, init_state=init, batch_iter=batches,
        n_steps=20, ckpt_dir=str(tmp_path / "faulty"), ckpt_every=5,
        fault_injector=FaultInjector(fail_at=[12]))
    assert report.restarts == 1
    assert float(clean[0]) == float(faulty[0]) == sum(range(1, 21))
    assert int(clean[1]) == 20


def test_recovery_gives_up_after_max_restarts(tmp_path):
    def step_fn(params, opt_state, batch):
        raise RuntimeError("always dying")

    with pytest.raises(RuntimeError):
        run_with_recovery(
            step_fn=step_fn, init_state=(jnp.zeros(()), jnp.zeros(())),
            batch_iter=lambda s: 0.0, n_steps=5,
            ckpt_dir=str(tmp_path), max_restarts=2)


def test_straggler_detection(tmp_path):
    """Steps exceeding the deadline are counted as straggler events."""
    import time as _t

    def step_fn(params, opt_state, batch):
        if int(opt_state) == 2:
            _t.sleep(0.12)
        return params, opt_state + 1, {"loss": params}

    (_, _), report = run_with_recovery(
        step_fn=step_fn,
        init_state=(jnp.zeros(()), jnp.zeros((), jnp.int32)),
        batch_iter=lambda s: None, n_steps=5,
        ckpt_dir=str(tmp_path), step_deadline_s=0.05)
    assert report.straggler_events == 1
