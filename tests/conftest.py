# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# 1 device. Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
