"""Data layer tests: the synthetic transaction generator."""
import numpy as np
import pytest

from repro.core.tidlist import pack_database
from repro.core.fpm import mine_serial
from repro.data.transactions import PROFILES, load, min_support_count


@pytest.mark.parametrize("profile", ["chess", "mushroom", "t10i4",
                                     "retail"])
def test_profiles_generate_valid_dbs(profile):
    db, p = load(profile, seed=0)
    n_items = p.n_dense_items if p.kind == "dense" else p.n_items
    assert len(db) == p.n_transactions
    assert all(0 <= i < n_items for t in db[:100] for i in t)
    assert all(len(set(t)) == len(t) for t in db[:100])


def test_dense_profiles_are_denser_than_quest():
    chess, pc = load("chess", 0)
    t10, pt = load("t10i4", 0)
    d_chess = np.mean([len(t) for t in chess]) / pc.n_dense_items
    d_t10 = np.mean([len(t) for t in t10]) / pt.n_items
    assert d_chess > 5 * d_t10


def test_generator_deterministic():
    a, _ = load("mushroom", seed=42)
    b, _ = load("mushroom", seed=42)
    assert a[:50] == b[:50]


def test_retail_profile_is_sparse_long_tail():
    """The retail profile must be a sparse long-tail regime: steep item
    popularity skew (a few head items carry much of the traffic, a long
    tail of rare items) at low density — the deep-narrow-equivalence-
    class regime the depth-first engine targets."""
    db, p = load("retail", 0)
    assert p.kind == "quest" and p.zipf > PROFILES["t10i4"].zipf
    counts = np.zeros(p.n_items)
    for t in db:
        for i in t:
            counts[i] += 1
    order = np.sort(counts)[::-1]
    head = order[: p.n_items // 100].sum() / counts.sum()
    assert head > 0.2                       # top-1% items: heavy head
    assert np.median(counts) < order[0] / 50    # long rare tail
    density = np.mean([len(t) for t in db]) / p.n_items
    assert density < 0.05                   # sparse


def test_retail_profile_yields_deep_itemsets():
    """Low support + correlated Quest patterns must produce k>=4
    frequent itemsets — deep classes, the depth-first regime."""
    db, p = load("retail", 0)
    db = db[:3000]
    bm = pack_database(db, p.n_items)
    res = mine_serial(bm, int(p.support * len(db)), max_k=4)
    assert any(len(k) >= 4 for k in res)


def test_profiles_yield_multilevel_itemsets():
    """Support thresholds must produce deep (k>=3) frequent itemsets —
    otherwise the clustering experiment is vacuous."""
    db, p = load("chess", 0)
    bm = pack_database(db[:800], p.n_dense_items)
    res = mine_serial(bm, int(p.support * 800), max_k=4)
    assert any(len(k) >= 3 for k in res)
