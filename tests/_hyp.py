"""Optional-hypothesis shim: property tests must never break collection.

Test modules import ``given``, ``settings`` and ``st`` from here instead
of from ``hypothesis`` directly. On a bare interpreter (no hypothesis —
the seed suite hard-failed at collection on this) every ``@given`` test
becomes a cleanly-skipped zero-arg test; everything else in the module
still collects and runs. With hypothesis installed this module is a
pass-through re-export.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Accepts any strategy-building call chain and returns itself,
        so module-level strategy expressions evaluate harmlessly."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # a fresh zero-arg function: pytest must not mistake the
            # strategy parameters for fixtures
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
