"""Sharding-rule resolution tests (mesh-independent logic, no devices)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import sharding as shd


class FakeMesh:
    """Only .shape is consulted by spec_for."""
    def __init__(self, shape):
        self.shape = shape


def rules():
    return shd.RULESETS["default"]


def test_divisible_dims_get_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for((152064, 4096), ("vocab", "embed"), mesh, rules())
    assert spec == P("model", "data")


def test_non_divisible_dim_falls_back_to_replicated():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 40 heads % 16 != 0 -> replicated head axis (qwen2.5 case)
    spec = shd.spec_for((5120, 40, 128), ("embed", "heads", "head_dim"),
                        mesh, rules())
    assert spec == P("data", None, None)


def test_axis_used_once_per_spec():
    mesh = FakeMesh({"data": 16, "model": 16})
    # both dims want 'model': only the first gets it
    spec = shd.spec_for((128, 1536), ("experts", "ff"), mesh, rules())
    assert spec == P("model", None)


def test_multipod_batch_uses_both_dp_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = shd.spec_for((256, 4096), ("batch", "seq"), mesh, rules())
    assert spec == P(("pod", "data"), None)


def test_singlepod_batch_skips_missing_pod_axis():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for((256, 4096), ("batch", "seq"), mesh, rules())
    assert spec == P("data", None)


def test_seq_parallel_activation_rule():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for((256, 4096, 4096),
                        ("act_batch", "act_seq", "act_embed"), mesh,
                        rules())
    assert spec == P("data", "model", None)


def test_decode_ruleset_shards_cache_seq_when_heads_cannot():
    mesh = FakeMesh({"data": 16, "model": 16})
    r = shd.RULESETS["decode"]
    # glm4: kv_heads=2 not divisible -> cache_seq takes model
    spec = shd.spec_for((40, 128, 32768, 2, 128),
                        ("layers", "batch", "cache_seq", "kv_heads",
                         "head_dim"), mesh, r)
    assert spec == P(None, "data", "model", None, None)
    # olmo: kv=16 divisible -> heads take model, seq replicated
    spec = shd.spec_for((16, 128, 32768, 16, 128),
                        ("layers", "batch", "cache_seq", "kv_heads",
                         "head_dim"), mesh, r)
    assert spec == P(None, "data", None, "model", None)


def test_tree_specs_roundtrip():
    from repro.configs.registry import get_smoke_config
    from repro.models.common import axes_tree
    from repro.models.registry import build_model
    mesh = FakeMesh({"data": 2, "model": 2})
    m = build_model(get_smoke_config("glm4-9b"))
    specs = shd.tree_specs(m.param_shapes(), axes_tree(m.param_defs()),
                           mesh, rules())
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)
    assert len(flat) == len(jax.tree.leaves(m.param_shapes()))


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_dryrun_collective_parser():
    """The HLO collective parser sums result-buffer bytes per op kind."""
    import importlib
    import os
    import subprocess
    import sys
    # import the parser without triggering the 512-device XLA_FLAGS in
    # this process: run in a subprocess
    code = """
import sys; sys.path.insert(0, 'src')
from repro.launch.dryrun import parse_collectives, collective_link_bytes
hlo = '''
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar = bf16[1024]{0} all-reduce(%y), to_apply=%add
  %aa = s8[2,2]{1,0} all-to-all(%z)
  %cp = f32[4]{0} collective-permute-start(%w)
  %no = f32[8]{0} add(%a, %b)
'''
out = parse_collectives(hlo)
assert out['bytes']['all-gather'] == 16*128*4, out
assert out['bytes']['all-reduce'] == 1024*2
assert out['bytes']['all-to-all'] == 4
assert out['bytes']['collective-permute'] == 16
assert out['counts']['all-gather'] == 1
lb = collective_link_bytes(out)
assert lb == 2*1024*2 + 16*128*4 + 4 + 16
print('ok')
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-1500:]
    assert "ok" in r.stdout
