"""Per-arch smoke tests (reduced configs): fwd/train step, shapes, no NaNs,
decode==apply consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.common import padded_vocab
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(0)

# the heaviest smoke configs (~20 s compile+run each) ride in the slow
# tier (`pytest -m slow`); tier-1 keeps one arch per family fast
HEAVY_ARCHS = {"dbrx-132b", "zamba2-1.2b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
               if a in HEAVY_ARCHS else a for a in ARCH_IDS]


def _batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encdec.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    if cfg.family == "audio":
        logits, aux = m.apply(params, batch["tokens"], batch["frames"])
    else:
        logits, aux = m.apply(params, batch["tokens"])
    assert logits.shape == (2, 32, padded_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode_matches_apply(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    if cfg.moe:
        # ample capacity -> no token drops -> decode == teacher forcing
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=16.0))
    m = build_model(cfg)
    params = m.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.family == "audio":
        frames = jax.random.normal(KEY, (b, cfg.encdec.n_frames,
                                         cfg.d_model), jnp.float32)
        full, _ = m.apply(params, toks, frames)
        cache = m.init_cache(b, s)
        _, c2 = m.prefill(params, toks[:, :1], frames)
        cache["cross_kv"] = c2["cross_kv"]
    else:
        full, _ = m.apply(params, toks)
        cache = m.init_cache(b, s)
    outs = []
    for i in range(s):
        lg, cache = m.decode_step(params, cache, toks[:, i:i + 1],
                                  jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=1e-3)


def test_loss_decreases_on_tiny_train():
    """~200-step driver check is examples/quickstart; 30 steps here."""
    from repro.configs.base import OptimizerConfig
    from repro.optim import adamw
    cfg = get_smoke_config("olmo-1b")
    m = build_model(cfg)
    params = m.init(KEY)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    opt = adamw.init(params)
    batch = _batch(cfg, b=4, s=64)   # fixed batch: loss must fall fast

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(m.loss)(params, batch)
        upd, opt, _ = adamw.update(ocfg, g, opt, params)
        return adamw.apply_updates(params, upd), opt, loss

    losses = []
    for _ in range(60):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_sliding_window_attention_masks_far_tokens():
    from repro.configs.registry import get_smoke_config
    from repro.models import attention as mattn
    cfg = get_smoke_config("olmo-1b").with_(dtype="float32")
    b, s, h, d = 1, 32, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(KEY, (b, s, h, d))
    v = jax.random.normal(KEY, (b, s, h, d))
    full = mattn.attention(cfg, q, k, v, causal=True)
    win = mattn.attention(cfg, q, k, v, causal=True, window=4)
    # early positions (inside window) match; late positions differ
    np.testing.assert_allclose(np.asarray(full[:, :4]),
                               np.asarray(win[:, :4]), atol=1e-5)
    assert float(jnp.max(jnp.abs(full[:, -1] - win[:, -1]))) > 1e-4


@pytest.mark.slow
def test_ring_buffer_decode_matches_full_cache_inside_window():
    """Hybrid long-ctx: ring-buffer window cache == full cache + window
    mask, for positions beyond the window. (zamba2 smoke config — the
    heaviest compile in the suite, so it rides in the slow tier.)"""
    from repro.models import attention as mattn
    cfg = get_smoke_config("zamba2-1.2b").with_(dtype="float32")
    m = build_model(cfg)
    params = m.init(KEY)
    b, s = 1, 24
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    w = cfg.hybrid.long_ctx_window  # smoke: 64 > s — use manual window
    # run with window=8 ring buffer vs window=8 mask on full-length cache
    cache_defs = m.cache_defs(b, s)
    full_cache = m.init_cache(b, s)
    outs_full = []
    for i in range(s):
        lg, full_cache = m.decode_step(params, full_cache,
                                       toks[:, i:i + 1], jnp.int32(i),
                                       window=8)
        outs_full.append(lg[:, 0])
    # ring buffer: cache length = window
    import repro.models.ssm as ssm_mod
    from repro.models import attention as attn_mod
    n_sites, ae, tail = m._layer_split()
    ring_cache = {
        "ssm": jax.tree.map(lambda a: a,
                            full_cache["ssm"]),
    }
    ring_cache = m.init_cache(b, s)
    ring_cache["kv"] = {
        kk: jnp.zeros((n_sites, b, 8) + vv.shape[3:], vv.dtype)
        for kk, vv in ring_cache["kv"].items()}
    outs_ring = []
    for i in range(s):
        lg, ring_cache = m.decode_step(params, ring_cache,
                                       toks[:, i:i + 1], jnp.int32(i),
                                       window=8)
        outs_ring.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs_full, 1)),
                               np.asarray(jnp.stack(outs_ring, 1)),
                               atol=1e-4, rtol=1e-3)


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV cache: <1% logit error, identical greedy tokens."""
    cfg = get_smoke_config("stablelm-3b").with_(dtype="float32")
    m = build_model(cfg)
    params = m.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _ = m.apply(params, toks)
    m8 = build_model(cfg.with_(kv_cache_dtype="int8"))
    cache = m8.init_cache(b, s)
    assert cache["kv"]["k"].dtype == jnp.int8
    outs = []
    for i in range(s):
        lg, cache = m8.decode_step(params, cache, toks[:, i:i + 1],
                                   jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 0.05
    assert bool(jnp.all(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))
