"""MoE dispatch-policy tests: clustered == onehot routing semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import MoEConfig
from repro.configs.registry import get_smoke_config
from repro.models import moe as moe_mod
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(7)


def make(cf=8.0, e=8, k=2):
    cfg = get_smoke_config("qwen3-moe-235b-a22b").with_(
        dtype="float32",
        moe=MoEConfig(n_experts=e, top_k=k, capacity_factor=cf))
    m = build_model(cfg)
    p = jax.tree.map(lambda a: a[0], m.init(KEY)["blocks"]["moe"])
    return cfg, p


@pytest.mark.parametrize("g", [1, 2, 4])
def test_clustered_equals_onehot_no_drop(g):
    cfg, p = make(cf=16.0)
    x = jax.random.normal(KEY, (64, cfg.d_model), jnp.float32)
    yc, auxc = moe_mod.moe_clustered(cfg, p, x, g)
    yo, auxo = moe_mod.moe_onehot(cfg, p, x, g)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yo), atol=1e-5)
    assert abs(float(auxc - auxo)) < 1e-6


def test_every_token_gets_topk_outputs_no_drop():
    cfg, p = make(cf=16.0, e=4, k=2)
    x = jax.random.normal(KEY, (32, cfg.d_model), jnp.float32)
    y, _ = moe_mod.moe_clustered(cfg, p, x, 1)
    # no row should be exactly zero (all tokens routed)
    norms = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.min(norms)) > 0


def test_capacity_drops_reduce_output_norm():
    cfg_hi, p = make(cf=16.0, e=8, k=2)
    cfg_lo = cfg_hi.with_(moe=MoEConfig(n_experts=8, top_k=2,
                                        capacity_factor=0.25))
    x = jax.random.normal(KEY, (128, cfg_hi.d_model), jnp.float32)
    y_hi, _ = moe_mod.moe_clustered(cfg_hi, p, x, 1)
    y_lo, _ = moe_mod.moe_clustered(cfg_lo, p, x, 1)
    # low capacity drops tokens -> some rows zeroed
    n_zero_lo = int(jnp.sum(jnp.linalg.norm(y_lo, axis=-1) < 1e-9))
    n_zero_hi = int(jnp.sum(jnp.linalg.norm(y_hi, axis=-1) < 1e-9))
    assert n_zero_lo > n_zero_hi


def _moe_dense_oracle(cfg, p, x):
    """Per-token dense oracle: run EVERY expert on EVERY token, weight by
    renormalized top-k gates (no capacity — ground truth for cf=∞)."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # all experts on all tokens: [E, T, D]
    h = jnp.einsum("td,edf->etf", x, p["wi"])
    g = jnp.einsum("td,edf->etf", x, p["wg"])
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * h, p["wo"])
    t = x.shape[0]
    out = jnp.zeros_like(x)
    for kk in range(m.top_k):
        out = out + top_p[:, kk:kk + 1] * ye[top_e[:, kk],
                                             jnp.arange(t)]
    return out


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_property_clustered_matches_dense_oracle(seed):
    cfg, p = make(cf=16.0, e=4, k=2)
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (16, cfg.d_model), jnp.float32)
    y, _ = moe_mod.moe_clustered(cfg, p, x, 1)
    want = _moe_dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_onehot_group_size_controls_groups():
    cfg, p = make()
    cfg2 = cfg.with_(moe=MoEConfig(n_experts=8, top_k=2, dispatch="onehot",
                                   onehot_group=16))
    assert moe_mod._n_groups(cfg2, 64) == 4
    cfg3 = cfg.with_(moe=MoEConfig(n_experts=8, top_k=2, n_groups=8))
    assert moe_mod._n_groups(cfg3, 64) == 8
