"""Join-backend layer: numpy vs Pallas parity, per-bucket selection."""
import numpy as np
import pytest

from repro.core import join_backend as jb
from repro.core import tidlist

RNG = np.random.default_rng(7)


def rand_bitmaps(e, w):
    prefix = RNG.integers(0, 2 ** 32, size=w, dtype=np.uint32)
    exts = RNG.integers(0, 2 ** 32, size=(e, w), dtype=np.uint32)
    return prefix, exts


def naive_counts(prefix, exts):
    return np.array([sum(bin(int(prefix[w]) & int(exts[i, w])).count("1")
                         for w in range(len(prefix)))
                     for i in range(exts.shape[0])], dtype=np.int64)


@pytest.mark.parametrize("e,w", [(1, 1), (5, 9), (33, 64)])
def test_numpy_backend_matches_naive(e, w):
    prefix, exts = rand_bitmaps(e, w)
    got = jb.get_backend("numpy").sweep(prefix, exts)
    np.testing.assert_array_equal(got, naive_counts(prefix, exts))


@pytest.mark.parametrize("e,w", [(3, 8), (17, 40)])
def test_numpy_vs_pallas_interpret_parity(e, w):
    """The kernel path must be bit-exact with the numpy ufunc path."""
    prefix, exts = rand_bitmaps(e, w)
    a = jb.get_backend("numpy").sweep(prefix, exts)
    b = jb.get_backend("pallas-interpret").sweep(prefix, exts)
    np.testing.assert_array_equal(a, b)
    assert b.dtype == np.int64


def test_support_counts_chunked_matches_unchunked():
    prefix, exts = rand_bitmaps(50, 16)
    full = tidlist.support_counts(prefix, exts)
    chunked = tidlist.support_counts(prefix, exts, chunk=7)
    np.testing.assert_array_equal(full, chunked)


def test_get_backend_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown join backend"):
        jb.get_backend("cuda")


def test_selector_constant_for_named_backend():
    sel = jb.make_selector("pallas-interpret")
    assert sel(1).name == "pallas-interpret"
    assert sel(10_000).name == "pallas-interpret"


def test_selector_auto_is_numpy_on_cpu():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("auto selection differs on TPU")
    sel = jb.make_selector("auto")
    assert sel(1).name == "numpy"
    assert sel(jb.PALLAS_MIN_EXTS * 4).name == "numpy"


def test_available_backends_always_has_cpu_paths():
    names = jb.available_backends()
    assert "numpy" in names and "pallas-interpret" in names


def test_ops_mode_dispatch_parity():
    import jax.numpy as jnp

    from repro.kernels.bitmap_join.ops import bitmap_join
    prefix, exts = rand_bitmaps(9, 12)
    ref = bitmap_join(jnp.asarray(prefix), jnp.asarray(exts), mode="ref")
    itp = bitmap_join(jnp.asarray(prefix), jnp.asarray(exts),
                      mode="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(itp))
    with pytest.raises(ValueError, match="mode"):
        bitmap_join(jnp.asarray(prefix), jnp.asarray(exts), mode="gpu")


def test_unavailable_backend_fails_fast():
    """pallas-jit off-TPU must raise at selector creation — not inside
    a scheduler worker thread mid-mine (regression: this deadlocked
    wait_all before the scheduler recorded task errors)."""
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("pallas-jit is available on TPU")
    with pytest.raises(ValueError, match="not available"):
        jb.make_selector("pallas-jit")


def test_mine_with_unavailable_backend_raises_not_hangs():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("pallas-jit is available on TPU")
    from repro.core.fpm import mine
    bm = RNG.integers(0, 2 ** 32, size=(6, 2), dtype=np.uint32)
    with pytest.raises(ValueError, match="not available"):
        mine(bm, 1, n_workers=2, max_k=3, backend="pallas-jit")
