"""Join-backend layer: batched numpy vs Pallas parity, the sweep
dispatcher's coalescing/flush/error semantics, backend resolution."""
import threading

import numpy as np
import pytest

from repro.core import join_backend as jb
from repro.core import tidlist
from repro.core.tidlist import BitmapArena

RNG = np.random.default_rng(7)


def rand_arena(n_rows, w, backing="auto"):
    rows = RNG.integers(0, 2 ** 32, size=(n_rows, w), dtype=np.uint32)
    return BitmapArena.from_bitmaps(rows, backing=backing), rows


def naive_counts(prefix, exts):
    return np.array([sum(bin(int(prefix[w]) & int(exts[i, w])).count("1")
                         for w in range(len(prefix)))
                     for i in range(exts.shape[0])], dtype=np.int64)


def make_requests(n_rows, specs):
    """specs: list of (prefix_handle, ext_handles) pairs."""
    return [jb.SweepRequest(p, tuple(e)) for p, e in specs]


# ------------------------------------------------------------- backends
@pytest.mark.parametrize("e,w", [(1, 1), (5, 9), (33, 64)])
def test_numpy_backend_matches_naive(e, w):
    arena, rows = rand_arena(e + 1, w)
    reqs = make_requests(e + 1, [(0, range(1, e + 1))])
    (got,) = jb.get_backend("numpy").sweep_many(arena, reqs)
    np.testing.assert_array_equal(got, naive_counts(rows[0], rows[1:]))


@pytest.mark.parametrize("backing", ["auto", "numpy"])
def test_numpy_vs_pallas_interpret_parity_ragged(backing):
    """The batched kernel path must be bit-exact with the numpy path on
    a ragged batch (different extension counts per request — the padded
    and masked lanes must not leak into any request's counts), for both
    the device-gather and host-gather arena paths."""
    arena, rows = rand_arena(12, 40, backing=backing)
    specs = [(0, range(1, 12)),          # wide
             (3, [7]),                   # single extension
             (11, [0, 2, 4, 6, 8, 10]),  # strided
             (5, range(6, 9))]           # narrow
    a = jb.get_backend("numpy").sweep_many(
        arena, make_requests(12, specs))
    b = jb.get_backend("pallas-interpret").sweep_many(
        arena, make_requests(12, specs))
    assert len(a) == len(b) == len(specs)
    for (p, e), x, y in zip(specs, a, b):
        np.testing.assert_array_equal(x, y)
        np.testing.assert_array_equal(
            x, naive_counts(rows[p], rows[list(e)]))
        assert y.dtype == np.int64


def test_bitmap_join_many_mask_zeroes_padding():
    import jax.numpy as jnp

    from repro.kernels.bitmap_join.ops import bitmap_join_many
    prefixes = jnp.asarray(RNG.integers(0, 2 ** 32, size=(2, 8),
                                        dtype=np.uint32))
    exts = jnp.asarray(RNG.integers(0, 2 ** 32, size=(2, 5, 8),
                                    dtype=np.uint32))
    mask = jnp.asarray(np.array([[1, 1, 1, 0, 0],
                                 [1, 0, 0, 0, 0]], dtype=bool))
    got = np.asarray(bitmap_join_many(prefixes, exts, mask, mode="ref"))
    assert (got[0, 3:] == 0).all() and (got[1, 1:] == 0).all()
    assert got[0, 0] > 0 or got[0, 1] > 0   # real lanes survive


def test_support_counts_chunked_matches_unchunked():
    prefix = RNG.integers(0, 2 ** 32, size=16, dtype=np.uint32)
    exts = RNG.integers(0, 2 ** 32, size=(50, 16), dtype=np.uint32)
    full = tidlist.support_counts(prefix, exts)
    chunked = tidlist.support_counts(prefix, exts, chunk=7)
    np.testing.assert_array_equal(full, chunked)


# ----------------------------------------------------------- dispatcher
def test_dispatcher_coalesces_full_batch():
    """n_clients pending requests flush as ONE batched launch (the
    dispatcher knows no further request can arrive once every client
    is blocked). flush_us is set high so a premature partial flush
    would be visible as flushes > 1."""
    arena, rows = rand_arena(9, 6)
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"),
                              n_clients=4, flush_us=500_000)
    try:
        futs = [disp.submit(p, tuple(range(p + 1, 9))) for p in range(4)]
        for p, f in enumerate(futs):
            np.testing.assert_array_equal(
                f.result(timeout=10),
                naive_counts(rows[p], rows[p + 1:]))
        assert disp.flushes == 1
        assert disp.batch_occupancy == 4.0
    finally:
        disp.stop()


def test_dispatcher_partial_flush_on_timeout():
    """A lone request must not wait for a batch that will never fill:
    the flush_us deadline bounds its latency."""
    arena, rows = rand_arena(4, 3)
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"),
                              n_clients=8, flush_us=1_000)
    try:
        got = disp.sweep(0, (1, 2, 3))
        np.testing.assert_array_equal(got, naive_counts(rows[0], rows[1:]))
        assert disp.flushes == 1 and disp.batch_occupancy == 1.0
    finally:
        disp.stop()


def test_dispatcher_error_resolves_every_future():
    class Bomb(jb.JoinBackend):
        def sweep_many(self, arena, requests):
            raise RuntimeError("batch boom")

    arena, _ = rand_arena(4, 3)
    disp = jb.SweepDispatcher(arena, Bomb(), n_clients=2,
                              flush_us=200_000)
    try:
        f1 = disp.submit(0, (1,))
        f2 = disp.submit(1, (2, 3))
        for f in (f1, f2):
            with pytest.raises(RuntimeError, match="batch boom"):
                f.result(timeout=10)
    finally:
        disp.stop()


def test_dispatcher_concurrent_clients_agree_with_serial():
    """Many threads hammering the dispatcher get exactly their own
    counts back (no cross-request mixups under coalescing)."""
    arena, rows = rand_arena(20, 10)
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"),
                              n_clients=6)
    errs = []

    def client(p):
        try:
            exts = tuple(i for i in range(20) if i != p)
            for _ in range(5):
                got = disp.sweep(p, exts)
                np.testing.assert_array_equal(
                    got, naive_counts(rows[p], rows[list(exts)]))
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(p,))
               for p in range(6)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
    finally:
        disp.stop()
    assert not errs, errs
    assert disp.requests == 30


def test_dispatcher_submit_after_stop_raises():
    arena, _ = rand_arena(2, 2)
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"), n_clients=1)
    disp.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        disp.submit(0, (1,))


# ------------------------------------------------------------ resolution
def test_get_backend_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown join backend"):
        jb.get_backend("cuda")


def test_resolve_backend_named():
    assert jb.resolve_backend("pallas-interpret").name == \
        "pallas-interpret"
    assert jb.resolve_backend("numpy").name == "numpy"


def test_resolve_backend_auto_is_numpy_on_cpu():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("auto resolution differs on TPU")
    assert jb.resolve_backend("auto").name == "numpy"


def test_available_backends_always_has_cpu_paths():
    names = jb.available_backends()
    assert "numpy" in names and "pallas-interpret" in names


def test_e_pad_floor_matches_kernel_tile():
    """The batch E-pad floor must track the kernel's E tile: a smaller
    floor would mint distinct jit shapes the kernel re-pads to one tile
    anyway (pure compile-cache waste)."""
    from repro.kernels.bitmap_join.kernel import EB_TILE
    assert jb.E_PAD_FLOOR == EB_TILE


def test_ops_mode_dispatch_parity():
    import jax.numpy as jnp

    from repro.kernels.bitmap_join.ops import bitmap_join, bitmap_join_many
    prefix = RNG.integers(0, 2 ** 32, size=12, dtype=np.uint32)
    exts = RNG.integers(0, 2 ** 32, size=(9, 12), dtype=np.uint32)
    ref = bitmap_join(jnp.asarray(prefix), jnp.asarray(exts), mode="ref")
    itp = bitmap_join(jnp.asarray(prefix), jnp.asarray(exts),
                      mode="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(itp))
    with pytest.raises(ValueError, match="mode"):
        bitmap_join(jnp.asarray(prefix), jnp.asarray(exts), mode="gpu")
    with pytest.raises(ValueError, match="mode"):
        bitmap_join_many(jnp.asarray(prefix[None]),
                         jnp.asarray(exts[None]), mode="gpu")


def test_unavailable_backend_fails_fast():
    """pallas-jit off-TPU must raise at backend resolution — not inside
    the dispatcher thread mid-mine (regression: this deadlocked
    wait_all before the scheduler recorded task errors)."""
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("pallas-jit is available on TPU")
    with pytest.raises(ValueError, match="not available"):
        jb.resolve_backend("pallas-jit")


def test_mine_with_unavailable_backend_raises_not_hangs():
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("pallas-jit is available on TPU")
    from repro.core.fpm import mine
    bm = RNG.integers(0, 2 ** 32, size=(6, 2), dtype=np.uint32)
    with pytest.raises(ValueError, match="not available"):
        mine(bm, 1, n_workers=2, max_k=3, backend="pallas-jit")
