"""Production-rate query serving: batched unknown-itemset sweeps
through the live dispatchers, the negative border, device-resident
top-k, per-kind server counters, and multi-tenant fairness."""
import itertools
import threading

import numpy as np
import pytest
from _hyp import given, settings, st

import repro.core.streaming as streaming_mod
from repro.core.fpm import mine
from repro.core.join_backend import NumpyBackend, SweepDispatcher
from repro.core.streaming import (PatternServer, PatternSnapshot,
                                  StreamingMiner, TenantHub)
from repro.core.tidlist import BitmapArena, pack_database


def rand_db(n, items=12, seed=7):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(items, size=rng.integers(2, 6),
                              replace=False).tolist())
            for _ in range(n)]


def brute(db, itemset):
    want = set(itemset)
    return sum(1 for t in db if want <= set(t))


def batch_mine(db, n_items, ms, **kw):
    return mine(pack_database(db, n_items), ms, **kw)[0]


# ------------------------------------------------- dispatcher coalescing
def test_query_and_candidate_sweeps_share_one_flush():
    """A candidate-class sweep and a priority query sweep pending on
    the same dispatcher drain in ONE flush (occupancy 2) — the
    coalescing claim at its most deterministic: flush threshold 2,
    straggler window far beyond the test, so the only way both
    futures resolve is the shared batch."""
    db = rand_db(64, items=8, seed=3)
    arena = BitmapArena.from_bitmaps(pack_database(db, 8))
    disp = SweepDispatcher(arena, NumpyBackend(), n_clients=2,
                           flush_us=5_000_000.0,
                           query_flush_us=5_000_000.0)
    try:
        f_cand = disp.submit(0, (1,))                  # candidate-class
        f_query = disp.submit(2, (3,), priority=True)  # query-class
        assert int(f_cand.result(timeout=10)[0]) == brute(db, (0, 1))
        assert int(f_query.result(timeout=10)[0]) == brute(db, (2, 3))
        assert disp.queue_flushes == 1
        assert disp.queue_requests == 2
        assert disp.query_requests == 1
        assert disp.queue_requests / disp.queue_flushes > 1
    finally:
        disp.stop()


def test_priority_query_flushes_within_query_window():
    """A lone query-class request must not sit out the full straggler
    window: the dispatcher caps its wait at query_flush_us."""
    db = rand_db(32, items=6, seed=4)
    arena = BitmapArena.from_bitmaps(pack_database(db, 6))
    # candidate flush window 5s; query window 1ms
    disp = SweepDispatcher(arena, NumpyBackend(), n_clients=8,
                           flush_us=5_000_000.0, query_flush_us=1000.0)
    try:
        got = disp.submit(0, (1,), priority=True).result(timeout=2)
        assert int(got[0]) == brute(db, (0, 1))
    finally:
        disp.stop()


# ------------------------------------------------- exactness (sweeps)
@settings(max_examples=12, deadline=None)
@given(st.data())
def test_unknown_itemset_sweeps_match_brute_force(data):
    """support_many answers ARBITRARY itemsets exactly — hypothesis
    drives random databases and random (mostly never-counted) probes
    against per-transaction brute force."""
    n_items = 10
    db = data.draw(st.lists(
        st.lists(st.integers(0, n_items - 1), min_size=1, max_size=6,
                 unique=True),
        min_size=5, max_size=60))
    ms = data.draw(st.integers(1, max(1, len(db) // 2)))
    probes = data.draw(st.lists(
        st.lists(st.integers(0, n_items - 1), min_size=0, max_size=5,
                 unique=True),
        min_size=1, max_size=8))
    sm = StreamingMiner(n_items, ms, initial_db=db, n_workers=2,
                        max_k=3)
    sm.refresh()
    try:
        got = sm.support_many(probes)
        assert got == [brute(db, x) for x in probes]
        # repeats answer identically (now mostly dict hits)
        assert sm.support_many(probes) == got
    finally:
        sm.close()


def test_support_many_is_snapshot_consistent_across_publish():
    """A query batch racing a refresh answers ENTIRELY from the
    generation it was planned against: fired from the before_publish
    hook (mid-refresh, pre-swap) it must see the old boundary for
    every probe — singleton, known, and swept alike."""
    full = rand_db(300, items=12, seed=5)
    sm = StreamingMiner(12, 25, initial_db=full[:200], n_workers=2,
                        max_k=4)
    sm.refresh()
    sm.ingest(full[200:])
    probes = [(0, 1, 2, 3, 4), (3, 4), (1, 5, 7), (2,), ()]
    want_old = [brute(full[:200], x) if x else 200 for x in probes]
    want_new = [brute(full, x) if x else 300 for x in probes]
    seen = {}

    def hook(snapshot):
        seen["mid"] = sm.support_many(probes)

    sm.refresh(before_publish=hook)
    try:
        assert seen["mid"] == want_old
        # after the swap the same probes answer over the full database
        # (mid-refresh backfills went to the superseded store, so they
        # cannot leak stale counts into the new generation)
        assert sm.support_many(probes) == want_new
    finally:
        sm.close()


def test_query_backfill_repeat_hits_and_survives_refresh():
    """An answered query backfills the known store (repeat == dict
    hit) — and a later ingest touching its items re-sweeps rather
    than serving the stale backfill."""
    full = rand_db(260, items=10, seed=11)
    sm = StreamingMiner(10, 10_000, initial_db=full[:200],
                        n_workers=2, max_k=2)   # nothing frequent:
    sm.refresh()                                # every probe sweeps
    srv = PatternServer(sm)
    probe = (0, 1, 2)
    try:
        assert srv.support(probe) == brute(full[:200], probe)
        assert srv.merged_stats()["sweep"] == 1
        assert srv.support(probe) == brute(full[:200], probe)
        stats = srv.merged_stats()
        assert stats["sweep"] == 1 and stats["hit"] == 1
        sm.ingest(full[200:])
        sm.refresh()
        assert srv.support(probe) == brute(full, probe)
    finally:
        sm.close()


# ------------------------------------------------- negative border
def test_negative_border_published_and_served():
    db = ([[0, 1]] * 3 + [[0]] * 10 + [[1]] * 10 + [[2, 3]] * 12)
    sm = StreamingMiner(4, 5, initial_db=db, n_workers=2, max_k=3)
    sm.refresh()
    try:
        snap = sm.snapshot
        # counted but infrequent: published on the border, flagged
        assert snap.support((0, 1)) is None
        assert snap.support((0, 1), include_infrequent=True) == 3
        assert snap.lookup((0, 1)) == (3, True)
        assert snap.lookup((2, 3)) == (12, False)
        assert snap.lookup((0, 2))[1] is True   # support 0, counted
        srv = PatternServer(sm)
        assert srv.support((0, 1)) == 3         # border == dict hit,
        assert srv.merged_stats()["sweep"] == 0  # no sweep needed
    finally:
        sm.close()


# ------------------------------------------------- device-resident top-k
def _reference_top_k(supports, prefix, k):
    """The serving layer's documented ordering, computed the slow way:
    strict extensions of prefix, support descending, lexicographic
    ties."""
    prefix = tuple(sorted(prefix))
    rows = [(x, s) for x, s in supports.items()
            if len(x) > len(prefix) and x[:len(prefix)] == prefix]
    return [(x, -ns) for ns, x in
            sorted(((-s, x) for x, s in rows))[:k]]


def _tie_heavy_supports():
    rng = np.random.default_rng(0)
    supports = {}
    for i in range(20):
        supports[(i,)] = 50 + int(rng.integers(0, 4))
    for i, j in itertools.combinations(range(12), 2):
        supports[(i, j)] = 10 + (i + j) % 5          # dense tie bands
    for x in [(0, 1, 2), (0, 1, 3), (0, 2, 5), (1, 2, 3), (2, 3, 4)]:
        supports[x] = 7
    return supports


@pytest.mark.parametrize("prefix,k", [
    ((), 10), ((), 1000), ((0,), 4), ((1,), 1), ((0, 1), 5),
    ((0, 1, 2), 3), ((9, 10, 11, 12), 2), ((), 0),
])
def test_top_k_host_and_device_paths_match_reference(monkeypatch,
                                                     prefix, k):
    supports = _tie_heavy_supports()
    want = _reference_top_k(supports, prefix, k)
    host = PatternSnapshot(1, 100, 2, supports).top_k(prefix, k)
    assert host == want
    # force the device-resident path on the same data
    monkeypatch.setattr(streaming_mod, "TOPK_DEVICE_MIN", 0)
    dev = PatternSnapshot(1, 100, 2, supports).top_k(prefix, k)
    assert dev == want


def test_top_k_device_path_on_miner(monkeypatch):
    monkeypatch.setattr(streaming_mod, "TOPK_DEVICE_MIN", 0)
    db = rand_db(200, items=10, seed=13)
    sm = StreamingMiner(10, 20, initial_db=db, n_workers=2, max_k=4)
    sm.refresh()
    try:
        supports = dict(sm.snapshot.supports)
        for prefix in [(), (0,), (1, 3)]:
            assert sm.snapshot.top_k(prefix, 7) == _reference_top_k(
                supports, prefix, 7)
    finally:
        sm.close()


# ------------------------------------------------- server counters
def test_server_counts_queries_per_kind_thread_safe():
    db = rand_db(150, items=8, seed=17)
    sm = StreamingMiner(8, 15, initial_db=db, n_workers=2, max_k=3)
    sm.refresh()
    srv = PatternServer(sm)
    hot = next(iter(sm.snapshot.supports))
    per_thread = 50

    def hammer():
        for _ in range(per_thread):
            srv.support(hot)
            srv.top_k((), 3)
            srv.frequent()

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.merged_stats()
        # support(known) + frequent() count as hits; no lost updates
        assert stats["hit"] == 2 * 8 * per_thread
        assert stats["top_k"] == 8 * per_thread
        assert stats["sweep"] == 0
        assert srv.queries == 3 * 8 * per_thread
    finally:
        sm.close()


# ------------------------------------------------- multi-tenant hub
def test_tenant_hub_isolation_fairness_and_serving():
    db_a = rand_db(150, items=12, seed=1)
    db_b = rand_db(120, items=12, seed=2)
    with TenantHub(12, n_workers=2, max_k=4) as hub:
        ta = hub.tenant("a", 15, weight=4.0)
        tb = hub.tenant("b", 12)
        assert hub.tenant("a") is ta          # fetch by id
        ta.ingest(db_a[:100])
        tb.ingest(db_b)
        ta.refresh()
        tb.refresh()
        assert dict(ta.snapshot.supports) == batch_mine(
            db_a[:100], 12, 15, max_k=4)
        assert dict(tb.snapshot.supports) == batch_mine(
            db_b, 12, 12, max_k=4)
        # one tenant's second generation leaves the other untouched
        ta.ingest(db_a[100:])
        ta.refresh()
        assert dict(ta.snapshot.supports) == batch_mine(
            db_a, 12, 15, max_k=4)
        assert tb.snapshot.generation == 1
        assert dict(tb.snapshot.supports) == batch_mine(
            db_b, 12, 12, max_k=4)
        # segments are tagged and disjoint; cross-tenant compaction
        # is refused at the arena layer
        segs_a = hub.arena.tenant_segments("a")
        segs_b = hub.arena.tenant_segments("b")
        assert segs_a and segs_b and not set(segs_a) & set(segs_b)
        assert hub.arena.compact(hub.arena.n_segments) == 0
        # serving answers each tenant over ITS stream only — the
        # len-5 probe exceeds max_k, so it always sweeps
        probes = [(0, 1, 2, 3, 4), (3, 4)]
        assert ta.server.support_many(probes) == [
            brute(db_a, x) for x in probes]
        assert tb.server.support_many(probes) == [
            brute(db_b, x) for x in probes]
        stats = hub.tenant_stats()
        assert stats["a"]["queries"]["sweep"] >= 1
        assert stats["b"]["queries"]["sweep"] >= 1
        assert stats["a"]["generation"] == 2
        assert stats["b"]["generation"] == 1
        assert stats["a"]["weight"] == 4.0
        # tenant-tagged tasks were served under the fairness rule
        assert stats["a"]["tasks_served"] > 0
        assert stats["b"]["tasks_served"] > 0


def test_tenant_queries_concurrent_with_refresh_are_exact():
    db_a = rand_db(200, items=10, seed=21)
    db_b = rand_db(150, items=10, seed=22)
    with TenantHub(10, n_workers=2, max_k=3) as hub:
        ta = hub.tenant("a", 20)
        tb = hub.tenant("b", 15)
        ta.ingest(db_a)
        ta.refresh()
        tb.ingest(db_b[:100])
        tb.refresh()
        tb.ingest(db_b[100:])
        probes = [(0, 1, 2, 3, 4), (2, 5)]
        seen = {}

        def hook(snapshot):
            # mid-refresh of B, tenant A's serving stays exact and
            # B still answers over its OLD boundary
            seen["a"] = ta.support_many(probes)
            seen["b"] = tb.support_many(probes)

        tb.refresh(before_publish=hook)
        assert seen["a"] == [brute(db_a, x) for x in probes]
        assert seen["b"] == [brute(db_b[:100], x) for x in probes]
        assert tb.support_many(probes) == [brute(db_b, x)
                                           for x in probes]
