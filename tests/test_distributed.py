"""Multi-device integration tests — each spawns a subprocess with
--xla_force_host_platform_device_count (the main pytest process must keep
seeing exactly 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a multi-device XLA subprocess — minutes each;
# tier-1 (`pytest -q`, addopts -m 'not slow') deselects the module
pytestmark = [pytest.mark.slow, pytest.mark.timeout(600)]


def run_py(code: str, n_dev: int = 8, timeout: int = 560) -> str:
    # JAX_PLATFORMS=cpu: without it jax probes for a TPU first, and on
    # sandboxed hosts the GCP-metadata HTTP retries can stall a child
    # for minutes before the CPU fallback kicks in
    env = {"XLA_FLAGS":
           f"--xla_force_host_platform_device_count={n_dev}",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_distributed_fpm_policies_agree():
    """The legacy two-policy contract through the unified engine: the
    `mine_distributed` shim on an 8-device mesh returns exact supports
    and preserves the locality ordering of the old bespoke driver."""
    out = run_py("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.data.transactions import load
        from repro.core.tidlist import pack_database
        from repro.core.fpm import mine_serial
        from repro.core.distributed_fpm import mine_distributed
        db, p = load('mushroom', seed=1)
        db = db[:400]
        bm = pack_database(db, p.n_dense_items)
        ms = int(0.3 * len(db))
        ref = mine_serial(bm, ms, max_k=4)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        for pol in ['clustered', 'round_robin']:
            got, stats = mine_distributed(bm, ms, mesh, policy=pol, max_k=4)
            assert got == ref, pol
            assert stats['n_devices'] == 8
            print(pol, stats['rows_touched'])
    """)
    rows = dict(line.split() for line in out.strip().splitlines())
    # the paper's locality claim, distributed form:
    assert int(rows["clustered"]) < int(rows["round_robin"])


def test_mesh_fpm_all_granularities_two_devices():
    """The tentpole on real (virtual) devices: every granularity runs
    through `fpm.mine(mesh=...)` on a 2-device mesh with per-device
    mirrors/dispatchers and exact supports. Bucket and depth-first take
    the pallas batched-join path; candidate uses the numpy backend (its
    per-candidate requests through an interpreted kernel are a
    correctness-only combination that costs minutes — the dispatcher
    routing under test is identical). Depth-first keeps its structural
    cache_misses == 0 on the mesh."""
    run_py("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.data.transactions import load
        from repro.core.tidlist import pack_database
        from repro.core.fpm import mine, mine_serial
        db, p = load('mushroom', seed=1)
        db = db[:400]
        bm = pack_database(db, p.n_dense_items)
        ms = int(0.22 * len(db))
        ref = mine_serial(bm, ms, max_k=4)
        assert len(jax.devices()) == 2
        mesh = Mesh(np.array(jax.devices()), ('data',))
        for gran, backend in [('bucket', 'pallas-interpret'),
                              ('depth-first', 'pallas-interpret'),
                              ('candidate', 'numpy')]:
            got, met = mine(bm, ms, mesh=mesh, policy='clustered',
                            n_workers=4, max_k=4, granularity=gran,
                            backend=backend)
            assert got == ref, gran
            assert met.n_devices == 2
            assert len(met.per_device) == 2
            assert sum(d['sweep_requests'] for d in met.per_device) \\
                == met.scheduler['sweeps_submitted']
            if gran == 'depth-first':
                assert met.cache_misses == 0
            print(gran, 'd2d', met.d2d_bytes, 'migr', met.migrations,
                  'occ', [round(d['batch_occupancy'], 2)
                          for d in met.per_device])
    """, n_dev=2)


def test_mesh_forced_migration_two_devices():
    """A forced cross-device bucket steal on a 2-device mesh: the
    stolen bucket's retained arena bitmap is migrated to the thief's
    shard, the transfer lands in d2d_bytes, and the thief's dispatcher
    sweeps the migrated handle with correct counts."""
    run_py("""
        import threading
        import jax, numpy as np
        from repro.core.join_backend import SweepDispatcher, get_backend
        from repro.core.scheduler import ClusteredPolicy, TaskScheduler
        from repro.core.tidlist import BitmapArena, popcount32
        devs = jax.devices()
        assert len(devs) == 2
        rows = np.random.default_rng(5).integers(
            0, 2 ** 32, size=(6, 16), dtype=np.uint32)
        arena = BitmapArena.from_bitmaps(rows, backing='jax',
                                         n_shards=2, devices=devs)
        disp = [SweepDispatcher(arena, get_backend('pallas-interpret'),
                                n_clients=1, shard=s) for s in range(2)]
        sched = TaskScheduler(2, ClusteredPolicy(2, lambda a: a),
                              device_of=[0, 1],
                              migrate_cb=lambda hs, src, dst:
                                  arena.migrate(hs, dst))
        started, migrated = threading.Event(), threading.Event()
        orig = arena.migrate
        def spy(hs, dst):
            n = orig(hs, dst); migrated.set(); return n
        arena.migrate = spy
        got, where = {}, {}
        hh = []
        def blocker():
            where['victim'] = sched.worker_device()
            started.set(); migrated.wait(timeout=10)
        def carrier():
            s = sched.worker_device()
            got['shard'] = s
            got['counts'] = disp[s].sweep(hh[0], (2, 3))
        sched.spawn(blocker, attr=0, worker=0)
        assert started.wait(timeout=5)
        # the blocker itself may have been stolen: pin the carrier
        # (and the handle's owner) to wherever it actually runs, so
        # the only idle worker — the other shard — must steal it
        victim = where['victim']
        thief = 1 - victim
        hh.append(arena.materialize(0, 1, shard=victim))
        sched.spawn(carrier, attr=1, worker=victim, handles=(hh[0],))
        sched.wait_all()
        sched.shutdown()
        for d in disp: d.stop()
        assert migrated.is_set()
        assert got['shard'] == thief
        assert arena.owner_of(hh[0]) == thief
        assert arena.d2d_bytes > 0, arena.d2d_bytes
        want = [int(popcount32(rows[0] & rows[1] & rows[e]).sum())
                for e in (2, 3)]
        assert list(got['counts']) == want, (got['counts'], want)
        print('migration ok, d2d', arena.d2d_bytes)
    """, n_dev=2)
