"""Multi-host mining tests.

Tier-1 runs the loopback cluster (N host arenas + schedulers in one
process, same reduction/exchange/steal code paths as the real thing,
KV transport swapped for in-memory slots) and asserts bit-identity
with single-host ``mine()``. The real 2-process ``jax.distributed``
equivalence test is slow-tier: it spawns subprocesses that each
initialize a distributed client over a loopback coordinator.
"""
import json
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.cluster import merge_metrics, mine_cluster
from repro.core.fpm import mine
from repro.core.streaming import StreamingMiner
from repro.core.tidlist import pack_database, partition_words

N_ITEMS = 24


def _db(n_tx, seed, lo=2, hi=9):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(N_ITEMS, size=int(rng.integers(lo, hi)),
                              replace=False).tolist())
            for _ in range(n_tx)]


def test_partition_words_properties():
    for n_w in [0, 1, 2, 7, 64, 157, 4062]:
        for n in [1, 2, 3, 5, 8]:
            ranges = partition_words(n_w, n)
            assert len(ranges) == n
            # contiguous cover, in order, each slice within one word of fair
            assert ranges[0][0] == 0 and ranges[-1][1] == n_w
            for (a0, b0), (a1, b1) in zip(ranges, ranges[1:]):
                assert b0 == a1
            widths = [b - a for a, b in ranges]
            assert max(widths) - min(widths) <= 1


@pytest.mark.parametrize("granularity",
                         ["bucket", "candidate", "depth-first"])
@pytest.mark.parametrize("policy", ["clustered", "fifo"])
def test_cluster_bit_matches_single_host(granularity, policy):
    bm = pack_database(_db(1500, 3), N_ITEMS)
    ms = 75
    ref, base = mine(bm, ms, granularity=granularity, max_k=5)
    assert base.net_bytes == 0 and base.steal_net == 0
    res, met = mine_cluster(bm, ms, hosts=2, policy=policy,
                            granularity=granularity, max_k=5,
                            n_workers=3)
    assert res == ref
    assert met.n_hosts == 2
    # every flush crossed the (loopback) interconnect
    assert met.net_bytes > 0
    assert len(met.per_host) == 2
    assert all(h["bytes_swept"] > 0 for h in met.per_host)


def test_cluster_three_hosts():
    bm = pack_database(_db(2000, 11), N_ITEMS)
    ms = 100
    ref, _ = mine(bm, ms, max_k=5)
    res, met = mine_cluster(bm, ms, hosts=3, max_k=5, n_workers=2)
    assert res == ref
    assert met.n_hosts == 3 and len(met.per_host) == 3


def test_cluster_forced_steal_migrates_buckets():
    """owner_fn pins every bucket on host 0; host 1 only makes progress
    via cross-host steal-as-migration. The race is timing-dependent on
    a shared-core runner, so retry until a migration lands."""
    bm = pack_database(_db(12000, 5, lo=3), N_ITEMS)
    ms = 600
    ref, _ = mine(bm, ms, granularity="bucket", max_k=4, n_workers=4)
    for _ in range(5):
        res, met = mine_cluster(bm, ms, hosts=2, granularity="bucket",
                                max_k=4, n_workers=4,
                                owner_fn=lambda key: 0)
        assert res == ref
        if met.cross_steals > 0:
            break
    assert met.cross_steals > 0
    assert met.steal_net > 0  # migrated buckets billed in bytes


def test_merge_metrics_sums_and_maxes():
    bm = pack_database(_db(800, 7), N_ITEMS)
    _, m0 = mine(bm, 40, max_k=4)
    res, met = mine_cluster(bm, 40, hosts=2, max_k=4, n_workers=2)
    # swept bytes sum over hosts; each host sweeps its own slice so the
    # total matches the single-host figure (same rows, split words)
    assert met.bytes_swept == sum(h["bytes_swept"] for h in met.per_host)
    assert met.candidates == m0.candidates
    assert met.frequent == m0.frequent == len(res)


def test_streaming_cluster_matches_batch():
    init, b1, b2 = _db(400, 21), _db(150, 22), _db(200, 23)
    sm = StreamingMiner(N_ITEMS, 25, initial_db=init, hosts=2,
                        n_workers=2, max_k=4)
    try:
        db = list(init)
        for b in (b1, b2):
            sm.ingest(b)
            db += b
            rep = sm.refresh()
        ref, _ = mine(pack_database(db, N_ITEMS), 25, max_k=4)
        assert dict(sm.snapshot.supports) == ref
        assert rep.metrics.n_hosts == 2
        assert rep.metrics.net_bytes > 0
        g = sm.cluster_gauges
        assert g is not None and g["net_bytes"] > 0
        # ingest routed segments to both host arenas
        assert all(ar.n_words > 0 for ar in sm._harenas)
        # queries reduce across host slices and stay exact
        bm = pack_database(db, N_ITEMS)
        import repro.core.tidlist as tl
        for q in ([0, 1, 2], [5, 9]):
            want = int(tl.popcount32(
                np.bitwise_and.reduce(bm[q], axis=0)).sum())
            assert sm.support_many([q])[0] == want
    finally:
        sm.close()


def test_streaming_single_host_has_no_gauges():
    sm = StreamingMiner(N_ITEMS, 25, initial_db=_db(200, 31))
    try:
        assert sm.cluster_gauges is None
    finally:
        sm.close()


def test_streaming_cluster_rejects_mesh_and_diffsets():
    with pytest.raises(ValueError):
        StreamingMiner(N_ITEMS, 5, hosts=2, representation="diffset")


# ---------------------------------------------------------------------------
# real 2-process jax.distributed equivalence (slow tier)

DIST_CODE = """
import sys
import numpy as np
from repro.core.cluster import mine_distributed_process
from repro.core.fpm import mine
from repro.core.tidlist import pack_database
rank = int(sys.argv[1]); n = int(sys.argv[2]); coord = sys.argv[3]
rng = np.random.default_rng(9)
db = [sorted(rng.choice(24, size=int(rng.integers(2, 8)),
                        replace=False).tolist()) for _ in range(900)]
bm = pack_database(db, 24)
ms = 45
res, met = mine_distributed_process(
    bm, ms, rank=rank, n_procs=n, coordinator=coord, max_k=4,
    n_workers=2)
ref, _ = mine(bm, ms, max_k=4)
assert res == ref, (rank, len(res), len(ref))
assert met.net_bytes > 0
print('MATCH', rank, len(res), met.net_bytes)
"""


@pytest.mark.slow
@pytest.mark.timeout(600)
def test_two_process_distributed_bit_matches():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        coord = f"127.0.0.1:{s.getsockname()[1]}"
    env = {"JAX_PLATFORMS": "cpu", "PYTHONPATH": "src",
           "PATH": "/usr/bin:/bin:/usr/local/bin"}
    procs = [subprocess.Popen(
        [sys.executable, "-c", textwrap.dedent(DIST_CODE),
         str(r), "2", coord],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env, cwd=".") for r in range(2)]
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=560)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        if p.returncode != 0 and "initialize" in err:
            pytest.skip(f"jax.distributed unavailable: {err[-300:]}")
        assert p.returncode == 0, err[-3000:]
        outs.append(out)
    for out in outs:
        assert "MATCH" in out
