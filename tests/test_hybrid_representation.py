"""Hybrid sparse representation: dEclat diffsets, the gather-intersect
kernel, and density-driven per-subtree selection.

Covers the four satellite test axes:
 - numpy-reference vs pallas-interpret parity for the gather-intersect
   kernel (ragged tid lists, empty payloads, a single extension);
 - mixed-representation engine equivalence (every granularity x
   representation cell mines the identical frequent set);
 - a hypothesis property test of diffset support arithmetic against
   brute-force set algebra (skips cleanly without hypothesis);
 - streaming refresh over sparse rows.
"""
import itertools

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import join_backend as jb
from repro.core import tidlist
from repro.core.buckets import DensityModel
from repro.core.fpm import mine, mine_serial
from repro.core.tidlist import BitmapArena, pack_database

RNG = np.random.default_rng(7)


def rand_db(n_tx, n_items=16, lo=1, hi=6, rng=RNG):
    return [list(rng.choice(n_items, size=rng.integers(lo, hi),
                            replace=False))
            for _ in range(n_tx)]


def naive_supports(db, itemset):
    s = set(itemset)
    return sum(1 for tx in db if s.issubset(tx))


# ------------------------------------------------ kernel parity (numpy
# reference vs pallas-interpret; ragged batches, empties, E == 1)
def _rand_sparse_batch(b, s, e, w, rng=RNG, ragged=True):
    """Random [B,S] padded tid batch + [B,E,W] ext word-columns."""
    tids = np.full((b, s), -1, np.int32)
    for i in range(b):
        n = int(rng.integers(0, s + 1)) if ragged else s
        t = rng.choice(32 * w, size=n, replace=False)
        t.sort()
        tids[i, :n] = t
    exts = rng.integers(0, 2 ** 32, size=(b, e, w), dtype=np.uint32)
    return tids, exts


@pytest.mark.parametrize("b,s,e,w", [(1, 7, 1, 2), (3, 16, 4, 3),
                                     (5, 33, 2, 8), (2, 64, 6, 4)])
def test_gather_intersect_interpret_matches_numpy_ref(b, s, e, w):
    jax = pytest.importorskip("jax")
    from repro.kernels.gather_intersect.kernel import (
        gather_intersect_many_kernel)
    from repro.kernels.gather_intersect.ref import (
        gather_intersect_many_np)
    tids, exts = _rand_sparse_batch(b, s, e, w)
    want = gather_intersect_many_np(tids, exts)
    got = np.asarray(gather_intersect_many_kernel(
        jax.numpy.asarray(tids), jax.numpy.asarray(exts),
        interpret=True))
    np.testing.assert_array_equal(got, want)


def test_gather_intersect_empty_tid_axis_is_all_zero():
    jax = pytest.importorskip("jax")
    from repro.kernels.gather_intersect.ops import gather_intersect_many
    exts = jax.numpy.asarray(
        RNG.integers(0, 2 ** 32, size=(2, 3, 4), dtype=np.uint32))
    tids = jax.numpy.zeros((2, 0), np.int32)
    out = np.asarray(gather_intersect_many(tids, exts, mode="ref"))
    assert out.shape == (2, 3) and not out.any()


def test_gather_intersect_all_padded_rows_count_zero():
    jax = pytest.importorskip("jax")
    from repro.kernels.gather_intersect.kernel import (
        gather_intersect_many_kernel)
    tids = np.full((2, 9), -1, np.int32)
    tids[0, :3] = [1, 40, 63]
    exts = np.full((2, 2, 2), 0xFFFFFFFF, np.uint32)
    got = np.asarray(gather_intersect_many_kernel(
        jax.numpy.asarray(tids), jax.numpy.asarray(exts),
        interpret=True))
    np.testing.assert_array_equal(got, [[3, 3], [0, 0]])


# ---------------------------------------- dispatcher sparse/dense mix
def _tid_arena(n=8, w=6, rng=RNG):
    rows = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    return BitmapArena.from_bitmaps(rows), rows


def naive_counts(prow, erows):
    return [int(tidlist.popcount32(prow & r).sum()) for r in erows]


def test_one_flush_mixes_representations():
    """A single dispatcher flush carries dense, tid-list and diffset
    prefixes; each request is routed to its representation's sweep and
    the counts agree with dense brute force."""
    arena, rows = _tid_arena()
    pt = tidlist.bitmap_to_tids(rows[0] & rows[1])
    ht = arena.push_tids(pt)                        # tids(0&1)
    sub = tidlist.bitmap_to_tids(rows[0] & rows[1] & rows[2])
    hd = arena.push_diffset(tidlist.sorted_difference(pt, sub),
                            anchor=ht, support=len(sub))
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"),
                              n_clients=3, flush_us=500_000)
    try:
        exts = (3, 4, 5)
        fd = disp.submit(0, exts)                   # dense prefix
        ft = disp.submit(ht, exts)                  # tid-list prefix
        fx = disp.submit(hd, exts)                  # diffset prefix
        np.testing.assert_array_equal(
            fd.result(10), naive_counts(rows[0], rows[3:6]))
        np.testing.assert_array_equal(
            ft.result(10), naive_counts(rows[0] & rows[1], rows[3:6]))
        # diffset requests count |diff ∩ e|: support(P+e) follows by
        # the dEclat identity support(anchor+e) - |diff ∩ e|
        want_sub = naive_counts(rows[0] & rows[1] & rows[2], rows[3:6])
        got = [a - d for a, d in zip(ft.result(10), fx.result(10))]
        assert got == want_sub
        assert disp.flushes == 1 and disp.requests == 3
    finally:
        disp.stop()


def test_sweep_bits_returns_alignable_bit_matrix():
    """host-parallel fast path: sweep_bits on a sparse prefix returns
    (counts, bits) from ONE gather — bits[j, i] is ext j's membership
    at payload position i, exactly gather_bits_rows' answer."""
    arena, rows = _tid_arena()
    pt = tidlist.bitmap_to_tids(rows[0] & rows[1])
    ht = arena.push_tids(pt)
    disp = jb.SweepDispatcher(arena, jb.get_backend("numpy"),
                              n_clients=1, flush_us=1_000)
    try:
        counts, bits = disp.sweep_bits(ht, (2, 3, 4))
        assert bits is not None and bits.shape == (3, len(pt))
        np.testing.assert_array_equal(
            counts, naive_counts(rows[0] & rows[1], rows[2:5]))
        np.testing.assert_array_equal(bits.sum(axis=1), counts)
        np.testing.assert_array_equal(
            bits, arena.gather_bits_rows(pt, [2, 3, 4]))
        # dense prefixes take the batched dense sweep: no bit matrix
        dcounts, dbits = disp.sweep_bits(0, (2, 3, 4))
        assert dbits is None
        np.testing.assert_array_equal(
            dcounts, naive_counts(rows[0], rows[2:5]))
    finally:
        disp.stop()


def test_gather_bits_rows_matches_per_tid_bit_test():
    arena, rows = _tid_arena(n=5, w=4)
    tids = np.sort(RNG.choice(32 * 4, size=20, replace=False)
                   ).astype(np.uint32)
    got = arena.gather_bits_rows(tids, [1, 3])
    for j, h in enumerate([1, 3]):
        want = [(int(rows[h][t >> 5]) >> (int(t) & 31)) & 1
                for t in tids]
        np.testing.assert_array_equal(got[j], want)


# ------------------------------------- engine equivalence (the matrix)
def test_mixed_representation_equivalence_matrix():
    """Every granularity x representation cell mines the identical
    frequent set; sparse runs actually take sparse sweeps. The database
    is dense enough that the lattice reaches k=4 — sparse prefixes only
    exist once classes hand rows down (k >= 3)."""
    db = rand_db(600, n_items=12, lo=3, hi=9)
    bm, counts = pack_database(db, 12, return_counts=True)
    ms = 40
    ref = mine_serial(bm, ms, max_k=5)
    assert ref, "degenerate test database"
    for gran, rep in itertools.product(
            ("bucket", "depth-first", "auto"),
            ("bitmap", "sparse", "auto")):
        res, met = mine(bm, ms, n_workers=3, max_k=5, backend="numpy",
                        granularity=gran, representation=rep,
                        item_counts=counts)
        assert res == ref, f"{gran}/{rep} mismatch"
        if rep == "bitmap":
            assert met.sparse_sweeps == 0 and not met.rep_picks
        if rep == "sparse" and gran != "candidate":
            assert met.sparse_sweeps > 0
            assert met.sparse_bytes_swept > 0


def test_depth_first_sparse_subtrees_project_without_arena_rows():
    """On the host backend, interior sparse classes are projections of
    the root's bit matrix: sparse sweeps happen, arena sparse rows
    don't (kernel backends still materialize arena rows — covered by
    the pallas test below)."""
    db = rand_db(600, n_items=12, lo=3, hi=9)
    bm, counts = pack_database(db, 12, return_counts=True)
    res, met = mine(bm, 40, n_workers=3, max_k=5, backend="numpy",
                    granularity="depth-first", representation="sparse",
                    item_counts=counts)
    assert met.sparse_sweeps > 0
    assert met.sparse_rows == 0
    assert res == mine_serial(bm, 40, max_k=5)


def test_pallas_interpret_sparse_matches_serial():
    """Kernel-backend path: sparse rows live in the arena, diffset
    chains resolve through anchors, and the gather-intersect kernel
    (interpret mode) produces the same frequent set."""
    pytest.importorskip("jax")
    db = rand_db(250, n_items=10)
    bm, counts = pack_database(db, 10, return_counts=True)
    ms = 25
    ref = mine_serial(bm, ms, max_k=4)
    for rep in ("sparse", "auto"):
        res, met = mine(bm, ms, n_workers=2, max_k=4,
                        backend="pallas-interpret",
                        granularity="depth-first", representation=rep,
                        item_counts=counts)
        assert res == ref, f"pallas-interpret/{rep} mismatch"
        if rep == "sparse":
            assert met.sparse_rows > 0       # arena rows, not masks


# -------------------------------------------------- streaming, sparse
@pytest.mark.parametrize("rep", ["sparse", "auto"])
def test_streaming_refresh_over_sparse_rows(rep):
    """Ingest+refresh rounds with sparse representations stay exact at
    every generation (delta sweeps searchsort tid payloads into the
    pending segments' windows)."""
    from repro.core.streaming import StreamingMiner
    full = rand_db(400, n_items=12, lo=3, hi=9)
    cuts = [260, 330, 400]
    ms = 30
    sm = StreamingMiner(12, ms, initial_db=full[:cuts[0]],
                        granularity="depth-first", n_workers=3,
                        max_k=5, representation=rep)
    prev = cuts[0]
    for cut in cuts:
        if cut != prev:
            sm.ingest(full[prev:cut])
            prev = cut
        sm.refresh()
        ref = mine(pack_database(full[:cut], 12), ms,
                   granularity="depth-first", n_workers=3, max_k=5)[0]
        assert dict(sm.snapshot.supports) == ref


# ------------------------------------------- diffset arithmetic (hyp.)
@settings(max_examples=40, deadline=None)
@given(st.data())
def test_property_diffset_support_arithmetic(data):
    """support(P+e) == support(P) - |diff ∩ tids(P)∩e ... | via the
    arena: push a random parent tid-list, carve a random child as a
    diffset, and check resolve_tids + sparse_support against
    brute-force set algebra, including empty diffs and empty children."""
    n_words = data.draw(st.integers(1, 4), label="n_words")
    univ = 32 * n_words
    parent = sorted(data.draw(
        st.sets(st.integers(0, univ - 1), min_size=1, max_size=univ),
        label="parent"))
    child = sorted(data.draw(
        st.sets(st.sampled_from(parent), max_size=len(parent)),
        label="child"))
    pt = np.asarray(parent, np.uint32)
    ct = np.asarray(child, np.uint32)
    base = RNG.integers(0, 2 ** 32, size=(2, n_words), dtype=np.uint32)
    arena = BitmapArena.from_bitmaps(base)
    hp = arena.push_tids(pt)
    diff = tidlist.sorted_difference(pt, ct)
    assert sorted(diff) == sorted(set(parent) - set(child))
    hc = arena.push_diffset(diff, anchor=hp, support=len(ct))
    np.testing.assert_array_equal(arena.resolve_tids(hc), ct)
    assert arena.sparse_support(hc) == len(child)
    # the dEclat identity against a random extension row
    erow = tidlist.tids_to_bitmap(
        np.asarray(sorted(data.draw(
            st.sets(st.integers(0, univ - 1), max_size=univ),
            label="ext")), np.uint32), n_words)
    inter_parent = naive_bit_and_count(pt, erow)
    inter_diff = naive_bit_and_count(diff, erow)
    want_child = naive_bit_and_count(ct, erow)
    assert inter_parent - inter_diff == want_child


def naive_bit_and_count(tids, row):
    return sum(1 for t in tids
               if (int(row[int(t) >> 5]) >> (int(t) & 31)) & 1)


# ------------------------------------------------------- density model
def test_density_model_child_rep_thresholds_and_ties():
    m = DensityModel(n_words=100, tids_per_word=2.0)
    # cheap child tid-list: S/tpw < W
    assert m.pick_child_rep(1000, 150) == "tidlist"
    # near-total child: tiny diffset wins when allowed
    assert m.pick_child_rep(1000, 990) == "diffset"
    assert m.pick_child_rep(1000, 990,
                            allow_diffset=False) == "bitmap"
    # huge child: bitmap (S/tpw and D/tpw both above W)
    assert m.pick_child_rep(1000, 500) == "bitmap"
    # exact tie prefers the simpler representation: cost 100 == W
    assert m.pick_child_rep(400, 200) == "bitmap"
    # tidlist/diffset tie at equal size prefers tidlist
    assert m.pick_child_rep(300, 150) == "tidlist"
    assert (m.bitmap_picks, m.tidlist_picks, m.diffset_picks) \
        == (3, 2, 1)


def test_density_model_force_pins_representation():
    mb = DensityModel(n_words=10, force="bitmap")
    ms_ = DensityModel(n_words=10, force="sparse")
    assert mb.pick_child_rep(100, 1) == "bitmap"
    assert ms_.pick_child_rep(100, 99) == "diffset"
    assert ms_.pick_child_rep(100, 1) == "tidlist"
    assert mb.pick_rep(1) == "bitmap" and ms_.pick_rep(999) == "tidlist"


def test_density_model_seed_and_ewma_observe():
    m = DensityModel.from_counts(4, [32, 64, 32])   # mean 32/word? no:
    assert m.ones_per_word == pytest.approx((32 + 64 + 32) / (3 * 4))
    before = m.ones_per_word
    m.observe([400, 400])                           # 100 ones/word
    assert before < m.ones_per_word < 100 / 1.0     # EWMA moved toward
    m2 = DensityModel.from_counts(4, None)
    assert m2.ones_per_word == 0.0


def test_density_model_granularity_split():
    m = DensityModel(n_words=100, tids_per_word=2.0)
    assert m.pick_granularity(150) == "depth-first"   # sparse subtree
    assert m.pick_granularity(1000) == "depth-first"  # 10 ones/word
    assert m.pick_granularity(5000) == "bucket"       # 50 ones/word


def test_pack_database_counts_match_bitmaps():
    db = rand_db(200)
    bm, counts = pack_database(db, 16, return_counts=True)
    np.testing.assert_array_equal(
        counts,
        [int(tidlist.popcount32(bm[i]).sum()) for i in range(16)])
    bm2 = pack_database(db, 16)
    np.testing.assert_array_equal(bm, bm2)
