"""Scheduler-policy behaviour tests (paper §3-4)."""
import threading
import time

import pytest

from repro.core.scheduler import (CilkPolicy, ClusteredPolicy, FifoPolicy,
                                  TaskScheduler, make_policy)


def run_tasks(policy, n_workers=4, n_tasks=200, attr_of=lambda i: i):
    sched = TaskScheduler(n_workers, policy)
    results = []
    lock = threading.Lock()

    def work(i):
        with lock:
            results.append(i)
        return i * 2

    tasks = [sched.spawn(work, i, attr=attr_of(i)) for i in range(n_tasks)]
    sched.wait_all()
    sched.shutdown()
    return sched, tasks, results


def test_all_tasks_run_cilk():
    sched, tasks, results = run_tasks(CilkPolicy(4))
    assert sorted(results) == list(range(200))
    assert all(t.result == i * 2 for i, t in enumerate(tasks))


def test_all_tasks_run_fifo():
    _, tasks, results = run_tasks(FifoPolicy(4))
    assert sorted(results) == list(range(200))


def test_all_tasks_run_clustered():
    pol = ClusteredPolicy(4, cluster_of=lambda a: a % 10)
    _, tasks, results = run_tasks(pol, attr_of=lambda i: i)
    assert sorted(results) == list(range(200))


def test_clustered_steal_takes_whole_bucket():
    pol = ClusteredPolicy(2, cluster_of=lambda a: a)
    from repro.core.scheduler import Task
    for i in range(6):
        pol.put(0, Task(lambda: None, (), attr=7))   # one bucket, 6 tasks
    got = pol.steal(1, 0)
    assert len(got) == 6                              # the WHOLE bucket
    assert pol.approx_len(0) == 0


def test_cilk_steal_takes_one():
    pol = CilkPolicy(2)
    from repro.core.scheduler import Task
    for i in range(6):
        pol.put(0, Task(lambda: None, ()))
    got = pol.steal(1, 0)
    assert len(got) == 1
    assert pol.approx_len(0) == 5


def test_clustered_get_drains_bucket_before_switching():
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    from repro.core.scheduler import Task
    for attr in [1, 2, 1, 2, 1]:
        pol.put(0, Task(lambda: None, (), attr=attr))
    seen = [pol.get(0).attr for _ in range(5)]
    # one full bucket first, then the other
    assert seen in ([1, 1, 1, 2, 2], [2, 2, 1, 1, 1])


def test_stats_tracked():
    sched, _, _ = run_tasks(CilkPolicy(4), n_tasks=500)
    s = sched.merged_stats()
    assert s["tasks_run"] == 500
    assert s["tasks_per_steal"] >= 0
    # non-bucket policies never switch drain buckets or migrate
    assert s["bucket_switches"] == 0
    assert s["steal_migrations"] == 0


def test_bucket_switches_counted_and_merged():
    """The clustered policy counts drain-bucket switches per worker at
    the queue; merged_stats must aggregate them (they were dropped
    before) and they must match the policy's own counters."""
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    from repro.core.scheduler import Task
    for attr in [1, 2, 1, 2, 1, 3]:
        pol.put(0, Task(lambda: None, (), attr=attr))
    while pol.get(0) is not None:
        pass
    # buckets drain whole: 1,1,1 then 2,2 then 3 -> three selections
    assert pol.switches[0] == 3

    sched, _, _ = run_tasks(ClusteredPolicy(2, cluster_of=lambda a: a % 5),
                            n_workers=2, n_tasks=100)
    s = sched.merged_stats()
    assert s["bucket_switches"] == sum(sched.policy.switches) > 0


def test_make_policy_names():
    for name, cls in [("cilk", CilkPolicy), ("fifo", FifoPolicy),
                      ("clustered", ClusteredPolicy)]:
        assert isinstance(make_policy(name, 2), cls)
    with pytest.raises(ValueError):
        make_policy("nope", 2)


def test_parallel_speedup_gil_released():
    """numpy task bodies release the GIL: 4 workers must beat 1 worker."""
    import os
    import numpy as np
    if (os.cpu_count() or 1) < 4:
        pytest.skip("needs >=4 cores for a thread-speedup assertion")
    if os.getloadavg()[0] > os.cpu_count() * 0.5:
        pytest.skip("machine too loaded for a timing assertion")
    big = np.random.default_rng(0).integers(
        0, 2 ** 32, size=(4, 1 << 19), dtype=np.uint32)

    def work(_):
        x = big[0]
        for r in big[1:]:
            x = x & r
        return int(x.sum())

    def timed(n):
        # best-of-3: a single shot is load-sensitive (one descheduled
        # worker flips the assertion), the minimum is stable
        best = float("inf")
        for _ in range(3):
            sched = TaskScheduler(n, CilkPolicy(n))
            t0 = time.perf_counter()
            for i in range(64):
                sched.spawn(work, i, attr=i)
            sched.wait_all()
            sched.shutdown()
            best = min(best, time.perf_counter() - t0)
        return best

    t1, t4 = timed(1), timed(4)
    # ratio bound, not absolute wall time: asserts "threads actually
    # ran concurrently", not "this machine is fast"
    assert t4 < t1 * 0.9, (t1, t4)


def test_nearest_neighbor_policy_correct_and_local():
    """Paper §6 future work: NN bucket selection — correctness + the
    bucket chosen after a drain shares items with the last prefix."""
    from repro.core.scheduler import NearestNeighborPolicy, Task
    pol = NearestNeighborPolicy(1, cluster_of=lambda a: a)
    for pref in [(1, 2), (7, 8), (1, 3), (9, 10)]:
        pol.put(0, Task(lambda: None, (), attr=pref))
    first = pol.get(0).attr
    second = pol.get(0).attr
    # after draining the first bucket, the nearest (overlapping) bucket
    # is picked next when one exists
    if 1 in first:
        assert 1 in second, (first, second)


def test_nn_policy_mines_correctly():
    import numpy as np
    from repro.core.fpm import mine, mine_serial
    from repro.core.tidlist import pack_database
    rng = np.random.default_rng(0)
    db = [sorted(rng.choice(12, size=rng.integers(2, 7),
                            replace=False).tolist()) for _ in range(80)]
    bm = pack_database(db, 12)
    ref = mine_serial(bm, 8, max_k=4)
    got, met = mine(bm, 8, policy="nn", n_workers=3, max_k=4)
    assert got == ref


# ----------------------------------------------- bucket-task regressions
def test_bucket_tasks_returning_arrays():
    """Bucket-granularity tasks return numpy arrays; results must come
    back per-task, un-mangled, under the clustered policy's bucket
    steals."""
    import numpy as np
    pol = ClusteredPolicy(3, cluster_of=lambda a: a[0])
    sched = TaskScheduler(3, pol)

    def sweep(base, n):
        return np.arange(base, base + n)

    tasks = [sched.spawn(sweep, i * 10, 4, attr=(i % 5, i))
             for i in range(40)]
    sched.wait_all()
    sched.shutdown()
    for i, t in enumerate(tasks):
        np.testing.assert_array_equal(t.result,
                                      np.arange(i * 10, i * 10 + 4))


def test_nested_spawn_during_drain():
    """A task spawning sub-tasks mid-drain must not let wait_all return
    early, deadlock, or lose tasks (steal/shutdown regression)."""
    pol = ClusteredPolicy(3, cluster_of=lambda a: a)
    sched = TaskScheduler(3, pol)
    ran = []
    lock = threading.Lock()

    def child(i):
        with lock:
            ran.append(("child", i))

    def parent(i):
        sched.spawn(child, i, attr=i + 100)
        with lock:
            ran.append(("parent", i))

    for i in range(20):
        sched.spawn(parent, i, attr=i)
    sched.wait_all()
    assert sched._outstanding == 0
    assert len(ran) == 40
    s = sched.merged_stats()
    assert s["tasks_run"] == s["spawned"] == 40
    sched.shutdown()


def test_wait_all_zero_outstanding_and_stats_invariant():
    """After wait_all: zero outstanding, tasks_run == spawned, and the
    scheduler is reusable for another wave (level-synchronous mining)."""
    sched = TaskScheduler(4, make_policy("clustered", 4, lambda a: a))
    for wave in range(3):
        for i in range(50):
            sched.spawn(lambda x: x, i, attr=i % 7)
        sched.wait_all()
        assert sched._outstanding == 0
        s = sched.merged_stats()
        assert s["tasks_run"] == s["spawned"] == 50 * (wave + 1)
    sched.shutdown()
    # shutdown is idempotent and leaves stats intact
    sched.shutdown()
    assert sched.merged_stats()["tasks_run"] == 150


def test_worker_stats_traffic_counters():
    """Task bodies account rows/bytes via worker_stats(); merged_stats
    must include them (shared locality metric with distributed_fpm)."""
    sched = TaskScheduler(2, make_policy("cilk", 2))

    def body(rows):
        st = sched.worker_stats()
        st.rows_touched += rows
        st.bytes_swept += rows * 8
        return rows

    for i in range(10):
        sched.spawn(body, 3, attr=i)
    sched.wait_all()
    sched.shutdown()
    s = sched.merged_stats()
    assert s["rows_touched"] == 30
    assert s["bytes_swept"] == 240
    # calls from a non-worker thread land in the external bucket
    sched.worker_stats().rows_touched += 5
    assert sched.merged_stats()["rows_touched"] == 35


def test_clustered_drains_deepest_bucket_first():
    """Depth-first drain order: when the drain bucket empties, the
    deepest waiting bucket (Task.depth) is picked next — the memory
    bound of the barrier-free engine."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr="a", depth=1))
    pol.put(0, Task(lambda: None, (), attr="b", depth=3))
    pol.put(0, Task(lambda: None, (), attr="c", depth=2))
    assert pol.get(0).attr == "b"
    assert pol.get(0).attr == "c"
    assert pol.get(0).attr == "a"


def test_nn_drain_selects_max_overlap_within_cap():
    """NN bucket selection: after a drain, the bucket sharing the most
    items with the last-executed prefix is picked."""
    from repro.core.scheduler import NearestNeighborPolicy, Task
    pol = NearestNeighborPolicy(1, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr=(5, 6)))
    assert pol.get(0).attr == (5, 6)            # sets _last
    pol.put(0, Task(lambda: None, (), attr=(7, 8)))
    pol.put(0, Task(lambda: None, (), attr=(5, 9)))
    assert pol.get(0).attr == (5, 9)            # overlap 1 beats 0


def test_nn_drain_scan_cap_bounds_selection():
    """The nearest-neighbour scan inspects at most SCAN_CAP buckets: a
    perfect-overlap bucket inserted beyond the cap must NOT be found."""
    from repro.core.scheduler import NearestNeighborPolicy, Task
    pol = NearestNeighborPolicy(1, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr=(1, 2)))
    assert pol.get(0).attr == (1, 2)            # _last = (1, 2)
    # the scan walks the NEWEST buckets first, so the oldest insertion
    # is the one beyond the cap
    pol.put(0, Task(lambda: None, (), attr=(1, 2, 3)))   # perfect overlap
    for i in range(pol.SCAN_CAP):               # zero-overlap fillers
        pol.put(0, Task(lambda: None, (), attr=(100 + 2 * i,
                                                101 + 2 * i)))
    got = pol.get(0).attr
    assert got != (1, 2, 3)


def test_spawn_from_worker_lands_on_spawning_worker():
    """The paper's runtime semantics: a task spawned from inside a task
    body defaults onto the spawning worker's own queue (locality by
    construction; a stolen bucket carries its whole subtree)."""
    class SpyPolicy(CilkPolicy):
        def __init__(self, n):
            super().__init__(n)
            self.puts = []

        def put(self, worker, task):
            self.puts.append((worker, task.attr))
            super().put(worker, task)

    pol = SpyPolicy(3)
    sched = TaskScheduler(3, pol)
    ran_on = {}

    def child():
        pass

    def parent():
        ran_on["worker"] = sched._tls.worker_id
        sched.spawn(child, attr="child", depth=1)

    sched.spawn(parent, attr="parent")
    sched.wait_all()
    sched.shutdown()
    child_puts = [w for w, a in pol.puts if a == "child"]
    assert child_puts == [ran_on["worker"]]


def test_child_spawned_from_task_error_surfaces_no_deadlock():
    """An exception inside a *spawned-from-task* child must be recorded
    on the child task (for the driver to raise) without killing the
    worker or deadlocking the terminal wait_all."""
    sched = TaskScheduler(2, CilkPolicy(2))
    children = []

    def child():
        raise RuntimeError("child boom")

    def parent():
        children.append(sched.spawn(child, attr="c", depth=1))

    sched.spawn(parent, attr="p")
    sched.wait_all()                     # must return, not hang
    sched.shutdown()
    assert sched._outstanding == 0
    assert len(children) == 1
    assert isinstance(children[0].error, RuntimeError)
    s = sched.merged_stats()
    assert s["tasks_run"] == s["spawned"] == 2


def test_task_exception_does_not_deadlock_wait_all():
    """A raising task body must not kill the worker (which would leave
    _outstanding stuck and deadlock wait_all); the error is recorded on
    the task instead."""
    sched = TaskScheduler(2, CilkPolicy(2))

    def boom(i):
        if i == 3:
            raise RuntimeError("kaboom")
        return i

    tasks = [sched.spawn(boom, i, attr=i) for i in range(6)]
    sched.wait_all()                     # must return, not hang
    sched.shutdown()
    assert sched._outstanding == 0
    errs = [t for t in tasks if t.error is not None]
    assert len(errs) == 1
    assert isinstance(errs[0].error, RuntimeError)
    assert all(t.result == i for i, t in enumerate(tasks) if i != 3)
    s = sched.merged_stats()
    assert s["tasks_run"] == s["spawned"] == 6


# ------------------------------------------------- staleness priorities
def test_clustered_drains_stale_hot_bucket_first():
    """Streaming re-mine: the bucket whose head task carries the
    highest staleness priority is drained first (depth only breaks
    ties) — stale-hot prefixes converge before cold ones."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr="cold", priority=0.0, depth=5))
    pol.put(0, Task(lambda: None, (), attr="warm", priority=10.0))
    pol.put(0, Task(lambda: None, (), attr="hot", priority=90.0))
    pol.put(0, Task(lambda: None, (), attr="warm", priority=10.0))
    assert pol.get(0).attr == "hot"
    assert pol.get(0).attr == "warm"
    assert pol.get(0).attr == "warm"            # drain before switching
    assert pol.get(0).attr == "cold"


def test_priority_zero_keeps_first_nonempty_rule():
    """Batch mining spawns everything at priority 0: selection stays
    the paper's O(1) first-non-empty rule (no scan)."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    for attr in ["a", "b", "c"]:
        pol.put(0, Task(lambda: None, (), attr=attr))
    assert pol.get(0).attr == "a"


def test_nn_priority_dominates_overlap():
    """NN policy: a stale-hot bucket beats a nearer (overlapping) cold
    one; with equal priorities the overlap rule is unchanged."""
    from repro.core.scheduler import NearestNeighborPolicy, Task
    pol = NearestNeighborPolicy(1, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr=(5, 6)))
    assert pol.get(0).attr == (5, 6)            # sets _last
    pol.put(0, Task(lambda: None, (), attr=(5, 9)))   # overlap 1, cold
    pol.put(0, Task(lambda: None, (), attr=(7, 8), priority=50.0))
    assert pol.get(0).attr == (7, 8)            # hot beats near
    pol.put(0, Task(lambda: None, (), attr=(7, 9)))
    assert pol.get(0).attr == (7, 9)            # equal prio: overlap
                                                # with _last == (7, 8)


def test_steal_unaccounts_hot_tasks():
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(2, cluster_of=lambda a: a)
    pol.put(0, Task(lambda: None, (), attr="x", priority=5.0))
    pol.put(0, Task(lambda: None, (), attr="x", priority=5.0))
    assert pol._hot[0] == 2
    got = pol.steal(1, 0)
    assert len(got) == 2 and pol._hot[0] == 0


# ------------------------------------------------- stable placement
def _placement_subprocess(hashseed: str) -> str:
    """Spawn placements for string-keyed clusters, in a subprocess with
    a fixed PYTHONHASHSEED (the salted-hash regression trigger)."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        from repro.core.scheduler import ClusteredPolicy, TaskScheduler
        pol = ClusteredPolicy(5, cluster_of=lambda a: a)
        placed = []
        orig = pol.put
        pol.put = lambda w, t: (placed.append((w, t.attr)), orig(w, t))
        sched = TaskScheduler(5, pol)
        for i in range(24):
            sched.spawn(lambda: None, attr=f"prefix-{i}")
        sched.wait_all(); sched.shutdown()
        spawn_puts = sorted(p for p in placed
                            if str(p[1]).startswith("prefix-"))
        print(";".join(f"{a}:{w}" for w, a in spawn_puts))
    """)
    env = {"PYTHONPATH": "src", "PYTHONHASHSEED": hashseed,
           "JAX_PLATFORMS": "cpu",
           "PATH": "/usr/bin:/bin:/usr/local/bin"}
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=120,
                       env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout.strip()


def test_external_spawn_placement_reproducible_across_processes():
    """Driver-thread spawns place by a stable hash of the cluster key:
    two processes with DIFFERENT hash salts must place every task on
    the same worker (hash() of a str would not)."""
    a = _placement_subprocess("1")
    b = _placement_subprocess("2")
    assert a and a == b


def test_stable_hash_is_salt_independent_for_common_key_types():
    from repro.core.scheduler import stable_hash
    # pinned values: changing these breaks cross-process placement
    assert stable_hash(("a", 1)) == stable_hash(("a", 1))
    assert stable_hash(42) != stable_hash(43)
    assert isinstance(stable_hash("prefix"), int)


def test_weighted_fair_drain_serves_by_tenant_deficit():
    """Multi-tenant fairness: with weights set, the drain rule picks
    the bucket whose head task's tenant has the highest
    weight/(served+1) deficit. Tenant a at weight 5 vs b at weight 1:
    a's deficit stays above 1.0 for exactly its first four tasks
    (5, 2.5, 1.67, 1.25), so they all drain before any of b's."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    pol.set_weights({"a": 5.0, "b": 1.0})
    for i in range(4):
        pol.put(0, Task(lambda: None, (), attr=("a", i), tenant="a"))
    for i in range(4):
        pol.put(0, Task(lambda: None, (), attr=("b", i), tenant="b"))
    order = [pol.get(0).tenant for _ in range(8)]
    assert order == ["a"] * 4 + ["b"] * 4
    assert pol.tenant_served() == {"a": 4, "b": 4}
    assert pol.get(0) is None


def test_weighted_fair_drain_interleaves_equal_weights():
    """Equal weights round-robin between tenants regardless of queue
    order — neither stream starves behind the other's backlog."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    pol.set_weights({"a": 1.0, "b": 1.0})
    for i in range(3):
        pol.put(0, Task(lambda: None, (), attr=("a", i), tenant="a"))
    for i in range(3):
        pol.put(0, Task(lambda: None, (), attr=("b", i), tenant="b"))
    order = [pol.get(0).tenant for _ in range(6)]
    # strict alternation (the starter is the newest bucket — the scan
    # walks insertion order reversed)
    assert order[0] != order[1]
    assert order[0::2] == [order[0]] * 3
    assert order[1::2] == [order[1]] * 3


def test_weights_none_keeps_fast_path_semantics():
    """Clearing the weights restores the weight-free drain order (the
    fast path) and stops the served bookkeeping."""
    from repro.core.scheduler import Task
    pol = ClusteredPolicy(1, cluster_of=lambda a: a)
    pol.set_weights({"a": 2.0})
    pol.set_weights(None)
    pol.put(0, Task(lambda: None, (), attr="x", tenant="a"))
    assert pol.get(0).attr == "x"
    assert pol.tenant_served() == {}


def test_scheduler_spawn_threads_tenant_tag():
    """spawn(..., tenant=) lands the tag on the executed Task."""
    seen = []
    lock = threading.Lock()
    sched = TaskScheduler(2, ClusteredPolicy(2, cluster_of=lambda a: a))

    def work(tag):
        with lock:
            seen.append(tag)

    for i in range(10):
        sched.spawn(work, f"t{i % 2}", attr=i, tenant=f"t{i % 2}")
    sched.wait_all()
    sched.shutdown()
    assert sorted(seen) == sorted([f"t{i % 2}" for i in range(10)])
