"""AdamW + schedule + clipping + compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import OptimizerConfig
from repro.optim import adamw
from repro.parallel import compression


def test_schedule_warmup_and_decay():
    o = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(o, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[100] < lrs[50] < lrs[10]        # monotone decay after
    assert lrs[100] >= 1e-4 - 1e-9             # floor at 10%


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((3,), 4.0)}
    clipped, gn = adamw.clip_by_global_norm(tree, 1.0)
    got = adamw.global_norm(clipped)
    assert abs(float(got) - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_adamw_reduces_quadratic_loss():
    o = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=200,
                        weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state, _ = adamw.update(o, g, state, params)
        params = adamw.apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_adamw_state_shapes_match_params():
    params = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}
    st_ = adamw.init(params)
    shapes = adamw.state_shapes(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    assert jax.tree.structure(st_.mu) == jax.tree.structure(params)
    assert shapes.mu["w"].shape == (3, 4)


# ----------------------------------------------------------- compression
def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_error_feedback_preserves_sum(seed):
    """Over many steps, error feedback makes the quantized stream's sum
    converge to the true gradient sum (bias-free accumulation)."""
    rng = np.random.default_rng(seed)
    g_true = jnp.asarray(rng.standard_normal(64), jnp.float32) * 0.1
    residual = {"g": jnp.zeros(64)}
    acc = jnp.zeros(64)
    for _ in range(50):
        qs, scales, new_res = compression.compress_grads(
            {"g": g_true}, residual)
        residual = {"g": new_res["g"]}
        acc = acc + compression.dequantize_int8(qs["g"], scales["g"])
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=float(scales["g"]) + 1e-5)


def test_compressed_bytes_are_4x_smaller():
    x = jnp.zeros((1024,), jnp.float32)
    q, s = compression.quantize_int8(x)
    assert q.dtype == jnp.int8 and q.nbytes * 4 == x.nbytes
