"""Observability: tracer rings, exporters, schema, traced engine runs."""
import json
import threading

import pytest
from _hyp import given, settings, st

from repro.core.fpm import mine
from repro.core.tidlist import pack_database
from repro.data.transactions import load
from repro.obs import (LatencyRecorder, MetricsRegistry, Tracer,
                       check_nesting, chrome_trace, schema,
                       summary_table, time_in_state, write_chrome_trace)


@pytest.fixture(scope="module")
def small_db():
    db, p = load("mushroom", seed=0)
    return [t for t in db[:300]], p


def _span(tr, name, t0, dt, cat="task"):
    """Synthesize a span with exact [t0, t0+dt] extent on the calling
    thread's ring (bypasses the wall clock for deterministic tests)."""
    tr._ring().append(("X", name, cat, t0, dt, None))


# ---------------------------------------------------------------- tracer --

def test_span_records_duration_and_args():
    tr = Tracer()
    t0 = tr.now()
    tr.span("work", t0, cat="task", args={"k": 1})
    (ev,) = tr.events()
    assert ev.ph == "X" and ev.name == "work" and ev.cat == "task"
    assert ev.dur >= 0.0 and ev.args == {"k": 1}


def test_ring_overflow_drops_oldest_without_corruption():
    tr = Tracer(ring_size=8)
    for i in range(20):
        _span(tr, f"s{i}", float(i), 0.5)
    evs = tr.events()
    # last cap events survive, in append order, uncorrupted
    assert [e.name for e in evs] == [f"s{i}" for i in range(12, 20)]
    assert all(e.dur == 0.5 for e in evs)
    assert tr.dropped() == 12
    assert "dropped" in str(chrome_trace(tr).get("otherData", {}))


def test_ring_is_per_thread_and_lane_order_is_stable():
    tr = Tracer()
    tr.set_lane("driver", sort_index=0)
    _span(tr, "main", 0.0, 1.0)

    def worker(i):
        tr.set_lane(f"worker-{i}", sort_index=10 + i)
        _span(tr, f"w{i}", 0.0, 1.0)

    ts = [threading.Thread(target=worker, args=(i,)) for i in (1, 0)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # sort_index, not registration order, decides display order
    assert tr.lane_names() == ["driver", "worker-0", "worker-1"]


def test_disabled_fast_path_is_structural(small_db):
    # the off switch is tracer=None at every site — a plain run must
    # not build rings anywhere
    db, p = small_db
    bm, counts = pack_database(db, p.n_dense_items, return_counts=True)
    res, met = mine(bm, int(0.3 * len(db)), policy="clustered",
                    n_workers=2, max_k=4, item_counts=counts)
    assert met.wall_s > 0


# ------------------------------------------------------------- exporters --

def test_nesting_well_formed_and_violation_detected():
    tr = Tracer()
    _span(tr, "child", 1.0, 2.0)
    _span(tr, "parent", 0.0, 10.0)
    _span(tr, "after", 11.0, 1.0)
    assert check_nesting(tr.events()) == []
    _span(tr, "straddle", 11.5, 2.0)   # starts inside "after", ends past
    bad = check_nesting(tr.events())
    assert len(bad) == 1 and "straddle" in bad[0]


def test_time_in_state_bills_nested_child_to_its_own_category():
    tr = Tracer()
    tr.set_lane("worker-0", sort_index=10)
    _span(tr, "sweep", 2.0, 3.0, cat="sweep")
    _span(tr, "task", 0.0, 10.0, cat="task")
    _span(tr, "park", 10.0, 4.0, cat="idle")
    (row,) = time_in_state(tr).values()
    assert row["sweep"] == pytest.approx(3.0)
    assert row["eval"] == pytest.approx(7.0)      # 10 − nested 3
    assert row["idle"] == pytest.approx(4.0)
    assert row["total"] == pytest.approx(14.0)
    assert row["extent"] == pytest.approx(14.0)
    table = summary_table(tr, wall_s=14.0)
    assert "worker-0" in table and "100.0%" in table


def test_chrome_trace_json_round_trip(tmp_path):
    tr = Tracer()
    tr.set_lane("driver", sort_index=0, pid=3)
    _span(tr, "level-2", 0.25, 0.5, cat="level")
    tr.counter("refresh_lag", {"s": 0.125})
    path = str(tmp_path / "t.trace.json")
    write_chrome_trace(tr, path)
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    names = {e["ph"]: e for e in evs}
    assert {"M", "X", "C"} <= set(names)
    x = names["X"]
    assert x["ts"] == pytest.approx(0.25e6)       # µs
    assert x["dur"] == pytest.approx(0.5e6)
    assert x["pid"] == 3 and x["tid"] >= 1
    c = names["C"]
    assert c["args"] == {"s": 0.125}
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name", "thread_sort_index"} <= {
        m["name"] for m in meta}
    assert any(m["args"].get("name") == "host-3" for m in meta)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(0, 7), min_size=1, max_size=30),
                min_size=1, max_size=4))
def test_merged_timeline_preserves_per_lane_order(lanes):
    """Property: events() merges rings lane by lane, and within every
    lane the collected order IS the append order — even across ring
    overflow (a small cap keeps only the newest suffix, still in
    order)."""
    tr = Tracer(ring_size=8)

    def emit(i, seq):
        tr.set_lane(f"lane-{i}", sort_index=i)
        for j, _ in enumerate(seq):
            _span(tr, f"{i}:{j}", float(j), 0.5)

    threads = [threading.Thread(target=emit, args=(i, seq))
               for i, seq in enumerate(lanes)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    by_lane = {}
    for ev in tr.events():
        by_lane.setdefault(ev.lane, []).append(ev.name)
    assert len(by_lane) == len(lanes)
    for i, seq in enumerate(lanes):
        got = [int(n.split(":")[1]) for n in by_lane[f"lane-{i}"]]
        want = list(range(len(seq)))[-8:]          # drop-oldest suffix
        assert got == want


# ---------------------------------------------------------------- schema --

def test_schema_builders_fill_defaults_and_validate():
    s = schema.scheduler_stats({"tasks_run": 5, "steals": 2,
                                "tasks_stolen": 4})
    schema.validate("scheduler", s)
    assert s["tasks_per_steal"] == pytest.approx(2.0)
    q = schema.query_stats({"hit": 1, "sweep": 2})
    schema.validate("query", q)
    assert q["queries"] == 3 and q["top_k"] == 0
    d = schema.device_stats({"device": 1, "flushes": 4,
                             "sweep_requests": 10, "host": 2})
    schema.validate("device", d)
    assert d["batch_occupancy"] == pytest.approx(2.5)
    schema.validate("host", schema.host_stats({"host": 1}))


def test_schema_validate_rejects_drift():
    with pytest.raises(ValueError, match="missing"):
        schema.validate("scheduler", {"tasks_run": 1})
    bad = schema.scheduler_stats({})
    bad["made_up"] = 7
    with pytest.raises(ValueError, match="off-schema"):
        schema.validate("scheduler", bad)
    bad2 = schema.query_stats({})
    bad2["hit"] = 1.5
    with pytest.raises(ValueError, match="must be int"):
        schema.validate("query", bad2)


def test_schema_merge_and_delta_recompose():
    a = schema.scheduler_stats({"tasks_run": 10, "steals": 2,
                                "tasks_stolen": 6})
    b = schema.scheduler_stats({"tasks_run": 4, "steals": 2,
                                "tasks_stolen": 2})
    m = schema.scheduler_stats(schema.merge_counters(
        [a, b], schema.SCHEDULER_COUNTERS))
    schema.validate("scheduler", m)
    assert m["tasks_run"] == 14 and m["tasks_per_steal"] == 2.0
    d = schema.delta_counters(m, b, schema.SCHEDULER_COUNTERS)
    assert d["tasks_run"] == 10 and "tasks_per_steal" not in d


def test_real_producers_conform_to_schema(small_db):
    db, p = small_db
    bm, counts = pack_database(db, p.n_dense_items, return_counts=True)
    res, met = mine(bm, int(0.3 * len(db)), policy="clustered",
                    n_workers=2, max_k=4, item_counts=counts)
    schema.validate("scheduler", met.scheduler)
    for row in met.per_device:
        schema.validate("device", row)


# -------------------------------------------------------------- registry --

def test_registry_snapshot_isolates_failing_source():
    reg = MetricsRegistry()
    reg.register("ok", lambda: {"x": 1})
    reg.register("boom", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["ok"] == {"x": 1}
    assert "ZeroDivisionError" in snap["boom"]["error"]
    reg.unregister("boom")
    assert reg.names() == ["ok"]


def test_latency_recorder_exact_percentiles():
    rec = LatencyRecorder(cap=1000)
    for ms in range(1, 101):                       # 1..100 ms
        rec.record("hit", ms / 1000.0)
    p = rec.percentiles("hit")
    assert p["n"] == 100
    assert p["p50"] == pytest.approx(0.051)        # round(0.50·99) = 50
    assert p["p95"] == pytest.approx(0.095)        # round(0.95·99) = 94
    assert p["p99"] == pytest.approx(0.099)        # round(0.99·99) = 98
    assert p["max"] == pytest.approx(0.100)
    rec.record("sweep", 0.002, n=3)                # batched share
    assert rec.counts() == {"hit": 100, "sweep": 3}


# ---------------------------------------------------- traced engine runs --

def test_traced_mine_matches_untraced_and_covers_workers(small_db):
    """The acceptance run: traced bucket/clustered mine yields a
    Perfetto-loadable trace with one lane per worker carrying task +
    flush/sweep + steal spans, well-formed nesting, and per-worker
    time-in-state that tiles the worker's active extent to within
    5%."""
    db, p = small_db
    bm, counts = pack_database(db, p.n_dense_items, return_counts=True)
    ms = int(0.3 * len(db))
    ref, _ = mine(bm, ms, policy="clustered", n_workers=4, max_k=4,
                  granularity="bucket", item_counts=counts)
    tr = Tracer()
    res, met = mine(bm, ms, policy="clustered", n_workers=4, max_k=4,
                    granularity="bucket", item_counts=counts, trace=tr)
    assert res == ref                              # tracing is inert
    names = tr.lane_names()
    workers = [n for n in names if n.startswith("worker-")]
    assert len(workers) == 4 and "driver" in names
    assert any(n.startswith("dispatcher-") for n in names)
    spans = [e for e in tr.events() if e.ph == "X"]
    cats = {e.cat for e in spans}
    assert {"task", "level", "flush", "sweep"} <= cats
    assert any(e.cat == "steal" or e.cat == "idle" for e in spans)
    assert check_nesting(tr.events()) == []
    per_worker = {e.lane for e in spans if e.cat == "task"}
    assert per_worker >= set(workers)              # every worker ran tasks
    for key, row in time_in_state(tr).items():
        if not row["lane"].startswith("worker-"):
            continue
        # spans tile the worker loop: total within 5% of the lane's
        # extent (+2ms absolute slack for inter-span bookkeeping)
        assert row["total"] >= 0.95 * row["extent"] - 0.002, row
        assert row["total"] <= row["extent"] + 1e-6, row
    doc = chrome_trace(tr)
    lanes_with_tasks = {(e["pid"], e["tid"]) for e in doc["traceEvents"]
                       if e.get("cat") == "task"}
    assert len(lanes_with_tasks) >= 4
    json.dumps(doc)                                # serializable


def test_traced_streaming_spans_lag_and_latency(small_db):
    from repro.core.streaming import PatternServer, StreamingMiner
    db, p = small_db
    ms = int(0.25 * len(db))
    tr = Tracer()
    sm = StreamingMiner(p.n_dense_items, ms, initial_db=db[:200],
                        n_workers=2, max_k=3, tracer=tr)
    try:
        sm.refresh()
        assert sm.refresh_lag == 0.0
        sm.ingest(db[200:260])
        assert sm.refresh_lag > 0.0                # pending segment waits
        sm.ingest(db[260:300])
        sm.refresh()
        assert sm.refresh_lag == 0.0               # publish drains the lag
        names = {e.name for e in tr.events()}
        assert {"ingest", "refresh", "publish"} <= names
        assert any(e.ph == "C" and e.name == "refresh_lag"
                   for e in tr.events())
        assert check_nesting(tr.events()) == []
        srv = PatternServer(sm)
        srv.support((0,))
        srv.top_k((), 3)
        kinds = set(srv.latency_percentiles())
        assert "top_k" in kinds and ("hit" in kinds or "sweep" in kinds)
        snap = sm.metrics_registry().snapshot()
        assert snap["stream"]["generation"] == sm.generation
        assert snap["stream"]["refresh_lag_s"] == 0.0
        assert "query_latency" in snap and "scheduler" in snap
        schema.validate("query", srv.merged_stats())
    finally:
        sm.close()
