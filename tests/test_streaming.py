"""Streaming subsystem: ingest/refresh equivalence vs batch mining,
border classification, incremental-work bounds, and snapshot serving."""
import threading

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.fpm import mine
from repro.core.itemsets import brute_force_frequent
from repro.core.streaming import (PatternServer, PatternSnapshot,
                                  StreamingMiner)
from repro.core.tidlist import pack_database

RNG = np.random.default_rng(7)


def rand_db(n, items=16, seed=7):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(items, size=rng.integers(2, 7),
                              replace=False).tolist())
            for _ in range(n)]


def batch_mine(db, n_items, ms, **kw):
    return mine(pack_database(db, n_items), ms, **kw)[0]


# ------------------------------------------------- equivalence matrix
@pytest.mark.parametrize("granularity,policy", [
    ("bucket", "clustered"), ("bucket", "nn"),
    ("depth-first", "clustered"), ("depth-first", "nn"),
    ("candidate", "clustered"), ("bucket", "fifo"),
])
def test_refresh_matches_batch_mine(granularity, policy):
    """The correctness anchor: after ANY ingest sequence, refresh()
    equals a from-scratch mine() on the concatenated database — same
    itemsets, same supports — at every generation."""
    full = rand_db(400)
    cuts = [250, 320, 360, 400]
    ms = 40
    sm = StreamingMiner(16, ms, initial_db=full[:cuts[0]],
                        granularity=granularity, policy=policy,
                        n_workers=3, max_k=5)
    prev_cut = cuts[0]
    for cut in cuts:
        if cut != prev_cut:
            sm.ingest(full[prev_cut:cut])
            prev_cut = cut
        rep = sm.refresh()
        ref = batch_mine(full[:cut], 16, ms, granularity=granularity,
                         policy=policy, n_workers=3, max_k=5)
        assert dict(sm.snapshot.supports) == ref
        assert rep.frequent == len(ref)
        assert sm.snapshot.n_transactions == cut


@pytest.mark.parametrize("granularity", ["bucket", "depth-first"])
def test_refresh_matches_on_logical_two_shard_mesh(granularity):
    """The equivalence holds when the SAME streaming engine runs over
    a logical 2-shard mesh (sharded arena, per-shard dispatchers,
    device-affine workers)."""
    full = rand_db(400, seed=11)
    ms = 40
    sm = StreamingMiner(16, ms, initial_db=full[:300],
                        granularity=granularity, n_workers=4,
                        max_k=5, mesh=2)
    sm.refresh()
    sm.ingest(full[300:])
    sm.refresh()
    ref = batch_mine(full, 16, ms, granularity=granularity,
                     n_workers=4, max_k=5, mesh=2)
    assert dict(sm.snapshot.supports) == ref


def test_multiple_ingests_between_refreshes_fold_together():
    full = rand_db(300, seed=3)
    ms = 30
    sm = StreamingMiner(16, ms, initial_db=full[:200], max_k=4)
    sm.refresh()
    sm.ingest(full[200:240])
    sm.ingest(full[240:270])
    sm.ingest(full[270:])
    assert sm.needs_refresh
    rep = sm.refresh()
    assert rep.segments_refreshed == (1, 2, 3)
    assert not sm.needs_refresh
    assert dict(sm.snapshot.supports) == batch_mine(full, 16, ms,
                                                    max_k=4)


def test_empty_initial_db_then_ingest():
    """A miner may start with nothing: generation 0 serves the empty
    snapshot, and the first refresh after ingest equals batch mining
    the batches alone."""
    sm = StreamingMiner(16, 20, max_k=4)
    assert sm.snapshot.generation == 0
    assert dict(sm.snapshot.supports) == {}
    assert sm.refresh().frequent == 0           # refresh of nothing
    db = rand_db(200, seed=5)
    sm.ingest(db[:150])
    sm.ingest(db[150:])
    sm.refresh()
    assert dict(sm.snapshot.supports) == batch_mine(db, 16, 20, max_k=4)


def test_ingest_rejects_out_of_range_items():
    sm = StreamingMiner(8, 2)
    with pytest.raises(ValueError, match="item id"):
        sm.ingest([[1, 2], [7, 9]])


# ------------------------------------------------- incremental bounds
def retail_stream(n=3000, cut=2980):
    from repro.data.transactions import load
    db, p = load("retail", seed=0)
    db = db[:n]
    return db, db[:cut], db[cut:], p.n_items


@pytest.mark.parametrize("granularity", ["bucket", "depth-first"])
def test_incremental_refresh_touches_fewer_rows(granularity):
    """A small ingest invalidates few equivalence classes: the refresh
    must read strictly fewer bitmap rows (and far fewer bytes) than a
    from-scratch re-mine at the same granularity, and most candidates
    must be answered from the reuse store without any sweep."""
    db, init, batch, n_items = retail_stream()
    ms = 30
    sm = StreamingMiner(n_items, ms, initial_db=init, max_k=4,
                        n_workers=3, granularity=granularity)
    sm.refresh()
    rep = sm.refresh()                          # nothing pending:
    assert rep.rows_touched == 0                # zero rows re-read
    sm.ingest(batch)
    rep = sm.refresh()
    ref, full = mine(pack_database(db, n_items), ms, max_k=4,
                     n_workers=3, granularity=granularity)
    assert dict(sm.snapshot.supports) == ref
    assert rep.rows_touched < full.rows_touched
    assert rep.bytes_swept < full.bytes_swept
    assert rep.reused > rep.swept_delta + rep.swept_full


def test_ingest_h2d_bills_only_the_new_segment():
    """Eager device backing: an ingest uploads exactly the new
    segment's base-bitmap payload — never the whole arena again."""
    db, init, batch, n_items = retail_stream(n=1200, cut=1100)
    sm = StreamingMiner(n_items, 20, initial_db=init, max_k=3,
                        arena="jax", backend="pallas-interpret",
                        n_workers=2)
    base_h2d = sm.arena.h2d_bytes               # eager initial upload
    assert base_h2d == sm.arena.seg_nbytes(0)
    rep = sm.ingest(batch)
    assert rep.h2d_bytes == rep.payload_bytes == sm.arena.seg_nbytes(1)
    assert rep.payload_bytes < base_h2d         # not the whole arena
    sm.refresh()
    assert dict(sm.snapshot.supports) == batch_mine(
        db, n_items, 20, max_k=3)


# ------------------------------------------------- border classification
def test_border_classification_stayed_born_died():
    """Fraction-based min_support: the threshold rises with the
    database, so the border moves both ways — new itemsets are born
    from the ingested pattern, old borderline ones die."""
    init = [[0, 1, 2]] * 60 + [[0, 1]] * 3 + [[3, 4]] * 45
    sm = StreamingMiner(6, 0.4, initial_db=init, max_k=4)
    r0 = sm.refresh()
    g1 = dict(sm.snapshot.supports)
    assert r0.born == len(g1) > 0
    assert (3, 4) in g1                         # 45 >= ms = 0.4*108 = 43
    # ingest tilts the database toward {0,1,2,5}: |D| grows, ms rises
    # to 0.4*198 = 79 — {3,4} (still 45) falls off the border while
    # the 5-itemsets (90) climb over it
    sm.ingest([[0, 1, 2, 5]] * 90)
    r1 = sm.refresh()
    g2 = dict(sm.snapshot.supports)
    assert r1.died == len(set(g1) - set(g2)) > 0    # (3,4) fell under ms
    assert r1.born == len(set(g2) - set(g1)) > 0    # (5,)-itemsets born
    assert r1.stayed == len(set(g1) & set(g2)) > 0
    assert (3, 4) not in g2 and (0, 1, 5) in g2


def test_fixed_absolute_threshold_nothing_dies():
    full = rand_db(300, seed=9)
    sm = StreamingMiner(16, 25, initial_db=full[:200], max_k=4)
    sm.refresh()
    g1 = set(sm.snapshot.supports)
    sm.ingest(full[200:])
    rep = sm.refresh()
    assert rep.died == 0                        # supports only grow
    assert g1 <= set(sm.snapshot.supports)


# ------------------------------------------------- serving layer
def test_snapshot_swap_is_atomic_queries_see_old_generation():
    """While a refresh is mining, the server answers from the previous
    published generation; the swap is one reference assignment."""
    full = rand_db(400, seed=13)
    ms = 40
    sm = StreamingMiner(16, ms, initial_db=full[:300], max_k=4)
    sm.refresh()
    srv = PatternServer(sm)
    g1 = dict(srv.frequent())
    sm.ingest(full[300:])
    seen = {}

    def probe(next_snap):
        # called after mining, immediately BEFORE the swap: the server
        # still serves generation 1 even though generation 2 is built
        seen["gen"] = srv.snapshot.generation
        seen["supports"] = dict(srv.frequent())
        seen["next"] = next_snap.generation

    sm.refresh(before_publish=probe)
    assert seen["gen"] == 1 and seen["next"] == 2
    assert seen["supports"] == g1
    assert srv.snapshot.generation == 2
    assert dict(srv.frequent()) == batch_mine(full, 16, ms, max_k=4)


def test_queries_during_concurrent_refresh_are_consistent():
    """Thread-level smoke: a query loop racing a real refresh must only
    ever observe fully-published generations (monotone, self-consistent
    snapshots)."""
    full = rand_db(600, seed=17)
    ms = 50
    sm = StreamingMiner(16, ms, initial_db=full[:400], max_k=5,
                        n_workers=3)
    sm.refresh()
    g1 = dict(sm.snapshot.supports)
    sm.ingest(full[400:])
    srv = PatternServer(sm)
    stop = threading.Event()
    bad = []

    def query_loop():
        while not stop.is_set():
            snap = srv.snapshot
            if snap.generation == 1 and dict(snap.supports) != g1:
                bad.append("gen1 mutated")
            if snap.generation not in (1, 2):
                bad.append(f"gen {snap.generation}")

    t = threading.Thread(target=query_loop)
    t.start()
    try:
        sm.refresh()
    finally:
        stop.set()
        t.join()
    assert not bad
    assert srv.snapshot.generation == 2


def test_snapshot_query_api():
    snap = PatternSnapshot(3, 100, 10, {
        (1,): 50, (2,): 40, (1, 2): 30, (1, 3): 20, (1, 2, 4): 12})
    assert snap.support((2, 1)) == 30           # order-insensitive
    assert snap.support((9,)) is None
    assert snap.top_k((1,), 2) == [((1, 2), 30), ((1, 3), 20)]
    assert snap.top_k((), 1) == [((1,), 50)]
    assert snap.frequent(25) == {(1,): 50, (2,): 40, (1, 2): 30}
    assert len(snap.frequent()) == 5


def test_pattern_server_counts_queries():
    sm = StreamingMiner(8, 2, initial_db=[[0, 1], [0, 1], [1, 2]])
    sm.refresh()
    srv = PatternServer(sm)
    srv.support((0, 1))
    srv.top_k((0,))
    srv.frequent()
    assert srv.queries == 3


# ------------------------------------------------- property tests
@settings(max_examples=10, deadline=None)
@given(st.data())
def test_property_interleaved_ingest_refresh_equals_batch(data):
    """Random databases, random split points, random refresh cadence,
    both incremental granularities: the final refresh always equals
    the brute-force frequent set of the concatenation."""
    n_items = data.draw(st.integers(5, 10))
    n_tx = data.draw(st.integers(8, 60))
    seed = data.draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    db = [sorted(rng.choice(n_items,
                            size=rng.integers(1, min(5, n_items) + 1),
                            replace=False).tolist())
          for _ in range(n_tx)]
    n_cuts = data.draw(st.integers(1, 4))
    cuts = sorted(data.draw(
        st.lists(st.integers(0, n_tx), min_size=n_cuts, max_size=n_cuts)))
    granularity = data.draw(st.sampled_from(["bucket", "depth-first"]))
    ms = data.draw(st.integers(1, max(1, n_tx // 3)))
    sm = StreamingMiner(n_items, ms, initial_db=db[:cuts[0]],
                        granularity=granularity, n_workers=2, max_k=4)
    prev = cuts[0]
    for cut in cuts[1:]:
        sm.ingest(db[prev:cut])
        prev = cut
        if data.draw(st.booleans()):            # refresh sometimes:
            sm.refresh()                        # pending segs pile up
    sm.ingest(db[prev:])
    sm.refresh()
    want = {x: s for x, s in brute_force_frequent(db, ms, max_k=4).items()}
    assert dict(sm.snapshot.supports) == want


def test_failed_refresh_leaves_state_intact_and_retry_is_exact():
    """A refresh that dies mid-mine (backend error) must not corrupt
    the miner: supports/known are committed only at publish, so a
    retry re-folds the SAME pending segments once — no double-counted
    deltas, and the retried generation still equals batch mining."""
    from repro.core import fpm as fpm_mod
    from repro.core.join_backend import NumpyBackend

    full = rand_db(300, seed=21)
    ms = 30
    sm = StreamingMiner(16, ms, initial_db=full[:250], max_k=4,
                        n_workers=2)
    sm.refresh()
    g1 = dict(sm.snapshot.supports)
    sm.ingest(full[250:])

    class Bomb(NumpyBackend):
        def sweep_many(self, arena, requests):
            raise RuntimeError("mid-refresh boom")

    orig = fpm_mod.resolve_backend
    fpm_mod.resolve_backend = lambda spec: Bomb()
    sm.close()        # next refresh rebuilds the persistent runtime
    try:              # through the patched resolver → hits the bomb
        with pytest.raises(RuntimeError, match="boom"):
            sm.refresh()
    finally:
        fpm_mod.resolve_backend = orig
        sm.close()    # drop the poisoned runtime before the retry
    # nothing published, nothing folded, queries still serve gen 1
    assert sm.snapshot.generation == 1
    assert dict(sm.snapshot.supports) == g1
    assert sm.needs_refresh
    # the retry folds the pending segment exactly once
    sm.refresh()
    assert dict(sm.snapshot.supports) == batch_mine(full, 16, ms,
                                                    max_k=4)


# ------------------------------------------------- segment compaction
def test_compaction_policy_fires_and_bounds_segments():
    """Default policy: small cold tails fold at publish, so repeated
    ingest/refresh cycles never accumulate segments."""
    full = rand_db(300, seed=3)
    sm = StreamingMiner(16, 30, initial_db=full[:200], n_workers=2,
                        max_k=4)
    sm.refresh()
    compacted = 0
    for lo in range(200, 300, 20):
        sm.ingest(full[lo:lo + 20])
        rep = sm.refresh()
        compacted += rep.compacted_segments
        if rep.compacted_segments:
            assert rep.compaction_bytes > 0
    assert compacted > 0
    assert sm.arena.n_segments <= 2
    assert dict(sm.snapshot.supports) == batch_mine(
        full, 16, 30, n_workers=2, max_k=4)


def test_compaction_disabled_accumulates_segments():
    full = rand_db(120, seed=5)
    sm = StreamingMiner(16, 12, initial_db=full[:60], n_workers=2,
                        max_k=4, compact_ratio=0.0,
                        compact_segments=10 ** 9)
    sm.refresh()
    for lo in range(60, 120, 20):
        sm.ingest(full[lo:lo + 20])
        rep = sm.refresh()
        assert rep.compacted_segments == 0
    assert sm.arena.n_segments == 4
    assert sm.arena.compactions == 0
    # compact_now() folds everything refreshed, results unchanged
    assert sm.compact_now() == 3
    assert sm.arena.n_segments == 1
    assert dict(sm.snapshot.supports) == batch_mine(
        full, 16, 12, n_workers=2, max_k=4)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_property_mining_identical_across_compaction_cadence(data):
    """Bit-identical published supports whatever the compaction
    cadence — never, after every refresh, or at random points —
    across both incremental granularities and a logical 2-shard
    mesh. Prefix handles recycled by one generation's mining span
    the next compaction, so this also exercises slot recycling
    through a merge."""
    n_items = data.draw(st.integers(6, 10))
    n_tx = data.draw(st.integers(30, 80))
    seed = data.draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    db = [sorted(rng.choice(n_items,
                            size=rng.integers(1, min(5, n_items) + 1),
                            replace=False).tolist())
          for _ in range(n_tx)]
    cadence = data.draw(st.sampled_from(["never", "every", "random"]))
    granularity = data.draw(st.sampled_from(["bucket", "depth-first"]))
    mesh = data.draw(st.sampled_from([None, 2]))
    ms = data.draw(st.integers(1, max(1, n_tx // 4)))
    cut = data.draw(st.integers(0, n_tx - 1))
    sm = StreamingMiner(n_items, ms, initial_db=db[:cut],
                        granularity=granularity, n_workers=2, max_k=4,
                        mesh=mesh, compact_ratio=0.0,
                        compact_segments=10 ** 9)
    sm.refresh()
    lo = cut
    while lo < n_tx:
        hi = min(n_tx, lo + data.draw(st.integers(5, 20)))
        sm.ingest(db[lo:hi])
        lo = hi
        sm.refresh()
        if cadence == "every" or (cadence == "random"
                                  and data.draw(st.booleans())):
            sm.compact_now()
    want = batch_mine(db, n_items, ms, granularity=granularity,
                      n_workers=2, max_k=4)
    assert dict(sm.snapshot.supports) == want


# ------------------------------------------- refresh/ingest overlap
def test_ingest_during_inflight_refresh_lands_next_generation():
    """ingest() must not block behind a running refresh(): the batch
    appended mid-refresh is invisible to the publishing generation
    and folds in on the next one."""
    full = rand_db(300, seed=9)
    sm = StreamingMiner(16, 30, initial_db=full[:260], n_workers=2,
                        max_k=4)
    sm.refresh()
    sm.ingest(full[260:280])
    seen = {}

    def hook(snapshot):
        # refresh() is mid-flight (pre-publish): ingest from the hook
        # thread itself — a blocking ingest would deadlock right here
        rep = sm.ingest(full[280:])
        seen["ingest_wall"] = rep.wall_s
        seen["needs_refresh"] = sm.needs_refresh

    rep2 = sm.refresh(before_publish=hook)
    # the published generation folded ONLY the pre-refresh batch
    assert sm.snapshot.n_transactions == 280
    assert seen["needs_refresh"] is True        # mid-refresh batch queued
    assert dict(sm.snapshot.supports) == batch_mine(
        full[:280], 16, 30, n_workers=2, max_k=4)
    rep3 = sm.refresh()
    assert rep3.generation == rep2.generation + 1
    assert sm.snapshot.n_transactions == 300
    assert dict(sm.snapshot.supports) == batch_mine(
        full, 16, 30, n_workers=2, max_k=4)


# ------------------------------------------------- jit cache bounds
def test_jit_cache_entries_bounded_across_ingest_cycles():
    """Pow2 shape padding in the batched kernel backend: 10
    ingest/refresh cycles (compaction off, so every cycle adds a
    fresh segment width) must mint a bounded number of jit cache
    entries, not one per (segment, batch shape)."""
    from repro.kernels.bitmap_join.kernel import bitmap_join_many_kernel
    full = rand_db(150, seed=13)
    sm = StreamingMiner(16, 12, initial_db=full[:50], n_workers=2,
                        max_k=4, backend="pallas-interpret",
                        compact_ratio=0.0, compact_segments=10 ** 9)
    sm.refresh()
    base = bitmap_join_many_kernel._cache_size()
    for cyc in range(10):
        lo = 50 + cyc * 10
        sm.ingest(full[lo:lo + 10])
        sm.refresh()
    grown = bitmap_join_many_kernel._cache_size() - base
    assert sm.arena.n_segments == 11            # nothing compacted
    # log-many shapes: B, E, L and W each pad to powers of two, so the
    # cycle count must not show up in the cache size
    assert grown <= 8, grown
    assert dict(sm.snapshot.supports) == batch_mine(
        full, 16, 12, n_workers=2, max_k=4)


@pytest.mark.parametrize("granularity,mesh", [
    ("bucket", None), ("depth-first", None), ("bucket", 2),
    ("depth-first", 2),
])
def test_compact_every_refresh_matches_batch_mine(granularity, mesh):
    """Deterministic cadence coverage (the hypothesis variant above
    skips without hypothesis): compacting after EVERY refresh, on both
    granularities and a logical 2-shard mesh, never changes published
    supports."""
    full = rand_db(200, seed=17)
    sm = StreamingMiner(16, 20, initial_db=full[:120],
                        granularity=granularity, mesh=mesh,
                        n_workers=2, max_k=4, compact_ratio=0.0,
                        compact_segments=10 ** 9)
    sm.refresh()
    sm.compact_now()
    for lo in range(120, 200, 40):
        sm.ingest(full[lo:lo + 40])
        sm.refresh()
        assert sm.compact_now() >= 0
        assert sm.arena.n_segments == 1
    assert dict(sm.snapshot.supports) == batch_mine(
        full, 16, 20, granularity=granularity, n_workers=2, max_k=4)
