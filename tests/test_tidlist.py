"""Unit + property tests for TID bitmap machinery."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import tidlist


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    bits = rng.random((13, 70)) < 0.4
    packed = tidlist.pack_bool(bits)
    assert packed.dtype == np.uint32
    back = tidlist.unpack_bool(packed, 70)
    np.testing.assert_array_equal(back, bits)


def test_popcount_matches_python():
    rng = np.random.default_rng(1)
    xs = rng.integers(0, 2 ** 32, size=1000, dtype=np.uint32)
    got = tidlist.popcount32(xs)
    want = np.array([bin(int(x)).count("1") for x in xs])
    np.testing.assert_array_equal(got, want)


def test_pack_database_supports():
    db = [[0, 1], [1, 2], [0, 1, 2], [1]]
    bm = tidlist.pack_database(db, 3)
    sup = tidlist.popcount32(bm).sum(axis=1)
    np.testing.assert_array_equal(sup, [2, 4, 2])


def test_pack_database_matches_dense_pack_bool_reference():
    """The direct per-word packer (O(W) per item, no [I, T] bool
    temporary) must produce bit-identical words to packing the dense
    bool matrix — including across word boundaries."""
    rng = np.random.default_rng(3)
    n_items, n_tx = 7, 131                   # 131 txns -> 5 words, ragged
    db = [sorted(rng.choice(n_items, size=rng.integers(0, 5),
                            replace=False).tolist()) for _ in range(n_tx)]
    bits = np.zeros((n_items, n_tx), dtype=bool)
    for t, txn in enumerate(db):
        for i in txn:
            bits[i, t] = True
    np.testing.assert_array_equal(tidlist.pack_database(db, n_items),
                                  tidlist.pack_bool(bits))


def test_popcount_fallback_path_matches(monkeypatch):
    """The pre-numpy-2.0 SWAR fallback (never taken when
    np.bitwise_count exists) must agree with the ufunc — and not
    copy an input that is already uint32."""
    monkeypatch.delattr(np, "bitwise_count", raising=False)
    rng = np.random.default_rng(5)
    xs = rng.integers(0, 2 ** 32, size=500, dtype=np.uint32)
    got = tidlist.popcount32(xs)
    want = np.array([bin(int(x)).count("1") for x in xs])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(tidlist.popcount32(xs.astype(np.int64)),
                                  want)                # non-uint32 input


def test_support_counts_prefix():
    db = [[0, 1, 2], [0, 1], [1, 2], [0, 2]]
    bm = tidlist.pack_database(db, 3)
    # prefix = item 0; extensions 1, 2
    counts = tidlist.support_counts(bm[0], bm[[1, 2]])
    assert counts.tolist() == [2, 2]   # {0,1}: t0,t1 ; {0,2}: t0,t3


def test_support_counts_single_extension_fast_path():
    """E==1 (deep, narrow equivalence classes) skips the broadcast
    temporary but must return the same shape/dtype/values."""
    db = [[0, 1, 2], [0, 1], [1, 2], [0, 2]]
    bm = tidlist.pack_database(db, 3)
    counts = tidlist.support_counts(bm[0], bm[[1]])
    assert counts.shape == (1,) and counts.dtype == np.int64
    assert counts.tolist() == [2]


def test_support_counts_empty_database_zero_words():
    """W==0 (empty database) must return zeros, not divide by zero in
    the adaptive chunk computation."""
    prefix = np.zeros(0, dtype=np.uint32)
    exts = np.zeros((3, 0), dtype=np.uint32)
    assert tidlist.support_counts(prefix, exts).tolist() == [0, 0, 0]


def test_support_counts_default_chunk_adapts_to_width():
    """The [chunk, W] temporary stays ~CHUNK_TARGET_BYTES: wide rows
    (scaled datasets) get a proportionally smaller chunk, and chunked
    execution still matches the unchunked result."""
    rng = np.random.default_rng(7)
    w = tidlist.CHUNK_TARGET_BYTES // 4 // 100    # -> default chunk 100
    prefix = rng.integers(0, 2 ** 32, size=w, dtype=np.uint32)
    exts = rng.integers(0, 2 ** 32, size=(250, w), dtype=np.uint32)
    got = tidlist.support_counts(prefix, exts)    # forces 3 chunks
    want = tidlist.support_counts(prefix, exts, chunk=exts.shape[0])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.integers(0, 19), max_size=10), min_size=1,
                max_size=40))
def test_property_support_equals_set_intersection(db):
    db = [sorted(set(t)) for t in db]
    bm = tidlist.pack_database(db, 20)
    tids = {i: {t for t, txn in enumerate(db) if i in txn}
            for i in range(20)}
    rng = np.random.default_rng(0)
    for _ in range(5):
        items = rng.choice(20, size=rng.integers(1, 4), replace=False)
        want = set.intersection(*(tids[i] for i in items)) \
            if len(items) else set()
        got = tidlist.support_of(bm[list(items)])
        assert got == len(want)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 100))
def test_property_pack_shape(n_items, n_tx):
    bits = np.zeros((n_items, n_tx), bool)
    packed = tidlist.pack_bool(bits)
    assert packed.shape == (n_items, tidlist.n_words(n_tx))
    assert tidlist.popcount32(packed).sum() == 0
