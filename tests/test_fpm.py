"""Apriori FPM engine vs brute force, both policies + locality metrics."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.fpm import mine, mine_serial
from repro.core.itemsets import brute_force_frequent
from repro.core.tidlist import pack_database
from repro.data.transactions import load


@pytest.fixture(scope="module")
def small_db():
    db, p = load("mushroom", seed=0)
    return [t for t in db[:300]], p


def test_serial_matches_brute_force(small_db):
    db, p = small_db
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.3 * len(db))
    ref = brute_force_frequent(db, ms, max_k=4)
    got = mine_serial(bm, ms, max_k=4)
    assert got == ref


@pytest.mark.parametrize("policy", ["cilk", "fifo", "clustered"])
def test_parallel_matches_serial(small_db, policy):
    db, p = small_db
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.3 * len(db))
    ref = mine_serial(bm, ms, max_k=4)
    got, metrics = mine(bm, ms, policy=policy, n_workers=4, max_k=4,
                        granularity="candidate")
    assert got == ref
    assert metrics.scheduler["tasks_run"] == metrics.candidates


@pytest.mark.parametrize("policy", ["cilk", "fifo", "clustered"])
def test_bucket_granularity_matches_serial(small_db, policy):
    """Default granularity: one task per prefix bucket, counts by
    vectorized sweep — identical supports, ~candidates/avg-bucket-size
    tasks."""
    db, p = small_db
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.3 * len(db))
    ref = mine_serial(bm, ms, max_k=4)
    got, metrics = mine(bm, ms, policy=policy, n_workers=4, max_k=4)
    assert got == ref
    assert metrics.scheduler["tasks_run"] == metrics.buckets
    assert metrics.buckets < metrics.candidates
    assert metrics.rows_touched > 0
    assert metrics.bytes_swept > 0


def test_clustered_has_better_locality_than_cilk(small_db):
    """The paper's central claim, in this reproduction's metrics.
    Candidate granularity: the cache hit-rate gap is exactly the
    incidental locality the bucket engine later makes structural."""
    db, p = small_db
    bm = pack_database(db, p.n_dense_items)
    ms = int(0.25 * len(db))
    _, m_clu = mine(bm, ms, policy="clustered", n_workers=4, max_k=5,
                    granularity="candidate")
    _, m_cilk = mine(bm, ms, policy="cilk", n_workers=4, max_k=5,
                     granularity="candidate")
    assert m_clu.cache_hit_rate > m_cilk.cache_hit_rate
    assert (m_clu.scheduler["tasks_per_steal"]
            >= m_cilk.scheduler["tasks_per_steal"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_mine_equals_brute_force_random_db(seed):
    rng = np.random.default_rng(seed)
    n_items, n_tx = 12, 60
    db = [sorted(rng.choice(n_items, size=rng.integers(1, 7),
                            replace=False).tolist())
          for _ in range(n_tx)]
    ms = int(rng.integers(2, 12))
    ref = brute_force_frequent(db, ms, max_k=4)
    bm = pack_database(db, n_items)
    got, _ = mine(bm, ms, policy="clustered", n_workers=3, max_k=4)
    assert got == ref


def test_min_support_one_includes_every_item_present():
    db = [[0], [1], [2, 3]]
    bm = pack_database(db, 4)
    got = mine_serial(bm, 1, max_k=3)
    assert (0,) in got and (3,) in got and (2, 3) in got
